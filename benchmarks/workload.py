"""Shared benchmark workload: a small traced/untraced training or serving
run — the benchmark-suite stand-in for the paper's HeCBench/SPEChpc apps."""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.core import TraceConfig, Tracer
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig

#: the benchmark "suite": one app per model family (≙ HeCBench variety)
SUITE = ("stablelm-3b", "h2o-danube-1.8b", "mamba2-1.3b", "moonshot-v1-16b-a3b")

_SHAPE = ShapeSpec("bench", "train", 64, 4)


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def run_training_workload(
    arch: str,
    steps: int = 12,
    trace: Optional[TraceConfig] = None,
    seed: int = 0,
) -> Dict[str, float]:
    """Run `steps` smoke-config train steps; returns wall time + trace stats.

    The first 2 steps (compile) are excluded from timing, matching the
    paper's overhead protocol (steady-state tracing overhead).
    """
    mesh = _mesh()
    model = Model(get_config(arch).smoke(), mesh)
    trainer = Trainer(
        model,
        _SHAPE,
        Partitioner(mesh),
        TrainConfig(peak_lr=1e-3, warmup=2, total_steps=steps + 10),
        TrainerConfig(steps=2, ckpt_every=10**9, ckpt_dir=None),
        rng_seed=seed,
    )
    tracer = Tracer(trace) if trace is not None else None
    if tracer is not None:
        tracer.start()
    try:
        trainer.cfg.steps = 2
        trainer.run()  # warmup/compile (2 steps)
        t0 = time.perf_counter()
        trainer.cfg.steps = 2 + steps
        out = trainer.run()
        wall = time.perf_counter() - t0
    finally:
        if tracer is not None:
            tracer.stop()
    res = {"wall_s": wall, "steps": steps, "final_loss": out["final_loss"]}
    if tracer is not None and tracer.handle is not None:
        res.update(
            events=tracer.handle.events,
            dropped=tracer.handle.dropped,
            trace_bytes=tracer.handle.size_bytes,
        )
    return res

"""Fig 7a/7b — runtime overhead per tracing mode, with/without sampling.

Protocol mirrors §5.2: run each suite app against a no-tracing baseline and
the six configurations T-min/T-default/T-full and TS-* (sampling at 50 ms),
reporting per-app and mean/median percentage overhead.

Paper's claims to validate: T-default mean ≈ 5.36%, median ≈ 1.99%
(HeCBench); SPEChpc default-mode mean 4.35–5.14%, max < 10%; sampling adds
≈ 1%.  Our absolute workloads differ (smoke-scale JAX training on CPU) but
the protocol and the relative ordering are the reproduction target.
"""

from __future__ import annotations

import json
import statistics
import tempfile
from typing import Dict, List

from repro.core import TraceConfig

from .workload import SUITE, run_training_workload

CONFIGS = [
    ("T-min", "minimal", False),
    ("T-default", "default", False),
    ("T-full", "full", False),
    ("TS-min", "minimal", True),
    ("TS-default", "default", True),
    ("TS-full", "full", True),
]


def run(steps: int = 12, suite=SUITE, repeats: int = 1) -> Dict:
    rows: List[dict] = []
    repeats = max(repeats, 1)
    for arch in suite:
        base = min(
            run_training_workload(arch, steps)["wall_s"] for _ in range(repeats)
        )
        row = {"arch": arch, "baseline_s": base}
        for label, mode, sample in CONFIGS:
            # same min-of-repeats protocol as the baseline: a single traced
            # run would fold run-to-run noise into the reported overhead %
            best = None
            for _ in range(repeats):
                with tempfile.TemporaryDirectory() as d:
                    r = run_training_workload(
                        arch,
                        steps,
                        trace=TraceConfig(out_dir=d, mode=mode, sample=sample),
                    )
                if best is None or r["wall_s"] < best["wall_s"]:
                    best = r
            row[label] = 100.0 * (best["wall_s"] - base) / base
            row[f"{label}_events"] = best.get("events", 0)
        rows.append(row)
    summary = {}
    for label, _, _ in CONFIGS:
        vals = [r[label] for r in rows]
        summary[label] = {
            "mean_pct": statistics.mean(vals),
            "median_pct": statistics.median(vals),
            "max_pct": max(vals),
        }
    return {"rows": rows, "summary": summary}


def main():
    out = run()
    for r in out["rows"]:
        print(
            f"{r['arch']:22s} base={r['baseline_s']:.2f}s "
            + " ".join(f"{l}={r[l]:+.1f}%" for l, _, _ in CONFIGS)
        )
    print("\nsummary (overhead %):")
    for label, s in out["summary"].items():
        print(
            f"  {label:10s} mean={s['mean_pct']:+.2f}% median={s['median_pct']:+.2f}% "
            f"max={s['max_pct']:+.2f}%"
        )
    return out


if __name__ == "__main__":
    main()

"""§3.7 — aggregation at scale: 512 ranks' tallies through the local-master
→ global-master tree (the paper's production-machine validation point)."""

from __future__ import annotations

import time
from typing import Dict

from repro.core.aggregate import merge_tallies
from repro.core.plugins.tally import ApiStat, Tally


def _rank_tally(rank: int, apis: int = 24) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 0))
    for a in range(apis):
        st = ApiStat()
        for i in range(50):
            st.add(500 + 13 * a + i + rank)
        t.apis[("ust_jaxrt", f"api_{a}")] = st
    return t


def run(ranks: int = 512, fanout: int = 32) -> Dict:
    tallies = [_rank_tally(r) for r in range(ranks)]
    t0 = time.perf_counter()
    composite, stats = merge_tallies(tallies, fanout=fanout)
    dt = time.perf_counter() - t0
    key = ("ust_jaxrt", "api_0")
    assert composite.apis[key].calls == ranks * 50
    assert len(composite.processes) == ranks
    return {
        "ranks": ranks,
        "fanout": fanout,
        "depth": stats.depth,
        "messages": stats.messages,
        "merge_wall_s": dt,
        "composite_calls": composite.apis[key].calls,
        "hostnames": len(composite.hostnames),
    }


def main():
    for fanout in (8, 32, 128):
        out = run(fanout=fanout)
        print(
            f"  ranks={out['ranks']} fanout={fanout:3d} depth={out['depth']} "
            f"messages={out['messages']} wall={out['merge_wall_s'] * 1000:.1f}ms"
        )
    return run()


if __name__ == "__main__":
    main()

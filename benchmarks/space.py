"""Fig 8a/8b — trace disk-space requirement per tracing mode.

Paper's claims to validate: minimal < default < full; on average default
needs <20% and minimal <17% of the space of full mode; sampling (TS-*)
increases space; aggregate-only (§3.7) is kilobytes.
"""

from __future__ import annotations

import os
import statistics
import tempfile
from typing import Dict, List

from repro.core import TraceConfig

from .overhead import CONFIGS
from .workload import SUITE, run_training_workload


def run(steps: int = 10, suite=SUITE) -> Dict:
    rows: List[dict] = []
    for arch in suite:
        row = {"arch": arch}
        for label, mode, sample in CONFIGS:
            with tempfile.TemporaryDirectory() as d:
                r = run_training_workload(
                    arch, steps, trace=TraceConfig(out_dir=d, mode=mode, sample=sample)
                )
            row[label] = r["trace_bytes"]
        # beyond-paper: zstd-compressed default-mode streams
        with tempfile.TemporaryDirectory() as d:
            r = run_training_workload(
                arch, steps, trace=TraceConfig(out_dir=d, mode="default", compress=True)
            )
            row["TZ-default"] = r["trace_bytes"]
        # §3.7 aggregate-only footprint
        with tempfile.TemporaryDirectory() as d:
            run_training_workload(
                arch, steps, trace=TraceConfig(out_dir=d, mode="default", aggregate_only=True)
            )
            row["aggregate"] = sum(
                os.path.getsize(os.path.join(d, f)) for f in os.listdir(d) if f.endswith(".tally")
            )
        rows.append(row)
    norm = {
        label: statistics.mean(100.0 * r[label] / r["T-full"] for r in rows)
        for label, _, _ in CONFIGS
    }
    norm["TZ-default"] = statistics.mean(
        100.0 * r["TZ-default"] / r["T-full"] for r in rows
    )
    return {"rows": rows, "normalized_vs_full_pct": norm}


def main():
    out = run()
    for r in out["rows"]:
        print(
            f"{r['arch']:22s} "
            + " ".join(f"{l}={r[l] / 1024:.0f}KiB" for l, _, _ in CONFIGS)
            + f" aggregate={r['aggregate']}B"
        )
    print("\nnormalized space vs T-full (%):")
    for label, pct in out["normalized_vs_full_pct"].items():
        print(f"  {label:10s} {pct:6.1f}%")
    return out


if __name__ == "__main__":
    main()

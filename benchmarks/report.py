"""Generate the EXPERIMENTS.md §Dry-run/§Roofline/§Perf tables from
results/*.json. (Run after dryrun + perf; the narrative in EXPERIMENTS.md
references these tables.)

    PYTHONPATH=src python -m benchmarks.report > results/report.md
"""

from __future__ import annotations

import glob
import json
import os

from .roofline import RESULTS, load, render

PERF = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def perf_tables() -> str:
    out = []
    for path in sorted(glob.glob(os.path.join(PERF, "cell_*.json"))):
        cell = os.path.basename(path)[len("cell_") : -len(".json")]
        rows = json.load(open(path))
        base = rows[0]
        out.append(
            f"\n### Cell {cell}: {base['arch']} × {base['shape']} ({base['mesh']})\n"
        )
        hdr = (
            f"| variant | compute s | memory s | collective s | bound | "
            f"useful/HLO | roofline% | peak HBM GiB |"
        )
        out.append(hdr)
        out.append("|---|---|---|---|---|---|---|---|")
        for r in rows:
            rf = r["roofline"]
            out.append(
                f"| {r['variant']} | {rf['t_compute']:.4f} | {rf['t_memory']:.4f} | "
                f"{rf['t_collective']:.4f} | {rf['bottleneck']} | "
                f"{rf['useful_flops_ratio']:.3f} | {100 * rf['roofline_fraction']:.1f}% | "
                f"{rf.get('peak_bytes', 0) / 2**30:.1f} |"
            )
        for r in rows[1:]:
            out.append(f"\n**{r['variant']}**")
            out.append(f"- hypothesis: {r['hypothesis']}")
            out.append(f"- prediction: {r['prediction']}")
            d = r["delta"]
            out.append(
                f"- measured: compute ×{d['t_compute']:.2f}, memory ×{d['t_memory']:.2f}, "
                f"collective ×{d['t_collective']:.3f}, roofline ×{d['roofline_fraction']:.1f}, "
                f"peak HBM ×{d['peak_bytes']:.2f}"
            )
    return "\n".join(out)


def dryrun_summary() -> str:
    rows = load()
    single = [r for r in rows if not r.get("multi_pod")]
    multi = [r for r in rows if r.get("multi_pod")]
    ok_s = sum(1 for r in single if r.get("ok"))
    ok_m = sum(1 for r in multi if r.get("ok"))
    lines = [
        f"single-pod (16×16=256 chips): {ok_s}/{len(single)} cells compiled",
        f"multi-pod (2×16×16=512 chips): {ok_m}/{len(multi)} cells compiled",
        "",
        "```",
        render(rows, multi_pod=False),
        "```",
        "",
        "multi-pod memory/collective proof (per-device):",
        "```",
        render(rows, multi_pod=True),
        "```",
    ]
    return "\n".join(lines)


def main():
    print("## §Dry-run + §Roofline (generated)\n")
    print(dryrun_summary())
    print("\n## §Perf hillclimb (generated)\n")
    print(perf_tables())


if __name__ == "__main__":
    main()

"""Wide-tally streaming bandwidth: bytes-on-wire, full vs delta (protocol v2),
plus the subscriber-fanout sweep of the broadcast hub.

The exascale failure mode the delta protocol targets: a rank tracing a very
wide API surface (thousands of tally rows) re-ships the *entire* cumulative
table every push under full-snapshot streaming, even though only the few hot
APIs changed since the last interval.  This benchmark builds such a tally,
advances only a hot subset each round, pushes through a real
``SnapshotStreamer`` → ``MasterServer`` TCP pair in both modes, and reports
steady-state bytes-on-wire (the first full frame is excluded — both modes
must pay it) plus the reduction factor.  Master-side composites are checked
for equality so the saving is never bought with wrong numbers.

The fanout sweep attaches 1 / 64 / 512 live subscribers and counts the
composite serializations (``MasterServer.sub_encodes``) the hub spends per
update: the shared-buffer hub encodes each delta **once per tenant**, so the
encode count must stay flat as subscribers grow (``encode_flatness ≈ 1``) —
the per-connection loop it replaced scaled encodes linearly.

    PYTHONPATH=src python -m benchmarks.stream_bw [--width 2000] [--rounds 40]
        [--fanout-subs 1,64,512] [--json BENCH_stream.json]
"""

from __future__ import annotations

import argparse
import selectors
import socket
import time

from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import (
    PROTOCOL_VERSION,
    MasterServer,
    SnapshotStreamer,
    pack_frame,
    parse_addr,
    recv_frame,
)


def make_wide_tally(width: int) -> Tally:
    """A cumulative tally with ``width`` host-API rows (plus device rows)."""
    t = Tally()
    t.hostnames.add("bench-node")
    t.processes.add(1)
    t.threads.add((1, 1))
    for i in range(width):
        st = ApiStat()
        st.add(1_000 + i)
        t.apis[("ust_jaxrt", f"api_{i:05d}")] = st
    for i in range(width // 10):
        st = ApiStat()
        st.add(5_000 + i)
        t.device_apis[("ust_kernel", f"kernel_{i:04d}")] = st
    return t


def advance(t: Tally, round_i: int, hot: int) -> None:
    """One interval of activity: only ``hot`` rows accumulate new calls."""
    for i in range(hot):
        t.apis[("ust_jaxrt", f"api_{i:05d}")].add(2_000 + round_i)
    t.device_apis[("ust_kernel", "kernel_0000")].add(7_000 + round_i)


def _stream_one_mode(addr: str, delta: bool, width: int, rounds: int, hot: int):
    t = make_wide_tally(width)
    s = SnapshotStreamer(addr, source=f"bench-{'delta' if delta else 'full'}", delta=delta)
    assert s.push(t)  # initial full snapshot (both modes pay this)
    deadline = time.monotonic() + 5.0
    while delta and s.peer_version is None and time.monotonic() < deadline:
        time.sleep(0.01)
        s.poll_control()  # deterministic delta engagement
    baseline = s.bytes_sent
    for r in range(rounds):
        advance(t, r, hot)
        assert s.push(t)
    steady = s.bytes_sent - baseline
    s.close()
    return steady, s.full_frames, s.delta_frames, t


def run(width: int = 2000, rounds: int = 40, hot: int = 16) -> dict:
    with MasterServer(port=0) as m:
        full_bytes, _, _, t_full = _stream_one_mode(m.addr, False, width, rounds, hot)
        delta_bytes, fulls, deltas, t_delta = _stream_one_mode(
            m.addr, True, width, rounds, hot
        )
        # correctness guard: both sources converged to identical state
        time.sleep(0.05)
        comp = m.composite()
    assert t_full.to_obj() == t_delta.to_obj()
    for src_tally in (t_full, t_delta):
        for key, st in src_tally.apis.items():
            assert comp.apis[key].calls >= st.calls
    ratio = full_bytes / max(1, delta_bytes)
    return {
        "width": width,
        "rounds": rounds,
        "hot": hot,
        "full_bytes": full_bytes,
        "delta_bytes": delta_bytes,
        "ratio": ratio,
        "delta_frames": deltas,
        "full_resync_frames": fulls,
        "bytes_per_push_full": full_bytes / rounds,
        "bytes_per_push_delta": delta_bytes / rounds,
    }


def _raise_nofile_limit(need: int) -> None:
    """Best-effort RLIMIT_NOFILE bump: 512 subscribers is >1k fds counting
    both socket ends plus the master's per-connection plumbing."""
    try:
        import resource

        soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
        if soft < need:
            resource.setrlimit(resource.RLIMIT_NOFILE, (min(need, hard), hard))
    except (ImportError, ValueError, OSError):
        pass


def _subscribe_socket(addr: str, period_s: float) -> socket.socket:
    s = socket.create_connection(parse_addr(addr), timeout=5.0)
    s.settimeout(5.0)
    s.sendall(pack_frame({"type": "hello", "v": PROTOCOL_VERSION, "source": "bench-sub"}))
    ack = recv_frame(s)
    assert ack is not None and ack["type"] == "hello_ack"
    s.sendall(
        pack_frame({"type": "subscribe", "v": PROTOCOL_VERSION, "period_s": period_s})
    )
    s.setblocking(False)
    return s


def _drain(sel, counts, total, duration_s: float) -> int:
    """Pump every readable subscriber for ``duration_s``; returns bytes.

    epoll-backed (``selectors``): 512 subscribers blow past select()'s
    FD_SETSIZE in a process that also owns the master's socket pairs."""
    drained = 0
    end = time.monotonic() + duration_s
    while time.monotonic() < end:
        for key, _ in sel.select(timeout=0.01):
            s = key.fileobj
            try:
                b = s.recv(1 << 16)
            except (BlockingIOError, InterruptedError):
                continue
            drained += len(b)
            counts[key.fd] = counts.get(key.fd, 0) + len(b)
    total[0] += drained
    return drained


def fanout_sweep(
    width: int = 300,
    updates: int = 8,
    subscribers=(1, 64, 512),
    period_s: float = 0.02,
) -> dict:
    """Live-subscriber fanout: encode count + bytes per delta per subscriber.

    For each subscriber count N the sweep attaches N real subscription
    connections, pushes ``updates`` composite updates, keeps every
    subscriber drained (no eviction noise), and reads the master's hub
    counters.  The figure of merit is ``encode_flatness`` — encodes per
    update at the widest N over the narrowest — which stays ≈1 for the
    shared hub and ≈N/1 for a per-subscriber encode loop.
    """
    _raise_nofile_limit(max(subscribers) * 2 + 512)
    per_n = {}
    with MasterServer(port=0) as m:
        t = make_wide_tally(width)
        m.submit("bench-src", t)
        step = 0
        for n in subscribers:
            socks = [_subscribe_socket(m.addr, period_s) for _ in range(n)]
            sel = selectors.DefaultSelector()
            for s in socks:
                sel.register(s, selectors.EVENT_READ)
            counts: dict = {}
            total = [0]
            # snapshot-on-join: wait until every subscriber saw its first frame
            deadline = time.monotonic() + 10.0
            while len(counts) < n and time.monotonic() < deadline:
                _drain(sel, counts, total, 0.05)
            enc0, frames0, bytes0 = m.sub_encodes, m.sub_frames, total[0]
            for _ in range(updates):
                advance(t, step, hot=8)
                step += 1
                m.submit("bench-src", t)
                _drain(sel, counts, total, max(0.1, period_s * 3))
            _drain(sel, counts, total, 0.2)  # settle: flush trailing frames
            encodes = m.sub_encodes - enc0
            frames = m.sub_frames - frames0
            drained = total[0] - bytes0
            sel.close()
            for s in socks:
                s.close()
            per_n[str(n)] = {
                "encodes": encodes,
                "encodes_per_update": encodes / updates,
                "frames_out": frames,
                "bytes_drained": drained,
                "bytes_per_update_per_sub": drained / (updates * n),
            }
            evictions = m.sub_evictions
    lo, hi = str(min(subscribers)), str(max(subscribers))
    return {
        "width": width,
        "updates": updates,
        "subscribers": per_n,
        # ≈1.0 when the hub encodes once per update regardless of fanout
        "encode_flatness": per_n[hi]["encodes"] / max(1, per_n[lo]["encodes"]),
        "bytes_per_delta_per_sub": per_n[hi]["bytes_per_update_per_sub"],
        "evictions": evictions,
    }


def main(
    width: int = 2000,
    rounds: int = 40,
    hot: int = 16,
    fanout_subs=(1, 64, 512),
    fanout_updates: int = 8,
) -> dict:
    r = run(width=width, rounds=rounds, hot=hot)
    print(
        f"  wide tally: {r['width']} host APIs, {r['hot']} hot, "
        f"{r['rounds']} steady-state pushes"
    )
    print(
        f"  full snapshots : {r['full_bytes']:>10d} B on wire "
        f"({r['bytes_per_push_full']:.0f} B/push)"
    )
    print(
        f"  delta frames   : {r['delta_bytes']:>10d} B on wire "
        f"({r['bytes_per_push_delta']:.0f} B/push)"
    )
    print(f"  reduction      : {r['ratio']:.1f}x  (target ≥ 5x)")
    fan = fanout_sweep(
        width=min(width, 300), updates=fanout_updates, subscribers=fanout_subs
    )
    r["fanout"] = fan
    print(f"  fanout sweep   : {fan['updates']} updates per subscriber count")
    for n, row in fan["subscribers"].items():
        print(
            f"    {n:>4s} subs: {row['encodes']:>3d} encodes "
            f"({row['encodes_per_update']:.1f}/update), "
            f"{row['frames_out']} frames out, "
            f"{row['bytes_per_update_per_sub']:.0f} B/update/sub"
        )
    print(
        f"  encode flatness: {fan['encode_flatness']:.2f}x "
        f"(≈1 = one encode per update regardless of fanout; "
        f"{fan['evictions']} evictions)"
    )
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--hot", type=int, default=16)
    ap.add_argument(
        "--fanout-subs",
        default="1,64,512",
        help="comma-separated subscriber counts for the hub fanout sweep",
    )
    ap.add_argument("--fanout-updates", type=int, default=8)
    ap.add_argument(
        "--json", default=None, help="write the result dict to this JSON file"
    )
    a = ap.parse_args()
    result = main(
        width=a.width,
        rounds=a.rounds,
        hot=a.hot,
        fanout_subs=tuple(int(x) for x in a.fanout_subs.split(",")),
        fanout_updates=a.fanout_updates,
    )
    if a.json:
        import json

        with open(a.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"  wrote {a.json}")

"""Wide-tally streaming bandwidth: bytes-on-wire, full vs delta (protocol v2).

The exascale failure mode the delta protocol targets: a rank tracing a very
wide API surface (thousands of tally rows) re-ships the *entire* cumulative
table every push under full-snapshot streaming, even though only the few hot
APIs changed since the last interval.  This benchmark builds such a tally,
advances only a hot subset each round, pushes through a real
``SnapshotStreamer`` → ``MasterServer`` TCP pair in both modes, and reports
steady-state bytes-on-wire (the first full frame is excluded — both modes
must pay it) plus the reduction factor.  Master-side composites are checked
for equality so the saving is never bought with wrong numbers.

    PYTHONPATH=src python -m benchmarks.stream_bw [--width 2000] [--rounds 40]
"""

from __future__ import annotations

import argparse
import time

from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import MasterServer, SnapshotStreamer


def make_wide_tally(width: int) -> Tally:
    """A cumulative tally with ``width`` host-API rows (plus device rows)."""
    t = Tally()
    t.hostnames.add("bench-node")
    t.processes.add(1)
    t.threads.add((1, 1))
    for i in range(width):
        st = ApiStat()
        st.add(1_000 + i)
        t.apis[("ust_jaxrt", f"api_{i:05d}")] = st
    for i in range(width // 10):
        st = ApiStat()
        st.add(5_000 + i)
        t.device_apis[("ust_kernel", f"kernel_{i:04d}")] = st
    return t


def advance(t: Tally, round_i: int, hot: int) -> None:
    """One interval of activity: only ``hot`` rows accumulate new calls."""
    for i in range(hot):
        t.apis[("ust_jaxrt", f"api_{i:05d}")].add(2_000 + round_i)
    t.device_apis[("ust_kernel", "kernel_0000")].add(7_000 + round_i)


def _stream_one_mode(addr: str, delta: bool, width: int, rounds: int, hot: int):
    t = make_wide_tally(width)
    s = SnapshotStreamer(addr, source=f"bench-{'delta' if delta else 'full'}", delta=delta)
    assert s.push(t)  # initial full snapshot (both modes pay this)
    deadline = time.monotonic() + 5.0
    while delta and s.peer_version is None and time.monotonic() < deadline:
        time.sleep(0.01)
        s.poll_control()  # deterministic delta engagement
    baseline = s.bytes_sent
    for r in range(rounds):
        advance(t, r, hot)
        assert s.push(t)
    steady = s.bytes_sent - baseline
    s.close()
    return steady, s.full_frames, s.delta_frames, t


def run(width: int = 2000, rounds: int = 40, hot: int = 16) -> dict:
    with MasterServer(port=0) as m:
        full_bytes, _, _, t_full = _stream_one_mode(m.addr, False, width, rounds, hot)
        delta_bytes, fulls, deltas, t_delta = _stream_one_mode(
            m.addr, True, width, rounds, hot
        )
        # correctness guard: both sources converged to identical state
        time.sleep(0.05)
        comp = m.composite()
    assert t_full.to_obj() == t_delta.to_obj()
    for src_tally in (t_full, t_delta):
        for key, st in src_tally.apis.items():
            assert comp.apis[key].calls >= st.calls
    ratio = full_bytes / max(1, delta_bytes)
    return {
        "width": width,
        "rounds": rounds,
        "hot": hot,
        "full_bytes": full_bytes,
        "delta_bytes": delta_bytes,
        "ratio": ratio,
        "delta_frames": deltas,
        "full_resync_frames": fulls,
        "bytes_per_push_full": full_bytes / rounds,
        "bytes_per_push_delta": delta_bytes / rounds,
    }


def main(width: int = 2000, rounds: int = 40, hot: int = 16) -> dict:
    r = run(width=width, rounds=rounds, hot=hot)
    print(
        f"  wide tally: {r['width']} host APIs, {r['hot']} hot, "
        f"{r['rounds']} steady-state pushes"
    )
    print(
        f"  full snapshots : {r['full_bytes']:>10d} B on wire "
        f"({r['bytes_per_push_full']:.0f} B/push)"
    )
    print(
        f"  delta frames   : {r['delta_bytes']:>10d} B on wire "
        f"({r['bytes_per_push_delta']:.0f} B/push)"
    )
    print(f"  reduction      : {r['ratio']:.1f}x  (target ≥ 5x)")
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--hot", type=int, default=16)
    ap.add_argument(
        "--json", default=None, help="write the result dict to this JSON file"
    )
    a = ap.parse_args()
    result = main(width=a.width, rounds=a.rounds, hot=a.hot)
    if a.json:
        import json

        with open(a.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"  wrote {a.json}")

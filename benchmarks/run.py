"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]
    PYTHONPATH=src python -m benchmarks.run --smoke [--json BENCH_smoke.json]

``--smoke`` is the CI configuration: the jax-light sections only
(tracepoint cost, aggregation tree, streaming bytes-on-wire) at small
sizes, with the results written as JSON so every PR leaves a
``BENCH_*.json`` artifact and the perf trajectory accumulates.  The full
run emits a ``name,us_per_call,derived`` CSV summary at the end.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def run_smoke(json_path: str) -> None:
    """CI smoke: fast sections, crash on regression-shaped breakage, JSON out."""
    import os

    from . import aggregate_scale, analysis_speed, stream_bw, tracepoint_cost

    results = {
        "mode": "smoke",
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    print("== smoke: §3.1 collection hot-path cost (legacy vs reserve/commit) ==")
    tc = tracepoint_cost.run()
    for k, v in sorted(tc.items()):
        if isinstance(v, dict):  # the per-fidelity-mode sweep
            for kk, vv in sorted(v.items()):
                print(f"  {k}.{kk:28s} {vv:12.3f}")
        else:
            print(f"  {k:30s} {v:12.1f}")
    results["tracepoint_cost"] = tc
    # standalone collection-path artifact, tracked by tools/bench_delta.py
    coll_path = os.path.join(os.path.dirname(json_path) or ".", "BENCH_collection.json")
    with open(coll_path, "w") as f:
        json.dump(
            {
                "python": platform.python_version(),
                "platform": platform.platform(),
                **tc,
            },
            f,
            indent=2,
            sort_keys=True,
        )
    print(f"wrote {coll_path}")

    print("== smoke: §3.4 analysis throughput (fold vs legacy graph) ==")
    an = analysis_speed.run(events=200_000, ranks=256)
    pa = an["parallel"]
    print(
        f"  tally fast={an['tally']['fast_events_per_s'] / 1e6:.2f}M ev/s "
        f"legacy={an['tally']['legacy_events_per_s'] / 1e6:.2f}M ev/s "
        f"speedup={an['tally']['speedup']:.1f}x | composite row-ops "
        f"{an['composite']['row_ops_ratio']:.0f}x fewer @{an['composite']['ranks']} ranks"
    )
    print(
        f"  parallel fold on {pa['cpus']} cpu(s): jobs-sweep max "
        f"{pa['speedup_max']:.2f}x | sidecar {pa['sidecar_speedup']:.1f}x"
    )
    results["analysis_speed"] = an

    print("== smoke: §3.7 aggregation tree (64 ranks) ==")
    ag = aggregate_scale.run(ranks=64, fanout=8)
    print(
        f"  ranks={ag['ranks']} fanout={ag['fanout']} depth={ag['depth']} "
        f"wall={ag['merge_wall_s'] * 1000:.1f}ms"
    )
    results["aggregate_scale"] = ag

    print("== smoke: §3.7+§6 streaming full vs delta bytes-on-wire ==")
    bw = stream_bw.run(width=200, rounds=10)
    print(
        f"  full={bw['full_bytes']}B delta={bw['delta_bytes']}B "
        f"reduction={bw['ratio']:.1f}x"
    )
    results["stream_bw"] = bw

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"wrote {json_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer steps / smaller suite")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI smoke subset (jax-light, small sizes), results as JSON",
    )
    ap.add_argument(
        "--json",
        default="BENCH_smoke.json",
        help="JSON output path for --smoke results",
    )
    args = ap.parse_args()

    if args.smoke:
        run_smoke(args.json)
        return

    from . import (
        aggregate_scale,
        analysis_speed,
        overhead,
        roofline,
        space,
        stream_bw,
        tally_table,
        tracepoint_cost,
    )
    from .workload import SUITE

    suite = SUITE[:2] if args.quick else SUITE
    steps = 8 if args.quick else 12
    csv = []

    print("== §3.1 tracepoint hot-path cost (LTTng analogue) ==")
    tc = tracepoint_cost.main()
    csv.append(("tracepoint_disabled", tc["disabled_ns"] / 1000, "ns->us per call"))
    csv.append(("tracepoint_enabled", tc["enabled_ns"] / 1000, "us per call"))
    csv.append(("tracepoint_drop", tc["drop_ns"] / 1000, "us per discarded event"))
    csv.append(("collection_pair_speedup", tc["speedup_pair"], "x vs legacy write path"))

    print("\n== Fig 7 runtime overhead per tracing mode ==")
    ov = overhead.run(steps=steps, suite=suite)
    for r in ov["rows"]:
        print(
            f"  {r['arch']:22s} base={r['baseline_s']:.2f}s "
            + " ".join(f"{l}={r[l]:+.1f}%" for l, _, _ in overhead.CONFIGS)
        )
    for label, s in ov["summary"].items():
        print(f"  {label:10s} mean={s['mean_pct']:+.2f}% median={s['median_pct']:+.2f}%")
    csv.append(
        ("overhead_T-default_median", ov["summary"]["T-default"]["median_pct"], "pct")
    )

    print("\n== Fig 8 trace space per mode ==")
    sp = space.run(steps=steps, suite=suite)
    for label, pct in sp["normalized_vs_full_pct"].items():
        print(f"  {label:10s} {pct:6.1f}% of T-full")
    csv.append(("space_default_vs_full", sp["normalized_vs_full_pct"]["T-default"], "pct"))
    csv.append(("space_min_vs_full", sp["normalized_vs_full_pct"]["T-min"], "pct"))

    print("\n== §4.3 serving tally (layered backends) ==")
    tally_table.main()

    print("\n== §3.7 512-rank aggregation tree ==")
    ag = aggregate_scale.main()
    csv.append(("aggregate_512_ranks", ag["merge_wall_s"] * 1e6, "us total"))

    print("\n== §3.4 analysis throughput: fold engine vs legacy graph ==")
    an = analysis_speed.main(events=200_000 if args.quick else 1_000_000)
    csv.append(("tally_fold_speedup", an["tally"]["speedup"], "x faster"))
    csv.append(
        ("composite_row_ops_ratio", an["composite"]["row_ops_ratio"], "x fewer ops")
    )

    print("\n== §3.7+§6 wide-tally streaming: full vs delta bytes-on-wire ==")
    bw = stream_bw.main(
        width=500 if args.quick else 2000, rounds=10 if args.quick else 40
    )
    csv.append(("stream_delta_reduction", bw["ratio"], "x fewer bytes"))

    print("\n== §Roofline table (from dry-run artifacts) ==")
    roofline.main()

    print("\nname,us_per_call,derived")
    for name, val, derived in csv:
        print(f"{name},{val:.3f},{derived}")


if __name__ == "__main__":
    main()

"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits a ``name,us_per_call,derived`` CSV summary at the end.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer steps / smaller suite")
    args = ap.parse_args()

    from . import (
        aggregate_scale,
        overhead,
        roofline,
        space,
        stream_bw,
        tally_table,
        tracepoint_cost,
    )
    from .workload import SUITE

    suite = SUITE[:2] if args.quick else SUITE
    steps = 8 if args.quick else 12
    csv = []

    print("== §3.1 tracepoint hot-path cost (LTTng analogue) ==")
    tc = tracepoint_cost.main()
    csv.append(("tracepoint_disabled", tc["disabled_ns"] / 1000, "ns->us per call"))
    csv.append(("tracepoint_enabled", tc["enabled_ns"] / 1000, "us per call"))
    csv.append(("tracepoint_drop", tc["drop_ns"] / 1000, "us per discarded event"))

    print("\n== Fig 7 runtime overhead per tracing mode ==")
    ov = overhead.run(steps=steps, suite=suite)
    for r in ov["rows"]:
        print(
            f"  {r['arch']:22s} base={r['baseline_s']:.2f}s "
            + " ".join(f"{l}={r[l]:+.1f}%" for l, _, _ in overhead.CONFIGS)
        )
    for label, s in ov["summary"].items():
        print(f"  {label:10s} mean={s['mean_pct']:+.2f}% median={s['median_pct']:+.2f}%")
    csv.append(
        ("overhead_T-default_median", ov["summary"]["T-default"]["median_pct"], "pct")
    )

    print("\n== Fig 8 trace space per mode ==")
    sp = space.run(steps=steps, suite=suite)
    for label, pct in sp["normalized_vs_full_pct"].items():
        print(f"  {label:10s} {pct:6.1f}% of T-full")
    csv.append(("space_default_vs_full", sp["normalized_vs_full_pct"]["T-default"], "pct"))
    csv.append(("space_min_vs_full", sp["normalized_vs_full_pct"]["T-min"], "pct"))

    print("\n== §4.3 serving tally (layered backends) ==")
    tally_table.main()

    print("\n== §3.7 512-rank aggregation tree ==")
    ag = aggregate_scale.main()
    csv.append(("aggregate_512_ranks", ag["merge_wall_s"] * 1e6, "us total"))

    print("\n== §3.7+§6 wide-tally streaming: full vs delta bytes-on-wire ==")
    bw = stream_bw.main(
        width=500 if args.quick else 2000, rounds=10 if args.quick else 40
    )
    csv.append(("stream_delta_reduction", bw["ratio"], "x fewer bytes"))

    print("\n== §Roofline table (from dry-run artifacts) ==")
    roofline.main()

    print("\nname,us_per_call,derived")
    for name, val, derived in csv:
        print(f"{name},{val:.3f},{derived}")


if __name__ == "__main__":
    main()

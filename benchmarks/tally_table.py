"""§4.3 table — layered-backend tally of a traced serving run.

Reproduces the HIPLZ analysis: a serve workload traced in full mode, whose
tally shows the framework layer (prefill/decode ≙ hip*) sitting on top of the
dispatch layer (dispatch/poll_ready ≙ zeEventHostSynchronize's spin lock) —
the same layering diagnosis the paper demonstrates on LRN/Aurora.
"""

from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine


def run(arch: str = "h2o-danube-1.8b", n_requests: int = 6, mode: str = "full"):
    model = Model(get_config(arch).smoke())
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(
        model, params, ServeConfig(batch_slots=2, cache_len=48, max_new_tokens=8)
    )
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        with Tracer(TraceConfig(out_dir=d, mode=mode)):
            for _ in range(n_requests):
                eng.submit(rng.integers(0, model.cfg.vocab_size, size=(12,)))
            eng.run_until_drained()
        t = tally_trace(d)
    return t


def main():
    t = run()
    print(render(t))
    print("\n-- device --")
    print(render(t, device=True))
    return t


if __name__ == "__main__":
    main()

"""Analysis throughput: single-pass fold engine vs legacy graph, sharded
parallel fold + columnar sidecar, and composite read cost at scale.

Three sections:

1. **tally_trace throughput** — a synthetic CTF-lite trace (entry/exit
   pairs + named kernel spans + discards, written through the real
   ``StreamWriter``) tallied by both paths.  Reports events/s and the
   fast-vs-legacy speedup; asserts both produce identical tallies so the
   speed is never bought with wrong numbers.
2. **parallel fold + sidecar** — the same trace folded via
   ``fold_trace(jobs=N)`` for each N in a sweep, each variant in a *fresh
   subprocess* (cold interpreter, its own pool, no shared page-cache-warm
   engine state leaking between timings), plus the ``.ctfcol`` columnar
   fast path (index once, then sidecar folds at jobs=1 and jobs=max).
   Every variant prints a canonical-tally digest; the parent asserts all
   digests agree — speedups are only reported for identical results.
   ``cpus`` is recorded alongside: on a 1-CPU box the jobs sweep measures
   pool overhead, not scaling, and the sidecar path carries the win.
3. **composite read cost** — a ``MasterServer`` holding N rank tallies,
   driven through steady-state rounds (a few ranks grow, then the
   composite is read, the `iprof top` polling pattern).  Compares ApiStat
   row-merge operations with the incremental cache vs rebuild-per-read,
   checking result equality each round.

    PYTHONPATH=src python -m benchmarks.analysis_speed [--events 1000000]
        [--parallel-events 10000000] [--jobs 1,2,4,8] [--ranks 256]
        [--json BENCH_analysis.json]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time

from repro.core.api_model import builtin_trace_model
from repro.core.clock import ClockInfo
from repro.core.ctf import StreamWriter, write_metadata
from repro.core.plugins.tally import ApiStat, Tally, tally_trace
from repro.core.ringbuffer import RingRegistry
from repro.core.stream import MasterServer
from repro.core.tracepoints import Tracepoints


def build_trace(trace_dir: str, events: int, streams: int = 2) -> int:
    """Write a ``events``-record trace through the real recorder → ring →
    StreamWriter pipeline.  One representative block of records is produced
    by the generated tracepoints, then replicated to size (entry/exit pairs
    balance within the block, so replication keeps pairing exact)."""
    model = builtin_trace_model()
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 24, pid=4242)
    tp.attach(reg, [ev.eid for ev in model.events])
    rec = tp.record
    block_events = 0
    for i in range(120):
        rec["ust_jaxrt:dispatch_entry"](f"fn_{i % 11}", 4, 1 << 12, 0)
        rec["ust_kernel:launch_span"](0, 50 + i, f"kern_{i % 7}", 8, 8, 1, 1 << 20, 1 << 16)
        rec["ust_jaxrt:dispatch_exit"](0)
        rec["ust_jaxrt:alloc_entry"](1 << 16, 0)
        rec["ust_jaxrt:alloc_exit"](0xDEAD0000 + i)
        block_events += 5
    block = reg.rings()[0].drain()
    tp.detach()
    per_stream = max(1, events // (streams * block_events))
    total = 0
    for s in range(streams):
        w = StreamWriter(
            os.path.join(trace_dir, f"stream_{4242 + s}_{7 + s}.ctf"), 4242 + s, 7 + s
        )
        for _ in range(per_stream):
            w.append(block)
            total += block_events
        w.close()
    write_metadata(
        trace_dir, model, ClockInfo.capture(), env={"hostname": "bench-node"}
    )
    return total


def _canon(t: Tally) -> dict:
    o = t.to_obj()
    o["apis"] = sorted(o["apis"])
    o["device_apis"] = sorted(o["device_apis"])
    return o


def run_tally(events: int = 1_000_000) -> dict:
    with tempfile.TemporaryDirectory() as d:
        n = build_trace(d, events)
        t0 = time.perf_counter()
        fast = tally_trace(d)
        fast_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        legacy = tally_trace(d, legacy_graph=True)
        legacy_s = time.perf_counter() - t0
    assert _canon(fast) == _canon(legacy), "fast path diverged from legacy graph"
    return {
        "events": n,
        "fast_s": fast_s,
        "legacy_s": legacy_s,
        "fast_events_per_s": n / fast_s,
        "legacy_events_per_s": n / legacy_s,
        "speedup": legacy_s / fast_s,
    }


# ---------------------------------------------------------------------------
# Parallel sharded fold + columnar sidecar (subprocess-isolated variants)
# ---------------------------------------------------------------------------


def _tally_digest(t: Tally) -> str:
    return hashlib.sha256(
        json.dumps(_canon(t), sort_keys=True).encode()
    ).hexdigest()[:16]


def _fold_variant_main(trace_dir: str, jobs: int, use_sidecar: bool) -> None:
    """Hidden subprocess entry (``--fold-dir``): time one fold variant in a
    cold interpreter and print ``{"wall_s", "digest"}`` as JSON."""
    from repro.core.fold import fold_trace

    t0 = time.perf_counter()
    t = fold_trace(trace_dir, jobs=jobs, use_sidecar=use_sidecar)
    wall = time.perf_counter() - t0
    print(json.dumps({"wall_s": wall, "digest": _tally_digest(t)}))


def _run_variant(trace_dir: str, jobs: int, use_sidecar: bool) -> dict:
    import repro

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable,
        os.path.abspath(__file__),
        "--fold-dir",
        trace_dir,
        "--fold-jobs",
        str(jobs),
    ]
    if not use_sidecar:
        cmd.append("--no-sidecar")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_parallel(
    events: int = 10_000_000, jobs: tuple = (1, 2, 4, 8), streams: int = 8
) -> dict:
    """Jobs sweep (record-parse) + sidecar fast path, one subprocess each."""
    from repro.core.ctf import build_sidecars

    jobs = tuple(sorted(set(jobs)))
    with tempfile.TemporaryDirectory() as d:
        n = build_trace(d, events, streams=streams)
        sweep = {}
        digests = set()
        for j in jobs:
            r = _run_variant(d, j, use_sidecar=False)
            sweep[j] = r["wall_s"]
            digests.add(r["digest"])
        t0 = time.perf_counter()
        n_sc = build_sidecars(d)
        index_s = time.perf_counter() - t0
        sc1 = _run_variant(d, 1, use_sidecar=True)
        scmax = _run_variant(d, max(jobs), use_sidecar=True)
        digests.add(sc1["digest"])
        digests.add(scmax["digest"])
    assert len(digests) == 1, f"fold variants diverged: {digests}"
    base = sweep[jobs[0]]
    return {
        "events": n,
        "streams": streams,
        "cpus": os.cpu_count(),
        "jobs_wall_s": {str(j): w for j, w in sweep.items()},
        "jobs_speedup": {str(j): base / w for j, w in sweep.items()},
        "speedup_max": max(base / w for w in sweep.values()),
        "index_streams": n_sc,
        "index_s": index_s,
        "sidecar_jobs1_s": sc1["wall_s"],
        "sidecar_jobsmax_s": scmax["wall_s"],
        "sidecar_speedup": base / sc1["wall_s"],
        "combined_speedup": base / scmax["wall_s"],
        "digest": digests.pop(),
    }


def _rank_tally(rank: int, width: int) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 0))
    for a in range(width):
        s = ApiStat()
        s.add(500 + 13 * a + rank)
        t.apis[("ust_jaxrt", f"api_{a:04d}")] = s
    return t


def run_composite(ranks: int = 256, width: int = 100, rounds: int = 32, hot: int = 8) -> dict:
    cached = MasterServer(port=0, composite_cache=True)  # never started: state only
    rebuild = MasterServer(port=0, composite_cache=False)
    for r in range(ranks):
        t = _rank_tally(r, width)
        cached.submit(f"r{r}", Tally().merge(t))
        rebuild.submit(f"r{r}", Tally().merge(t))
    cached.composite(), rebuild.composite()  # first build paid by both modes
    c0, b0 = cached.comp_row_ops, rebuild.comp_row_ops
    t_cached = t_rebuild = 0.0
    for i in range(rounds):
        for h in range(hot):
            src = f"r{(i * hot + h) % ranks}"
            grown = Tally().merge(cached.ranks()[src])
            grown.apis[("ust_jaxrt", "api_0000")].add(1_000 + i)
            cached.submit(src, Tally().merge(grown))
            rebuild.submit(src, Tally().merge(grown))
        t0 = time.perf_counter()
        cc = cached.composite()
        t_cached += time.perf_counter() - t0
        t0 = time.perf_counter()
        rc = rebuild.composite()
        t_rebuild += time.perf_counter() - t0
        assert _canon(cc) == _canon(rc), "cached composite diverged from rebuild"
    c_ops = cached.comp_row_ops - c0
    b_ops = rebuild.comp_row_ops - b0
    return {
        "ranks": ranks,
        "width": width,
        "rounds": rounds,
        "hot_per_round": hot,
        "cached_row_ops": c_ops,
        "rebuild_row_ops": b_ops,
        "row_ops_ratio": b_ops / max(1, c_ops),
        "cached_read_s": t_cached,
        "rebuild_read_s": t_rebuild,
        "read_speedup": t_rebuild / max(1e-9, t_cached),
    }


def run(
    events: int = 1_000_000,
    ranks: int = 256,
    parallel_events: int | None = None,
    jobs: tuple = (1, 2),
) -> dict:
    """``parallel_events=None`` scales the parallel sweep down to the tally
    section's size (the CI-smoke configuration)."""
    out = {"tally": run_tally(events), "composite": run_composite(ranks)}
    out["parallel"] = run_parallel(
        parallel_events if parallel_events is not None else events,
        jobs=jobs,
        streams=max(4, max(jobs)),
    )
    return out


def main(
    events: int = 1_000_000,
    ranks: int = 256,
    json_path: str | None = None,
    parallel_events: int | None = None,
    jobs: tuple = (1, 2, 4, 8),
) -> dict:
    out = run(events, ranks, parallel_events=parallel_events, jobs=jobs)
    ta, co, pa = out["tally"], out["composite"], out["parallel"]
    print(
        f"  tally_trace {ta['events']} events: fast={ta['fast_s']:.2f}s "
        f"({ta['fast_events_per_s'] / 1e6:.2f}M ev/s) "
        f"legacy={ta['legacy_s']:.2f}s ({ta['legacy_events_per_s'] / 1e6:.2f}M ev/s) "
        f"speedup={ta['speedup']:.1f}x"
    )
    sweep = " ".join(
        f"jobs{j}={w:.2f}s({pa['jobs_speedup'][j]:.2f}x)"
        for j, w in sorted(pa["jobs_wall_s"].items(), key=lambda kv: int(kv[0]))
    )
    print(
        f"  parallel fold {pa['events']} events x{pa['streams']} streams "
        f"on {pa['cpus']} cpu(s): {sweep} | index={pa['index_s']:.2f}s "
        f"sidecar jobs1={pa['sidecar_jobs1_s']:.2f}s "
        f"({pa['sidecar_speedup']:.1f}x) combined={pa['combined_speedup']:.1f}x"
    )
    print(
        f"  composite @{co['ranks']} ranks x{co['width']} rows, {co['rounds']} reads: "
        f"row-ops cached={co['cached_row_ops']} rebuild={co['rebuild_row_ops']} "
        f"({co['row_ops_ratio']:.0f}x fewer) read-wall {co['read_speedup']:.1f}x faster"
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=1_000_000)
    ap.add_argument("--ranks", type=int, default=256)
    ap.add_argument("--json", default=None)
    ap.add_argument(
        "--parallel-events",
        type=int,
        default=None,
        help="event count for the jobs sweep (default: --events)",
    )
    ap.add_argument("--jobs", default="1,2,4,8", help="comma-separated jobs sweep")
    # hidden subprocess mode: time one fold variant and print JSON
    ap.add_argument("--fold-dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--fold-jobs", type=int, default=1, help=argparse.SUPPRESS)
    ap.add_argument("--no-sidecar", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.fold_dir:
        _fold_variant_main(args.fold_dir, args.fold_jobs, not args.no_sidecar)
    else:
        main(
            args.events,
            args.ranks,
            args.json,
            parallel_events=args.parallel_events,
            jobs=tuple(int(j) for j in args.jobs.split(",")),
        )

"""Analysis throughput: single-pass fold engine vs legacy graph, and
composite read cost at scale (the PR-4 perf targets).

Two sections:

1. **tally_trace throughput** — a synthetic CTF-lite trace (entry/exit
   pairs + named kernel spans + discards, written through the real
   ``StreamWriter``) tallied by both paths.  Reports events/s and the
   fast-vs-legacy speedup; asserts both produce identical tallies so the
   speed is never bought with wrong numbers.
2. **composite read cost** — a ``MasterServer`` holding N rank tallies,
   driven through steady-state rounds (a few ranks grow, then the
   composite is read, the `iprof top` polling pattern).  Compares ApiStat
   row-merge operations with the incremental cache vs rebuild-per-read,
   checking result equality each round.

    PYTHONPATH=src python -m benchmarks.analysis_speed [--events 1000000]
        [--ranks 256] [--json BENCH_analysis.json]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from repro.core.api_model import builtin_trace_model
from repro.core.clock import ClockInfo
from repro.core.ctf import StreamWriter, write_metadata
from repro.core.plugins.tally import ApiStat, Tally, tally_trace
from repro.core.ringbuffer import RingRegistry
from repro.core.stream import MasterServer
from repro.core.tracepoints import Tracepoints


def build_trace(trace_dir: str, events: int, streams: int = 2) -> int:
    """Write a ``events``-record trace through the real recorder → ring →
    StreamWriter pipeline.  One representative block of records is produced
    by the generated tracepoints, then replicated to size (entry/exit pairs
    balance within the block, so replication keeps pairing exact)."""
    model = builtin_trace_model()
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 24, pid=4242)
    tp.attach(reg, [ev.eid for ev in model.events])
    rec = tp.record
    block_events = 0
    for i in range(120):
        rec["ust_jaxrt:dispatch_entry"](f"fn_{i % 11}", 4, 1 << 12, 0)
        rec["ust_kernel:launch_span"](0, 50 + i, f"kern_{i % 7}", 8, 8, 1, 1 << 20, 1 << 16)
        rec["ust_jaxrt:dispatch_exit"](0)
        rec["ust_jaxrt:alloc_entry"](1 << 16, 0)
        rec["ust_jaxrt:alloc_exit"](0xDEAD0000 + i)
        block_events += 5
    block = reg.rings()[0].drain()
    tp.detach()
    per_stream = max(1, events // (streams * block_events))
    total = 0
    for s in range(streams):
        w = StreamWriter(
            os.path.join(trace_dir, f"stream_{4242 + s}_{7 + s}.ctf"), 4242 + s, 7 + s
        )
        for _ in range(per_stream):
            w.append(block)
            total += block_events
        w.close()
    write_metadata(
        trace_dir, model, ClockInfo.capture(), env={"hostname": "bench-node"}
    )
    return total


def _canon(t: Tally) -> dict:
    o = t.to_obj()
    o["apis"] = sorted(o["apis"])
    o["device_apis"] = sorted(o["device_apis"])
    return o


def run_tally(events: int = 1_000_000) -> dict:
    with tempfile.TemporaryDirectory() as d:
        n = build_trace(d, events)
        t0 = time.perf_counter()
        fast = tally_trace(d)
        fast_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        legacy = tally_trace(d, legacy_graph=True)
        legacy_s = time.perf_counter() - t0
    assert _canon(fast) == _canon(legacy), "fast path diverged from legacy graph"
    return {
        "events": n,
        "fast_s": fast_s,
        "legacy_s": legacy_s,
        "fast_events_per_s": n / fast_s,
        "legacy_events_per_s": n / legacy_s,
        "speedup": legacy_s / fast_s,
    }


def _rank_tally(rank: int, width: int) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 0))
    for a in range(width):
        s = ApiStat()
        s.add(500 + 13 * a + rank)
        t.apis[("ust_jaxrt", f"api_{a:04d}")] = s
    return t


def run_composite(ranks: int = 256, width: int = 100, rounds: int = 32, hot: int = 8) -> dict:
    cached = MasterServer(port=0, composite_cache=True)  # never started: state only
    rebuild = MasterServer(port=0, composite_cache=False)
    for r in range(ranks):
        t = _rank_tally(r, width)
        cached.submit(f"r{r}", Tally().merge(t))
        rebuild.submit(f"r{r}", Tally().merge(t))
    cached.composite(), rebuild.composite()  # first build paid by both modes
    c0, b0 = cached.comp_row_ops, rebuild.comp_row_ops
    t_cached = t_rebuild = 0.0
    for i in range(rounds):
        for h in range(hot):
            src = f"r{(i * hot + h) % ranks}"
            grown = Tally().merge(cached.ranks()[src])
            grown.apis[("ust_jaxrt", "api_0000")].add(1_000 + i)
            cached.submit(src, Tally().merge(grown))
            rebuild.submit(src, Tally().merge(grown))
        t0 = time.perf_counter()
        cc = cached.composite()
        t_cached += time.perf_counter() - t0
        t0 = time.perf_counter()
        rc = rebuild.composite()
        t_rebuild += time.perf_counter() - t0
        assert _canon(cc) == _canon(rc), "cached composite diverged from rebuild"
    c_ops = cached.comp_row_ops - c0
    b_ops = rebuild.comp_row_ops - b0
    return {
        "ranks": ranks,
        "width": width,
        "rounds": rounds,
        "hot_per_round": hot,
        "cached_row_ops": c_ops,
        "rebuild_row_ops": b_ops,
        "row_ops_ratio": b_ops / max(1, c_ops),
        "cached_read_s": t_cached,
        "rebuild_read_s": t_rebuild,
        "read_speedup": t_rebuild / max(1e-9, t_cached),
    }


def run(events: int = 1_000_000, ranks: int = 256) -> dict:
    return {"tally": run_tally(events), "composite": run_composite(ranks)}


def main(events: int = 1_000_000, ranks: int = 256, json_path: str | None = None) -> dict:
    out = run(events, ranks)
    ta, co = out["tally"], out["composite"]
    print(
        f"  tally_trace {ta['events']} events: fast={ta['fast_s']:.2f}s "
        f"({ta['fast_events_per_s'] / 1e6:.2f}M ev/s) "
        f"legacy={ta['legacy_s']:.2f}s ({ta['legacy_events_per_s'] / 1e6:.2f}M ev/s) "
        f"speedup={ta['speedup']:.1f}x"
    )
    print(
        f"  composite @{co['ranks']} ranks x{co['width']} rows, {co['rounds']} reads: "
        f"row-ops cached={co['cached_row_ops']} rebuild={co['rebuild_row_ops']} "
        f"({co['row_ops_ratio']:.0f}x fewer) read-wall {co['read_speedup']:.1f}x faster"
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=1_000_000)
    ap.add_argument("--ranks", type=int, default=256)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(args.events, args.ranks, args.json)

"""§3.1 — tracepoint hot-path cost (LTTng's 'order of nanoseconds' claim).

Measures, per event:
  * disabled tracepoint (no session) — the always-paid cost;
  * enabled tracepoint → ring write;
  * drop path (ring full, discard mode);
  * consumer drain throughput.

LTTng's C tracepoints cost ~ns; our Python-generated recorders land in the
µs regime — the *relative* claim that disabled ≪ enabled and that drops
never block is the architecture property being validated (DESIGN.md §7).
"""

from __future__ import annotations

import time
from typing import Dict

from repro.core.api_model import builtin_trace_model
from repro.core.ringbuffer import RingRegistry
from repro.core.tracepoints import Tracepoints


def _time_per_call(fn, n: int = 50_000) -> float:
    t0 = time.perf_counter_ns()
    for _ in range(n):
        fn()
    return (time.perf_counter_ns() - t0) / n


def run() -> Dict[str, float]:
    model = builtin_trace_model()
    tp = Tracepoints(model)
    rec = tp.record["ust_jaxrt:memcpy_entry"]
    call = lambda: rec(0x1234, 0xFF00_5678, 1 << 20, 0, b"")

    out: Dict[str, float] = {}
    out["disabled_ns"] = _time_per_call(call)  # no session attached

    reg = RingRegistry(1 << 22, pid=1)
    tp.attach(reg, range(len(model.events)))
    out["enabled_ns"] = _time_per_call(call)

    # throughput + consumer drain
    n = 200_000
    t0 = time.perf_counter_ns()
    for _ in range(n):
        call()
        if reg.get().used > (1 << 21):
            reg.get().drain()
    dt = time.perf_counter_ns() - t0
    out["throughput_events_per_s"] = n / (dt / 1e9)

    # drop path: fill the ring, measure discard cost
    small = RingRegistry(1 << 10, pid=2)
    tp.attach(small, range(len(model.events)))
    while small.get().dropped == 0:
        call()
    out["drop_ns"] = _time_per_call(call)
    dropped_before = small.get().dropped
    call()
    assert small.get().dropped == dropped_before + 1  # counted, not blocked
    tp.detach()
    return out


def main():
    out = run()
    for k, v in out.items():
        print(f"  {k:28s} {v:,.0f}")
    return out


if __name__ == "__main__":
    main()

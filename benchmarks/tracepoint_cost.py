"""§3.1 — tracepoint hot-path cost (LTTng's 'order of nanoseconds' claim).

Measures, per event:
  * timing-harness overhead (calibrated out: the loop + lambda cost);
  * disabled tracepoint (no session) — the always-paid cost;
  * enabled tracepoint on the legacy bytes-write path (``ring_reserve=False``:
    per-segment ``pack`` + concatenation + ``RingBuffer.write`` copy);
  * enabled tracepoint on the zero-allocation reserve/commit path
    (``pack_into`` directly into ring storage);
  * the paper's running-example workload — a memcpy API call, i.e. an
    entry+exit *pair* — on both paths.  The reserve path frames the pair
    through one fused recorder (one reservation, one publish), which is the
    headline ``speedup_pair`` number;
  * drop path (ring full, discard mode);
  * producer throughput with a zero-copy consumer drain.

LTTng's C tracepoints cost ~ns; our Python-generated recorders land in the
µs regime — the *relative* claims (disabled ≪ enabled, drops never block,
reserve/commit ≥3x the legacy path on the pair workload) are the
architecture properties being validated.

    PYTHONPATH=src python -m benchmarks.tracepoint_cost [--json out.json]

Raw numbers include the timing loop + lambda dispatch; net numbers subtract
the calibrated ``loop_overhead_ns`` (measured with a no-op lambda through
the same harness).  Speedups compare net values.
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict

from repro.core.api_model import builtin_trace_model
from repro.core.clock import now
from repro.core.ringbuffer import RingRegistry
from repro.core.tracepoints import Tracepoints


def _time_block(fn, n: int) -> float:
    """One timing pass: ns per ``fn()`` call over ``n`` calls."""
    t0 = time.perf_counter_ns()
    for _ in range(n):
        fn()
    return (time.perf_counter_ns() - t0) / n


def _time_per_call(fn, n: int = 50_000, repeats: int = 5, prep=None) -> float:
    """min-of-repeats ns per ``fn()`` call; ``prep`` runs untimed per repeat."""
    best = float("inf")
    for _ in range(repeats):
        if prep is not None:
            prep()
        best = min(best, _time_block(fn, n))
    return best


def run() -> Dict[str, float]:
    model = builtin_trace_model()
    tp = Tracepoints(model)
    rec = tp.record["ust_jaxrt:memcpy_entry"]
    rex = tp.record["ust_jaxrt:memcpy_exit"]
    pair = tp.record_pair["ust_jaxrt:memcpy"]

    call = lambda: rec(0x1234, 0xFF00_5678, 1 << 20, 0, b"")

    def legacy_pair_call():  # the running-example API call: entry + exit
        rec(0x1234, 0xFF00_5678, 1 << 20, 0, b"")
        rex(0)

    fused_pair_call = lambda: pair(0x1234, 0xFF00_5678, 1 << 20, 0, b"", now(), 0)

    out: Dict[str, float] = {}
    # harness calibration: loop + lambda dispatch, nothing else
    nop = lambda: None
    ov = out["loop_overhead_ns"] = _time_per_call(nop)

    out["disabled_ns"] = _time_per_call(call)  # no session attached
    out["disabled_net_ns"] = out["disabled_ns"] - ov

    reg = RingRegistry(1 << 24, pid=1)
    drain = lambda: [r.drain() for r in reg.rings()]

    # Legacy vs reserve, interleaved round-robin: each round measures every
    # configuration back-to-back, so machine-wide drift (CI runner
    # throttling) hits both paths alike.  ns metrics take the min over
    # rounds; speedups take the *median of per-round ratios*, which stays
    # honest even when whole rounds land in a throttled window.
    n, rounds = 50_000, 9
    best = {k: float("inf") for k in ("ls", "lp", "rs", "rp")}
    ratios_single, ratios_pair = [], []
    eids = range(len(model.events))
    for _ in range(rounds):
        tp.attach(reg, eids, ring_reserve=False)
        drain()
        ls = _time_block(call, n)
        drain()
        lp = _time_block(legacy_pair_call, n // 2)
        tp.attach(reg, eids, ring_reserve=True)
        drain()
        rs = _time_block(call, n)
        drain()
        rp = _time_block(fused_pair_call, n // 2)
        o = _time_block(nop, n)
        ov = min(ov, o)
        best["ls"] = min(best["ls"], ls)
        best["lp"] = min(best["lp"], lp)
        best["rs"] = min(best["rs"], rs)
        best["rp"] = min(best["rp"], rp)
        ratios_single.append((ls - o) / (rs - o))
        ratios_pair.append((lp - o) / (rp - o))
    out["loop_overhead_ns"] = ov
    out["legacy_enabled_ns"] = best["ls"]
    out["legacy_enabled_net_ns"] = best["ls"] - ov
    out["legacy_pair_ns_per_event"] = best["lp"] / 2
    out["legacy_pair_net_ns_per_event"] = (best["lp"] - ov) / 2
    out["enabled_ns"] = best["rs"]
    out["enabled_net_ns"] = best["rs"] - ov
    out["pair_ns_per_event"] = best["rp"] / 2
    out["pair_net_ns_per_event"] = (best["rp"] - ov) / 2

    out["speedup_single"] = statistics.median(ratios_single)
    out["speedup_pair"] = statistics.median(ratios_pair)

    # fidelity-ladder sweep (§5.2 ladder, run-time knob): the same fused
    # pair workload per rung.  Producer-side cost only — "tally-only" pays
    # full recorder cost here (its win is downstream: no stream files), the
    # "sampled" rung's gate skips 63/64 of the record bodies, and "off"
    # falls through the enablement check like a session-less call.
    interval = 64
    mode_names = ("full", "sampled", "tally-only", "off")
    best_m = {m: float("inf") for m in mode_names}
    tp.attach(reg, eids, ring_reserve=True)
    for _ in range(7):  # interleaved rounds, same drift argument as above
        for m in mode_names:
            tp.set_fidelity(m, interval=interval)
            drain()
            best_m[m] = min(best_m[m], _time_block(fused_pair_call, n // 2) / 2)
    tp.set_fidelity("full")
    full_ns = best_m["full"]
    out["modes"] = {
        "sampling_interval": interval,
        "full_ns_per_event": full_ns,
        "sampled_ns_per_event": best_m["sampled"],
        "tally_only_ns_per_event": best_m["tally-only"],
        "off_ns_per_event": best_m["off"],
        "sampled_fraction_of_full": best_m["sampled"] / full_ns,
        "tally_only_fraction_of_full": best_m["tally-only"] / full_ns,
        "off_fraction_of_full": best_m["off"] / full_ns,
    }

    # throughput + zero-copy consumer drain (reserve path, pair workload)
    rb = reg.get()
    rb.drain()
    n = 200_000
    t0 = time.perf_counter_ns()
    for _ in range(n // 2):
        fused_pair_call()
        if rb.used > (1 << 21):
            rb.drain_view()
            rb.release()
    dt = time.perf_counter_ns() - t0
    out["throughput_events_per_s"] = n / (dt / 1e9)

    # drop path: fill the ring, measure discard cost
    small = RingRegistry(1 << 10, pid=2)
    tp.attach(small, range(len(model.events)), ring_reserve=True)
    while small.get().dropped == 0:
        call()
    out["drop_ns"] = _time_per_call(call)
    dropped_before = small.get().dropped
    call()
    assert small.get().dropped == dropped_before + 1  # counted, not blocked
    tp.detach()
    return out


def main(json_path=None):
    out = run()
    for k, v in out.items():
        if isinstance(v, dict):
            print(f"  {k}:")
            for kk, vv in v.items():
                print(f"    {kk:30s} {vv:,.3f}")
        else:
            print(f"  {k:28s} {v:,.1f}")
    print(
        f"  -> pair workload speedup (net): {out['speedup_pair']:.2f}x, "
        f"single record: {out['speedup_single']:.2f}x"
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results as JSON")
    main(ap.parse_args().json)

"""§Roofline — render the per-(arch × shape × mesh) roofline table from the
dry-run's JSON results (results/dryrun).  Run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both -o results/dryrun
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(results_dir: str = RESULTS) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def render(rows: List[dict], multi_pod: bool = False) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':18s} {'compute':>9s} {'memory':>9s} "
        f"{'coll':>9s} {'bound':>10s} {'MODEL/HLO':>9s} {'roofline%':>9s} {'HBM GiB':>8s}"
    )
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("multi_pod", False) != multi_pod:
            continue
        if not r.get("ok"):
            out.append(f"{r['arch']:22s} {r['shape']:12s} FAILED: {r.get('error', '?')[:60]}")
            continue
        rf = r["roofline"]
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:18s} "
            f"{rf['t_compute']:9.4f} {rf['t_memory']:9.4f} {rf['t_collective']:9.4f} "
            f"{rf['bottleneck']:>10s} {rf['useful_flops_ratio']:9.3f} "
            f"{100 * rf['roofline_fraction']:8.1f}% "
            f"{rf.get('peak_bytes', 0) / 2**30:8.1f}"
        )
    return "\n".join(out)


def main():
    rows = load()
    if not rows:
        print("  (no dry-run results yet — run repro.launch.dryrun first)")
        return []
    print(render(rows, multi_pod=False))
    multi = [r for r in rows if r.get("multi_pod")]
    if multi:
        print(f"\nmulti-pod compile proof: {sum(1 for r in multi if r.get('ok'))}/{len(multi)} cells OK")
    return rows


if __name__ == "__main__":
    main()

"""Serve a small model with batched requests under tracing (§4.3 analogue):
the tally shows the framework layer (prefill/decode) over the dispatch layer
(dispatch/poll_ready spin lock in full mode) — the HIPLZ layering analysis.

The session also opens a live master (``serve_port=0``): mid-run the engine
reports its own live profile (``eng.live_profile()``), and ``iprof top`` can
attach to the printed port while the server runs — the §6 streaming service
from the serving side.

    PYTHONPATH=src python examples/serve_traced.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.core.plugins.timeline import write_timeline
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine


def main():
    model = Model(get_config("stablelm-3b").smoke())
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(
        model, params, ServeConfig(batch_slots=4, cache_len=64, max_new_tokens=12)
    )
    rng = np.random.default_rng(7)
    trace_dir = tempfile.mkdtemp(prefix="thapi_serve_")

    with Tracer(TraceConfig(out_dir=trace_dir, mode="full", sample=True, serve_port=0)) as tr:
        print(f"live profile served on 127.0.0.1:{tr.server.port} (iprof top attaches)")
        for _ in range(10):
            eng.submit(rng.integers(0, model.cfg.vocab_size, size=(16,)))
        done = eng.run_until_drained()
        live = eng.live_profile(top=5)
        if live:
            print("\n-- live profile (mid-session, engine's own view) --")
            print(live)

    print(f"served {len(done)} requests "
          f"({sum(len(r.out_tokens) for r in done)} tokens)\n")
    t = tally_trace(trace_dir)
    print(render(t))
    tl = trace_dir + "/timeline.json"
    n = write_timeline(trace_dir, tl)
    print(f"\n{n} timeline events → {tl} (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()

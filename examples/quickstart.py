"""Quickstart: train a small model for a few steps UNDER THAPI TRACING, then
analyze the trace with the tally / validation plugins.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.core.plugins.validate import render as vrender, validate_trace
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def main():
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    model = Model(get_config("h2o-danube-1.8b").smoke(), mesh)
    trace_dir = tempfile.mkdtemp(prefix="thapi_quickstart_")

    with Tracer(TraceConfig(out_dir=trace_dir, mode="default", sample=True)):
        trainer = Trainer(
            model,
            ShapeSpec("quickstart", "train", 64, 4),
            Partitioner(mesh),
            TrainConfig(peak_lr=3e-3, warmup=5, total_steps=100),
            TrainerConfig(steps=20, ckpt_every=10, ckpt_dir=trace_dir + "/ckpt"),
        )
        result = trainer.run()

    print(f"trained {result['steps_run']} steps, final loss {result['final_loss']:.3f}\n")
    t = tally_trace(trace_dir)
    print(render(t))
    print("\n-- device --")
    print(render(t, device=True))
    print()
    print(vrender(validate_trace(trace_dir)))
    print(f"\ntrace at {trace_dir} — try:")
    print(f"  PYTHONPATH=src python -m repro.core.iprof timeline {trace_dir} -o /tmp/tl.json")


if __name__ == "__main__":
    main()

"""End-to-end driver (deliverable b): train a ~100M-param dense model for a
few hundred steps on the local mesh, with checkpointing, tracing and a final
tally + validation report.

    PYTHONPATH=src python examples/distributed_train.py [--steps 200]

(~100M params: 12L × d512 × ff2048 × 32k vocab ≈ 96M.)

``--live`` instead demonstrates the §3.7+§6 streaming aggregation service on
localhost: N worker processes each run a small traced workload, streaming
live tally state (protocol-v2 delta frames) to a *local master* which
forwards the per-rank breakdown to a *global master* (the full fanout tree,
live, rank identities intact).  Each worker also runs an adaptive policy
that retunes its snapshot cadence from the live ``busy_fraction`` of
``train_step`` mid-run.  The driver renders the global composite while the
ranks run — what ``iprof top`` shows — then proves the final live composite
matches the offline ``iprof combine`` of the very same run's per-rank
aggregates, API for API, and that the ``query_ranks`` per-rank sums equal
the merged composite.

With ``--live-slow-rank R`` one rank is deliberately slowed inside its
``train_step`` spans; a **cluster-scope adaptive controller**
(``StragglerRankPolicy`` over the global master's per-rank composites) runs
in the driver, flags the lagging rank from API-level evidence — which rank,
which API, how far behind the cluster median — records the flag as an
``ust_repro:advisory`` event in the driver's own trace, and feeds the
trainer-layer straggler watchdog (``StragglerWatchdog.note_api_evidence``),
the same callback a real ``Trainer`` exposes as ``straggler_callback``.

    PYTHONPATH=src python examples/distributed_train.py --live --live-slow-rank 1
"""

import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile
import time

import jax

from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.core.plugins.validate import render as vrender, validate_trace
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def config_100m():
    base = get_config("h2o-danube-1.8b")
    return dataclasses.replace(
        base,
        name="danube-100m",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32_000,
        head_dim=64,
        sliding_window=1024,
        dtype="float32",
    )


# ---------------------------------------------------------------------------
# --live: multi-process streaming aggregation demo
# ---------------------------------------------------------------------------


def live_worker(
    rank: int,
    out_dir: str,
    addr: str,
    steps: int,
    slow_s: float = 0.0,
    seconds: float = 0.0,
) -> None:
    """One traced rank: tiny jit workload, tally state streamed to ``addr``
    (v2 delta frames in steady state), final aggregate also written to disk
    (aggregate_only) so the driver can cross-check the live composite
    against ``iprof combine``.

    Each worker also runs the §6 adaptive consumer: a cadence policy watches
    the live windowed ``busy_fraction`` of ``train_step`` and retunes the
    snapshot push period mid-run — snapshots arrive fast while the rank is
    compiling/computing, slow while it idles.  Every knob turn is printed
    and recorded as an ``ust_repro:advisory`` event in the trace.

    ``slow_s`` injects extra latency *inside* every ``train_step`` span —
    the synthetic straggler the driver's cluster-scope controller must
    catch from the per-rank composites alone.  With ``seconds`` set the
    worker keeps stepping until that much wall time has passed (at least
    ``steps`` steps), so fast and slow ranks stay *concurrently* active —
    cross-rank windows only exist while ranks overlap.
    """
    import jax.numpy as jnp

    from repro.core import (
        AdaptiveController,
        StreamCadencePolicy,
        collective_span,
        traced_jit,
        train_step_span,
    )

    f = traced_jit(lambda x: (x * x).sum(), name="square_sum")
    x = jnp.arange(128.0) + rank
    ctrl = AdaptiveController(
        [
            StreamCadencePolicy(
                "ust_repro", "train_step", high=0.05, low=0.005, fast_s=0.05, slow_s=0.5
            )
        ],
        period_s=0.1,
        on_action=lambda a: print(f"[rank {rank}] {a}", flush=True),
    )
    cfg = TraceConfig(
        out_dir=out_dir,
        mode="default",
        rank=rank,
        aggregate_only=True,
        stream_to=addr,
        stream_period_s=0.1,
        adaptive=ctrl,
    )
    with Tracer(cfg) as tr:
        deadline = time.monotonic() + seconds
        s = 0
        while s < steps or (seconds > 0 and time.monotonic() < deadline):
            with train_step_span(s, 2, 64) as sp:
                sp.outs["loss"] = float(f(x))
                sp.outs["grad_norm"] = 1.0
                if slow_s > 0:
                    time.sleep(slow_s)  # the injected straggler latency
            with collective_span("all_reduce", 128, "data", 2):
                pass
            time.sleep(0.05)  # spread steps so mid-run snapshots differ
            s += 1
    st = tr.streamer
    print(
        f"[rank {rank}] streamed {st.pushed} frames "
        f"({st.delta_frames} deltas, {st.full_frames} full, {st.bytes_sent} B); "
        f"{len(ctrl.actions)} adaptive knob turns",
        flush=True,
    )


def _api_totals(t):
    """(table, provider, api) → (calls, total_ns); the acceptance currency."""
    out = {}
    for name, table in (("host", t.apis), ("device", t.device_apis)):
        for key, st in table.items():
            out[(name,) + key] = (st.calls, st.total_ns)
    return out


def run_live(args) -> int:
    from repro.core import (
        ClusterAdaptiveController,
        MasterServer,
        StragglerRankPolicy,
        StreamClient,
    )
    from repro.core.aggregate import combine_aggregates, find_aggregates, merge_tallies
    from repro.core.babeltrace import CTFSource
    from repro.core.plugins.tally import Tally, render_by_rank
    from repro.train import StragglerWatchdog

    root = tempfile.mkdtemp(prefix="thapi_live_")
    # Global master at the tree root, one local master forwarding into it —
    # the paper's rank → local master → global master chain, live.  The
    # local master forwards the per-rank breakdown (forward_ranks default),
    # so rank identities survive to the root where the cluster controller
    # reads them.
    global_m = MasterServer(port=0).start()
    local_m = MasterServer(
        port=0, forward_to=global_m.addr, forward_period_s=0.1
    ).start()
    print(f"[live] global master {global_m.addr} ← local master {local_m.addr}")
    # one authenticated-capable client, one pooled connection for every
    # driver-side read of the global master (composite + per-rank breakdown)
    gclient = StreamClient(global_m.addr)

    env = dict(os.environ)
    procs = []
    for r in range(args.live_ranks):
        out = os.path.join(root, f"r{r}")
        cmd = [
            sys.executable,
            os.path.abspath(__file__),
            "--live-worker",
            str(r),
            "--live-out",
            out,
            "--live-addr",
            local_m.addr,
            "--live-steps",
            str(args.live_steps),
        ]
        if args.live_seconds:
            cmd += ["--live-worker-seconds", str(args.live_seconds)]
        if args.live_slow_rank is not None and r == args.live_slow_rank:
            cmd += ["--live-slow", str(args.live_slow_s)]
        procs.append(subprocess.Popen(cmd, env=env))
    if args.live_slow_rank is not None:
        print(
            f"[live] rank {args.live_slow_rank} deliberately slowed by "
            f"{args.live_slow_s * 1000:.0f}ms per train_step"
        )

    # Cluster-scope adaptive control in the driver: a StragglerRankPolicy
    # polls the global master's per-rank composites over TCP (query_ranks),
    # flags ranks lagging the cluster median on train_step latency, and
    # feeds the trainer-layer watchdog — the same callback a real Trainer
    # exposes as `trainer.straggler_callback`.
    watchdog = StragglerWatchdog()
    monitor = ClusterAdaptiveController(
        [
            StragglerRankPolicy(
                "ust_repro", "train_step", ratio=1.75, metric="latency", patience=1
            )
        ],
        addr=global_m.addr,
        period_s=0.4,
        on_straggler=watchdog.note_api_evidence,
        on_action=lambda a: print(f"[cluster] {a}", flush=True),
    )

    # The driver runs its own tiny tracing session so every cluster flag is
    # also recorded as a ust_repro:advisory event — the "adaptation is
    # observable" invariant holds at cluster scope too.
    driver_dir = os.path.join(root, "driver")
    print(f"[live] {len(procs)} ranks streaming; composite while they run:")
    with Tracer(TraceConfig(out_dir=driver_dir, mode="default", online=True)) as drv:
        monitor.attach(drv)
        while any(p.poll() is None for p in procs):
            monitor.tick()
            time.sleep(0.2)
            t, meta = gclient.composite()
            if t.apis or t.device_apis:
                print(
                    f"\n[live] -- {meta['sources']} sources, "
                    f"{meta['snapshots']} snapshots --"
                )
                print(render(t, top=5))
    rc = max(p.wait() for p in procs)
    if rc != 0:
        print(f"[live] a worker failed (exit {rc})", file=sys.stderr)
        return rc

    # Final snapshots are pushed at tracer stop; wait for them to propagate
    # up the tree, then compare against the offline batch combine.
    offline = combine_aggregates(find_aggregates(root))
    want = _api_totals(offline)
    deadline = time.time() + 10.0
    live = None
    while time.time() < deadline:
        local_m.flush(force=True)
        live, _ = gclient.composite()
        if _api_totals(live) == want:
            break
        time.sleep(0.2)
    ranks, _ = gclient.ranks()
    gclient.close()
    local_m.stop()
    global_m.stop()

    lst = local_m.stats()
    print(
        f"\n[live] local master ingested {lst['snapshots']} state updates "
        f"({lst['deltas']} deltas, {lst['full_snapshots']} full snapshots, "
        f"{lst['resyncs']} resyncs)"
    )
    print("\n[live] final composite (streaming, via global master):")
    print(render(live))
    print("\n[live] per-rank breakdown at the global master (iprof top --by-rank):")
    print(render_by_rank(ranks))
    print("\n[live] offline combine of the same run's rank aggregates:")
    print(render(offline))

    ok = True
    if _api_totals(live) == want:
        print(
            f"\n[live] OK: live composite matches offline combine "
            f"({len(want)} API rows, {args.live_ranks} ranks)"
        )
    else:
        print("\n[live] MISMATCH between live composite and offline combine", file=sys.stderr)
        ok = False

    # per-rank sums must reproduce the merged composite, API for API
    rank_merge, _ = merge_tallies([Tally().merge(t) for t in ranks.values()])
    if _api_totals(rank_merge) == _api_totals(live):
        print(
            f"[live] OK: query_ranks per-rank sums equal the merged composite "
            f"({len(ranks)} ranks)"
        )
    else:
        print("[live] MISMATCH between per-rank sums and composite", file=sys.stderr)
        ok = False

    if args.live_slow_rank is not None:
        reports = watchdog.api_reports()
        advisories = [
            ev for ev in CTFSource(driver_dir) if ev.name == "ust_repro:advisory"
        ]
        wanted = f"rank{args.live_slow_rank}"
        hit = [r for r in reports if r.source.endswith(wanted)]
        if hit and advisories:
            r = hit[0]
            print(
                f"[live] OK: straggler {r.source} flagged on {r.provider}:{r.api} "
                f"at {r.ratio:.1f}x the cluster median; trainer watchdog got "
                f"{len(reports)} report(s), {len(advisories)} advisory event(s) "
                f"in the driver trace"
            )
        else:
            print(
                f"[live] FAIL: slow rank {wanted} not flagged "
                f"(reports={len(reports)}, advisories={len(advisories)})",
                file=sys.stderr,
            )
            ok = False
    return 0 if ok else 1


# ---------------------------------------------------------------------------
# --chaos: closed-loop remediation demo
#   fault injection → cluster flags → escalation ladder → checkpoint-and-drain
#   → evict + re-mesh → survivors finish the evicted rank's work
# ---------------------------------------------------------------------------


def chaos_worker(
    rank, out_dir, addr, quota, ctl_dir, fault, seed, incarnation=0, src=None, ckpt=None
):
    """One chaos rank: traced step loop with a deterministic FaultInjector,
    periodic (async) checkpoints of its progress, and a control-file channel
    the driver's remediation hooks use to escalate / drain it.

    Commands (one per line, appended to ``ctl/rank<r>.cmd``):
      * ``escalate``  — climb the fidelity ladder (sampled → full);
      * ``drain``     — commit a durable checkpoint, ack, exit cleanly;
      * ``extra:N``   — the re-mesh dealt this rank N orphaned steps; a
                        *negative* N is the splice clawing re-dealt work
                        back for a replacement — the worker returns only
                        what it has not already finished (clamped at
                        ``done``) and acks ``clawed:<returned>:<target>``;
      * ``finish``    — run is over, exit.

    A rank that reaches its quota idles on cheap heartbeat steps (so
    cross-rank windows keep existing — a straggler only lags relative to
    *active* peers) until the driver says ``finish`` or deals it more work.

    With ``incarnation > 0`` this worker is an elastic *replacement*: it
    resumes ``done`` from the newest checkpoint in ``ckpt`` (its dead
    predecessor's drain point) and streams under the predecessor's source
    id ``src`` with the new incarnation — the master atomically swaps the
    per-source state on its first frame and fences the dead incarnation.
    """
    import json

    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import Checkpointer, latest_checkpoint
    from repro.core import traced_jit, train_step_span
    from repro.core.faults import FaultInjector, parse_fault_specs

    base_step_s = 0.04
    inj = FaultInjector(parse_fault_specs(fault) if fault else [], rank=rank, seed=seed)
    ck = Checkpointer(ckpt or os.path.join(out_dir, "ckpt"), keep=2)
    cmd_path = os.path.join(ctl_dir, f"rank{rank}.cmd")
    ack_path = os.path.join(ctl_dir, f"rank{rank}.ack")

    def ack(line):
        with open(ack_path, "a") as fh:
            fh.write(line + "\n")

    f = traced_jit(lambda x: (x * x).sum(), name="square_sum")
    x = jnp.arange(64.0) + rank
    done, target, cmds_seen, idle_acked = 0, quota, 0, -1
    if incarnation:
        path = latest_checkpoint(ck.root)
        if path is not None:
            with open(os.path.join(path, "manifest.json")) as fh:
                done = int(json.load(fh)["extra"]["steps_done"])
        ack(f"restored:{done}:{incarnation}")
    cfg = TraceConfig(
        out_dir=out_dir,
        mode="default",
        fidelity="sampled",  # headroom for the escalate rung (sampled → full)
        sampling_interval=2,  # short run: keep the straggler visible when sampled
        rank=rank,
        aggregate_only=True,
        stream_to=addr,
        stream_period_s=0.1,
        stream_source=src,
        stream_incarnation=incarnation,
    )
    with Tracer(cfg) as tr:
        while True:
            try:
                with open(cmd_path) as fh:
                    lines = [ln.strip() for ln in fh if ln.strip()]
            except OSError:
                lines = []
            finish = False
            for ln in lines[cmds_seen:]:
                cmds_seen += 1
                if ln == "escalate":
                    prev = tr.set_mode("full")
                    ack(f"escalated:{prev}->full")
                elif ln == "drain":
                    ck.wait()
                    ck.save(done, {"w": np.float32(done)}, extra={"steps_done": done})
                    ack(f"drained:{done}")
                    return  # quiesced: Tracer exit flushes the final aggregate
                elif ln.startswith("extra:"):
                    delta = int(ln.split(":", 1)[1])
                    if delta < 0:
                        # splice claw-back: finished work is never returned
                        old = target
                        target = max(done, target + delta)
                        ack(f"clawed:{old - target}:{target}")
                    else:
                        target += delta
                        ack(f"extra:{target}")
                elif ln == "finish":
                    finish = True
            if finish:
                break
            if done >= target:
                if idle_acked != target:
                    idle_acked = target
                    ack(f"idle:{done}")
                # heartbeat step: keeps this rank in the cross-rank window
                # without advancing its work counter
                with train_step_span(done, 1, 16) as sp:
                    sp.outs["loss"] = 0.0
                    sp.outs["grad_norm"] = 0.0
                time.sleep(base_step_s)
                continue
            with train_step_span(done, 1, 16) as sp:
                sp.outs["loss"] = float(f(x))
                sp.outs["grad_norm"] = 1.0
                time.sleep(inj.sleep_s(done, base_step_s))  # SLOWDOWN fault
            if inj.should_hang(done):
                ack(f"hung:{done}")
                time.sleep(600)  # HANG fault: stuck until evicted
            if inj.should_die(done):
                os._exit(17)  # KILL fault: no cleanup, no final aggregate
            done += 1
            if done % 5 == 0:
                ck.save_async(done, {"w": np.float32(done)}, extra={"steps_done": done})
            time.sleep(base_step_s)
        ck.wait()
        ck.save(done, {"w": np.float32(done)}, extra={"steps_done": done})
        ack(f"done:{done}")
    print(f"[rank {rank}] finished {done} steps", flush=True)


def run_chaos(args) -> int:
    import json
    import re

    from repro.checkpoint import latest_checkpoint
    from repro.core import (
        RUNG_DRAIN,
        RUNG_ESCALATE,
        RUNG_EVICT,
        RUNG_REPLACE,
        ClusterAdaptiveController,
        MasterServer,
        RemediationEngine,
        RemediationHooks,
        SickHostPolicy,
        StragglerRankPolicy,
    )
    from repro.core.aggregate import combine_aggregates, find_aggregates
    from repro.core.babeltrace import CTFSource
    from repro.core.plugins.tally import ApiStat, Tally
    from repro.core.stream import SnapshotStreamer
    from repro.launch.elastic import ReplacementManager, WorkerSupervisor
    from repro.launch.mesh import plan_eviction

    nranks, quota = args.chaos_ranks, args.chaos_steps
    root = tempfile.mkdtemp(prefix="thapi_chaos_")
    ctl = os.path.join(root, "ctl")
    os.makedirs(ctl)
    master = MasterServer(port=0).start()
    print(
        f"[chaos] master {master.addr}; {nranks} ranks × {quota} steps; "
        f"fault={args.inject_fault or 'none'}"
        + (" (dry-run: advisory only)" if args.chaos_dry_run else "")
        + (" (elastic: replace instead of evict)" if args.chaos_replace else "")
    )

    procs = {}
    for r in range(nranks):
        open(os.path.join(ctl, f"rank{r}.cmd"), "w").close()
        cmd = [
            sys.executable,
            os.path.abspath(__file__),
            "--chaos-worker", str(r),
            "--chaos-out", os.path.join(root, f"r{r}"),
            "--chaos-addr", master.addr,
            "--chaos-ctl", ctl,
            "--chaos-quota", str(quota),
            "--chaos-seed", str(args.chaos_seed),
        ]
        if args.inject_fault:
            cmd += ["--chaos-fault", args.inject_fault]
        procs[r] = subprocess.Popen(cmd, env=dict(os.environ))

    def _rank_of(source):
        m = re.search(r"rank(\d+)$", source)
        return int(m.group(1)) if m else -1

    def _send(r, line):
        with open(os.path.join(ctl, f"rank{r}.cmd"), "a") as fh:
            fh.write(line + "\n")

    def _acks(r):
        try:
            with open(os.path.join(ctl, f"rank{r}.ack")) as fh:
                return [ln.strip() for ln in fh if ln.strip()]
        except OSError:
            return []

    def _wait_ack(r, prefix, timeout=10.0):
        deadline = time.time() + timeout
        while time.time() < deadline:
            for ln in _acks(r):
                if ln.startswith(prefix):
                    return ln
            if procs[r].poll() is not None:
                return None
            time.sleep(0.05)
        return None

    # -- remediation hooks: the ladder's rungs, driver-side -----------------------
    drained_steps = {}
    evicted = []
    replaced = set()
    extras = {r: 0 for r in range(nranks)}
    dealt_record = {}  # rank → deal_shares at its eviction/replacement
    out_dirs = {r: os.path.join(root, f"r{r}") for r in range(nranks)}

    def hk_escalate(target, detail):
        _send(_rank_of(target), "escalate")
        return True  # advisory write; the worker applies it at a step boundary

    def hk_drain(target, detail):
        r = _rank_of(target)
        if procs[r].poll() is None and r not in hung:
            _send(r, "drain")
            ln = _wait_ack(r, "drained:")
            if ln is not None:
                drained_steps[r] = int(ln.split(":")[1])
                return True
        # dead / unresponsive rank: "drain" means recovering its last durable
        # checkpoint — that is the state the survivors resume from
        path = latest_checkpoint(os.path.join(root, f"r{r}", "ckpt"))
        if path is None:
            drained_steps[r] = 0
            return True
        with open(os.path.join(path, "manifest.json")) as fh:
            drained_steps[r] = int(json.load(fh)["extra"]["steps_done"])
        return True

    def hk_evict(target, detail):
        r = _rank_of(target)
        if procs[r].poll() is None:
            procs[r].terminate()
            try:
                procs[r].wait(timeout=10)
            except subprocess.TimeoutExpired:
                procs[r].kill()
                procs[r].wait()
        evicted.append(r)
        plan = plan_eviction(nranks, evicted)
        if r in dealt_record:
            # replace rung already dealt this rank's remainder before its
            # spawn chain failed; evicting must not deal it twice
            print(f"[chaos] re-mesh: survivors {plan.survivors} (work already "
                  f"dealt by the failed replace: {dealt_record[r]})")
            return True
        remaining = quota - drained_steps.get(r, 0)
        shares = plan.reassign({r: remaining})
        for s, extra in shares.items():
            if extra:
                extras[s] += extra
                _send(s, f"extra:{extra}")
        print(
            f"[chaos] re-mesh: survivors {plan.survivors}, dense ranks "
            f"{plan.dense_rank}; {remaining} orphaned steps dealt {dict(shares)}"
        )
        return True

    # -- elastic replacement (the ``replace`` rung, --chaos-replace) --------------
    def _spawn_replacement(r, inc):
        """Launch incarnation ``inc`` of rank ``r``: fresh trace dir, the
        predecessor's checkpoint root and source id, the drained step count
        as its base quota (the splice claw-back arrives as ``extra:`` later)."""
        out = os.path.join(root, f"r{r}.i{inc}")
        cmd = [
            sys.executable,
            os.path.abspath(__file__),
            "--chaos-worker", str(r),
            "--chaos-out", out,
            "--chaos-addr", master.addr,
            "--chaos-ctl", ctl,
            "--chaos-quota", str(drained_steps.get(r, 0)),
            "--chaos-seed", str(args.chaos_seed),
            "--chaos-incarnation", str(inc),
            "--chaos-src", rank_source[r],
            "--chaos-ckpt", os.path.join(root, f"r{r}", "ckpt"),
        ]
        p = subprocess.Popen(cmd, env=dict(os.environ))
        procs[r] = p
        out_dirs[r] = out
        return p

    supervisor = WorkerSupervisor(_spawn_replacement)
    for r, p in procs.items():
        supervisor.register(r, p, incarnation=0)
    manager = ReplacementManager(
        supervisor,
        ckpt_root_for=lambda r: os.path.join(root, f"r{r}", "ckpt"),
        # admitted = the master has ingested a frame from the new incarnation
        # (the atomic per-source swap has happened; the fence is live)
        ready=lambda r, inc: master.incarnation_of(rank_source.get(r, "")) >= inc,
        ready_timeout_s=30.0,
        spawn_retries=1,
        on_event=lambda a, t, d, ok: engine.note(a, t, d, ok),
    )

    def hk_replace(target, detail):
        r = _rank_of(target)
        if rank_source.get(r) is None or r not in drained_steps:
            return False  # source id / drain point not known yet; ladder retries
        d = drained_steps[r]
        plan = plan_eviction(nranks, [r])
        if r not in dealt_record:
            # deal the dead rank's remainder out NOW so survivors keep
            # working while the replacement spawns; the splice claws the
            # un-done part back
            dealt_record[r] = plan.deal_shares(r, quota - d)
            for s, n in dealt_record[r].items():
                extras[s] += n
                _send(s, f"extra:{n}")
            print(
                f"[chaos] replace: {quota - d} orphaned steps dealt "
                f"{dealt_record[r]} while the replacement spawns", flush=True
            )
        res = manager.replace(r, plan, dealt_record[r], reason=detail, target=target)
        if not res.ok:
            return False  # engine retries, then falls through to evict
        returned = 0
        for s, g in res.giveback.items():
            _send(s, f"extra:-{g}")
        for s, g in res.giveback.items():
            ln = _wait_ack(s, "clawed:")
            got = int(ln.split(":")[1]) if ln else 0
            extras[s] -= got
            returned += got
        extras[r] = (d + returned) - quota
        if returned:
            _send(r, f"extra:{returned}")
        replaced.add(r)
        print(
            f"[chaos] replacement rank {r} incarnation {res.incarnation} admitted "
            f"at step {d}; survivors returned {returned} un-done steps; "
            f"mesh back to {len(res.plan.survivors)}/{nranks} ranks", flush=True
        )
        return True

    actions = []
    engine = RemediationEngine(
        RemediationHooks(
            escalate=hk_escalate,
            drain=hk_drain,
            replace=hk_replace if args.chaos_replace else None,
            evict=hk_evict,
        ),
        cooldown_s=0.4,
        escalate_after=2,
        healthy_windows=4,
        dry_run=args.chaos_dry_run,
        max_evictions=1,
        max_replacements=1,
        replace_retries=2,
        on_action=lambda a: (actions.append(a), print(f"[chaos] {a}", flush=True)),
    )
    straggler = StragglerRankPolicy(
        "ust_repro", "train_step", ratio=2.5, metric="latency", patience=1
    )
    sick = SickHostPolicy(patience=2)
    monitor = ClusterAdaptiveController(
        [straggler, sick],
        master=master,
        period_s=0.3,
        on_flag=engine.ingest_flag,
        on_healthy=engine.observe_healthy,
    )

    rank_source = {}  # rank id → stream source id, learned from the master
    hung = set()
    ok = True
    fault_kind = (args.inject_fault or "").split(":", 1)[0]

    driver_dir = os.path.join(root, "driver")
    with Tracer(TraceConfig(out_dir=driver_dir, mode="default", online=True)) as drv:
        engine.attach(drv)
        monitor.attach(drv)
        deadline = time.time() + args.chaos_timeout
        while time.time() < deadline:
            monitor.tick()
            for src in list(master.ranks(copy=False)):
                rank_source.setdefault(_rank_of(src), src)
            for r in range(nranks):
                for ln in _acks(r):
                    if ln.startswith("hung:"):
                        hung.add(r)
            # Policies flag once, on the excursion's edge; the ladder wants
            # the flag re-asserted every tick while the condition holds —
            # bridge level → edge here.  Dead and drained-but-not-evicted
            # ranks are driver-level evidence the policies can't see.
            for src, ratio in straggler.flagged.items():
                engine.ingest_flag(src, "straggler", f"{ratio:.2f}x median latency")
            for src, ev in sick.flagged.items():
                engine.ingest_flag(src, "sick-host", ev)
            for r, p in procs.items():
                src = rank_source.get(r, f"rank{r}")
                if r not in evicted and p.poll() not in (None, 0):
                    engine.ingest_flag(src, "dead", f"exit {p.poll()}")
                if r in hung and r not in evicted:
                    engine.ingest_flag(src, "hung", "no step progress")
                if (
                    r in drained_steps
                    and r not in evicted
                    and r not in replaced
                    and not args.chaos_dry_run
                ):
                    engine.ingest_flag(src, "drained", "awaiting eviction")
            engine.tick()
            # done when every non-evicted rank is idle at its (possibly
            # re-meshed) target and the injected fault has been dealt with
            settled = True
            for r in range(nranks):
                if r in evicted:
                    continue
                if procs[r].poll() not in (None, 0):
                    # dead but not evicted: unresolved — except in dry-run,
                    # where the ladder only advises and never evicts
                    if not args.chaos_dry_run:
                        settled = False
                    continue
                if r in hung:
                    continue  # can't make progress; eviction is the exit
                want = quota + extras[r]
                idle = [ln for ln in _acks(r) if ln.startswith("idle:")]
                if not (idle and int(idle[-1].split(":")[1]) >= want):
                    settled = False
            if args.chaos_replace:
                # replace mode settles on a successful splice, not an eviction
                if not replaced:
                    settled = False
            elif fault_kind and not args.chaos_dry_run and not evicted:
                settled = False
            if fault_kind and args.chaos_dry_run and not any(
                a.action == RUNG_EVICT and a.dry_run for a in actions
            ):
                settled = False
            if settled:
                break
            time.sleep(0.1)
        else:
            print("[chaos] TIMEOUT waiting for the run to settle", file=sys.stderr)
            ok = False
        for r in range(nranks):
            if r not in evicted and procs[r].poll() is None:
                if r in hung:
                    procs[r].terminate()  # never reads the control file again
                else:
                    _send(r, "finish")
        for r, p in procs.items():
            if r not in evicted:
                try:
                    p.wait(timeout=30)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait()
                    ok = False
                    print(f"[chaos] FAIL: rank {r} did not exit on finish",
                          file=sys.stderr)

    # -- verification -------------------------------------------------------------
    # (1) work conservation: survivors' completed steps + the evicted rank's
    # drained progress account for every planned step, re-mesh included
    completed = {}
    for r in range(nranks):
        if r in evicted:
            completed[r] = drained_steps.get(r, 0)
        else:
            done = [ln for ln in _acks(r) if ln.startswith("done:")]
            completed[r] = int(done[-1].split(":")[1]) if done else 0
    total, planned = sum(completed.values()), nranks * quota
    if args.chaos_dry_run and fault_kind in ("kill", "hang"):
        # advisory-only mode never recovers a dead rank's work — by design
        print(f"[chaos] dry-run with {fault_kind}: {total}/{planned} steps "
              f"(lost work is the point: nothing was remediated)")
    elif total == planned:
        print(f"[chaos] OK: {total} steps completed = {nranks} ranks × {quota} planned")
    else:
        print(f"[chaos] FAIL: {total} steps completed != {planned} planned "
              f"(per-rank {completed})", file=sys.stderr)
        ok = False

    # (2) live per-rank state matches the offline fold of the same ranks'
    # aggregates (a killed rank never flushes one — noted and skipped);
    # final frames flush at worker exit, so give them a moment to land
    for r in range(nranks):
        aggs = find_aggregates(out_dirs[r])
        src = rank_source.get(r)
        if not aggs:
            print(f"[chaos] rank {r}: no offline aggregate (died mid-run), skipped")
            continue
        if src is None:
            print(f"[chaos] FAIL: rank {r} has an aggregate but no live state",
                  file=sys.stderr)
            ok = False
            continue
        want = _api_totals(combine_aggregates(aggs))
        deadline = time.time() + 5.0
        match = False
        while time.time() < deadline and not match:
            live = master.ranks().get(src)
            match = live is not None and _api_totals(live) == want
            if not match:
                time.sleep(0.1)
        if match:
            print(f"[chaos] OK: rank {r} live state == offline aggregate")
        else:
            print(f"[chaos] FAIL: rank {r} live state != offline aggregate",
                  file=sys.stderr)
            ok = False

    # (2b) elastic fencing: final healthy rank count, then a zombie frame —
    # the dead incarnation speaking up late with a poison row under the old
    # incarnation number — which the master must fence (fence_rejects > 0)
    # and whose row must never reach the composite
    if args.chaos_replace:
        healthy = sum(
            1 for r, p in procs.items() if r not in evicted and p.poll() == 0
        )
        if healthy == nranks and replaced and not evicted:
            print(f"[chaos] OK: {healthy}/{nranks} ranks healthy at exit "
                  f"(rank {sorted(replaced)[0]} replaced in place, no eviction)")
        else:
            print(f"[chaos] FAIL: {healthy}/{nranks} healthy ranks "
                  f"(replaced={sorted(replaced)}, evicted={evicted})",
                  file=sys.stderr)
            ok = False
        poison = Tally()
        poison.apis[("ust_zombie", "poison")] = ApiStat(
            calls=1, total_ns=10**12, min_ns=10**12, max_ns=10**12
        )
        fenced = 0
        deadline = time.time() + 10.0
        while time.time() < deadline and replaced:
            src = rank_source[sorted(replaced)[0]]
            z = SnapshotStreamer(master.addr, source=src, delta=False)
            try:
                z.push(poison)  # hello carries incarnation 0 < live: fenced
            except Exception:
                pass
            finally:
                z.close()
            fenced = master.stats()["fence_rejects"]
            if fenced:
                break
            time.sleep(0.2)
        poisoned = any(
            ("ust_zombie", "poison") in t.apis for t in master.ranks().values()
        ) or ("ust_zombie", "poison") in master.composite().apis
        if fenced > 0 and not poisoned:
            print(f"[chaos] OK: zombie fenced (fence_rejects={fenced}), "
                  "poison row absent from the composite")
        else:
            print(f"[chaos] FAIL: fence_rejects={fenced}, poisoned={poisoned}",
                  file=sys.stderr)
            ok = False

    # (3) every remediation decision is a trace event, and the ladder held
    # its invariants (drain strictly before evict, dry-run touches nothing)
    trace_events = [
        ev for ev in CTFSource(driver_dir) if ev.name == "ust_repro:remediation"
    ]
    if len(trace_events) == len(actions) and (not fault_kind or actions):
        print(f"[chaos] OK: {len(actions)} remediation decisions, every one traced")
    else:
        print(f"[chaos] FAIL: {len(actions)} decisions but {len(trace_events)} "
              f"trace events", file=sys.stderr)
        ok = False
    if fault_kind:
        names = [a.action for a in actions]
        if args.chaos_dry_run:
            if all(a.dry_run for a in actions) and not evicted and all(
                not _acks(r) or not any(ln.startswith(("escalated", "drained"))
                                        for ln in _acks(r))
                for r in range(nranks)
            ):
                print("[chaos] OK: dry-run — full ladder advised, nothing touched")
            else:
                print("[chaos] FAIL: dry-run had side effects", file=sys.stderr)
                ok = False
        elif args.chaos_replace:
            want_rungs = [RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE]
            if (
                all(w in names for w in want_rungs)
                and names.index(RUNG_DRAIN) < names.index(RUNG_REPLACE)
                and RUNG_EVICT not in names
                and "replace_admit" in names
            ):
                print("[chaos] OK: ladder walked "
                      f"{' → '.join(w for w in want_rungs)} "
                      "(drain before replace, no eviction)")
            else:
                print(f"[chaos] FAIL: ladder order wrong: {names}", file=sys.stderr)
                ok = False
            if engine.replacements == 1 and manager.admitted == 1:
                print(f"[chaos] OK: 1 replacement admitted "
                      f"({manager.spawned} spawn attempt(s)), work clawed back")
            else:
                print(f"[chaos] FAIL: replacements={engine.replacements}, "
                      f"admitted={manager.admitted}", file=sys.stderr)
                ok = False
        else:
            want_rungs = [RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT]
            if all(w in names for w in want_rungs) and (
                names.index(RUNG_DRAIN) < names.index(RUNG_EVICT)
            ):
                print("[chaos] OK: ladder walked "
                      f"{' → '.join(w for w in want_rungs)} (drain before evict)")
            else:
                print(f"[chaos] FAIL: ladder order wrong: {names}", file=sys.stderr)
                ok = False
            if len(evicted) == 1 and engine.evicted:
                print(f"[chaos] OK: rank {evicted[0]} evicted, "
                      f"{quota - drained_steps.get(evicted[0], 0)} steps re-dealt")
            else:
                print(f"[chaos] FAIL: eviction did not happen: {evicted}",
                      file=sys.stderr)
                ok = False
    master.stop()
    print("\n[chaos] remediation log:")
    print(engine.render_log() or "  (no actions)")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--live", action="store_true", help="streaming aggregation demo")
    ap.add_argument("--live-ranks", type=int, default=2)
    ap.add_argument("--live-steps", type=int, default=20)
    ap.add_argument(
        "--live-slow-rank",
        type=int,
        default=None,
        help="slow this rank inside train_step; the cluster controller must flag it",
    )
    ap.add_argument(
        "--live-slow-s",
        type=float,
        default=0.25,
        help="injected per-step latency for --live-slow-rank (seconds)",
    )
    ap.add_argument(
        "--live-seconds",
        type=float,
        default=None,
        help="run every rank for this much wall time (keeps fast and slow "
        "ranks concurrently active; defaults to 6s in slow-rank mode)",
    )
    ap.add_argument("--live-worker", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-addr", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-slow", type=float, default=0.0, help=argparse.SUPPRESS)
    ap.add_argument(
        "--live-worker-seconds", type=float, default=0.0, help=argparse.SUPPRESS
    )
    ap.add_argument(
        "--chaos",
        action="store_true",
        help="closed-loop remediation demo: fault injection → escalation "
        "ladder → checkpoint-and-drain → evict + re-mesh",
    )
    ap.add_argument(
        "--inject-fault",
        default=None,
        help="fault spec(s) for --chaos, e.g. 'slowdown:rank=1,after=5,factor=8' "
        "or 'kill:rank=1,after=8' (';'-separated for several)",
    )
    ap.add_argument("--chaos-ranks", type=int, default=3)
    ap.add_argument("--chaos-steps", type=int, default=25)
    ap.add_argument(
        "--chaos-dry-run",
        action="store_true",
        help="remediation engine advises only: every decision is traced, no "
        "hook runs, nothing is drained or evicted",
    )
    ap.add_argument(
        "--chaos-replace",
        action="store_true",
        help="elastic mode: the remediation ladder replaces the failed rank "
        "(spawn + restore + splice) instead of shrinking the mesh; pair "
        "with a kill fault, e.g. --inject-fault 'kill:rank=1,after=8'",
    )
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-timeout", type=float, default=120.0)
    ap.add_argument("--chaos-worker", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-addr", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-ctl", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-quota", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-fault", default=None, help=argparse.SUPPRESS)
    ap.add_argument(
        "--chaos-incarnation", type=int, default=0, help=argparse.SUPPRESS
    )
    ap.add_argument("--chaos-src", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--chaos-ckpt", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.chaos_worker is not None:
        chaos_worker(
            args.chaos_worker,
            args.chaos_out,
            args.chaos_addr,
            args.chaos_quota,
            args.chaos_ctl,
            args.chaos_fault,
            args.chaos_seed,
            incarnation=args.chaos_incarnation,
            src=args.chaos_src,
            ckpt=args.chaos_ckpt,
        )
        return
    if args.chaos:
        sys.exit(run_chaos(args))
    if args.live_worker is not None:
        live_worker(
            args.live_worker,
            args.live_out,
            args.live_addr,
            args.live_steps,
            slow_s=args.live_slow,
            seconds=args.live_worker_seconds,
        )
        return
    if args.live and args.live_slow_rank is not None and args.live_seconds is None:
        # straggler detection needs cross-rank windows: ranks must overlap
        args.live_seconds = 6.0
    if args.live:
        sys.exit(run_live(args))

    cfg = config_100m()
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    model = Model(cfg, mesh)
    print(f"{cfg.name}: {cfg.num_params() / 1e6:.0f}M params on {mesh.shape}")

    work = tempfile.mkdtemp(prefix="thapi_e2e_")
    with Tracer(TraceConfig(out_dir=work, mode="default", sample=True)):
        trainer = Trainer(
            model,
            ShapeSpec("e2e", "train", args.seq, args.batch),
            Partitioner(mesh),
            TrainConfig(peak_lr=3e-4, warmup=20, total_steps=args.steps),
            TrainerConfig(
                steps=args.steps, ckpt_every=50, ckpt_dir=work + "/ckpt", log_every=20
            ),
        )
        res = trainer.run()

    h = res["history"]
    print(f"\nloss: {h[0]['loss']:.3f} → {h[-1]['loss']:.3f} over {res['steps_run']} steps")
    print(f"stragglers flagged: {res['straggler_steps']}, failures: {res['failures']}\n")
    print(render(tally_trace(work), top=10))
    print()
    print(vrender(validate_trace(work)))


if __name__ == "__main__":
    main()

"""End-to-end driver (deliverable b): train a ~100M-param dense model for a
few hundred steps on the local mesh, with checkpointing, tracing and a final
tally + validation report.

    PYTHONPATH=src python examples/distributed_train.py [--steps 200]

(~100M params: 12L × d512 × ff2048 × 32k vocab ≈ 96M.)
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.core.plugins.validate import render as vrender, validate_trace
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def config_100m():
    base = get_config("h2o-danube-1.8b")
    return dataclasses.replace(
        base,
        name="danube-100m",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32_000,
        head_dim=64,
        sliding_window=1024,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = config_100m()
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    model = Model(cfg, mesh)
    print(f"{cfg.name}: {cfg.num_params() / 1e6:.0f}M params on {mesh.shape}")

    work = tempfile.mkdtemp(prefix="thapi_e2e_")
    with Tracer(TraceConfig(out_dir=work, mode="default", sample=True)):
        trainer = Trainer(
            model,
            ShapeSpec("e2e", "train", args.seq, args.batch),
            Partitioner(mesh),
            TrainConfig(peak_lr=3e-4, warmup=20, total_steps=args.steps),
            TrainerConfig(
                steps=args.steps, ckpt_every=50, ckpt_dir=work + "/ckpt", log_every=20
            ),
        )
        res = trainer.run()

    h = res["history"]
    print(f"\nloss: {h[0]['loss']:.3f} → {h[-1]['loss']:.3f} over {res['steps_run']} steps")
    print(f"stragglers flagged: {res['straggler_steps']}, failures: {res['failures']}\n")
    print(render(tally_trace(work), top=10))
    print()
    print(vrender(validate_trace(work)))


if __name__ == "__main__":
    main()

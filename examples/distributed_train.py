"""End-to-end driver (deliverable b): train a ~100M-param dense model for a
few hundred steps on the local mesh, with checkpointing, tracing and a final
tally + validation report.

    PYTHONPATH=src python examples/distributed_train.py [--steps 200]

(~100M params: 12L × d512 × ff2048 × 32k vocab ≈ 96M.)

``--live`` instead demonstrates the §3.7+§6 streaming aggregation service on
localhost: N worker processes each run a small traced workload, streaming
live tally state (protocol-v2 delta frames) to a *local master* which
forwards the per-rank breakdown to a *global master* (the full fanout tree,
live, rank identities intact).  Each worker also runs an adaptive policy
that retunes its snapshot cadence from the live ``busy_fraction`` of
``train_step`` mid-run.  The driver renders the global composite while the
ranks run — what ``iprof top`` shows — then proves the final live composite
matches the offline ``iprof combine`` of the very same run's per-rank
aggregates, API for API, and that the ``query_ranks`` per-rank sums equal
the merged composite.

With ``--live-slow-rank R`` one rank is deliberately slowed inside its
``train_step`` spans; a **cluster-scope adaptive controller**
(``StragglerRankPolicy`` over the global master's per-rank composites) runs
in the driver, flags the lagging rank from API-level evidence — which rank,
which API, how far behind the cluster median — records the flag as an
``ust_repro:advisory`` event in the driver's own trace, and feeds the
trainer-layer straggler watchdog (``StragglerWatchdog.note_api_evidence``),
the same callback a real ``Trainer`` exposes as ``straggler_callback``.

    PYTHONPATH=src python examples/distributed_train.py --live --live-slow-rank 1
"""

import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile
import time

import jax

from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.core.plugins.validate import render as vrender, validate_trace
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def config_100m():
    base = get_config("h2o-danube-1.8b")
    return dataclasses.replace(
        base,
        name="danube-100m",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32_000,
        head_dim=64,
        sliding_window=1024,
        dtype="float32",
    )


# ---------------------------------------------------------------------------
# --live: multi-process streaming aggregation demo
# ---------------------------------------------------------------------------


def live_worker(
    rank: int,
    out_dir: str,
    addr: str,
    steps: int,
    slow_s: float = 0.0,
    seconds: float = 0.0,
) -> None:
    """One traced rank: tiny jit workload, tally state streamed to ``addr``
    (v2 delta frames in steady state), final aggregate also written to disk
    (aggregate_only) so the driver can cross-check the live composite
    against ``iprof combine``.

    Each worker also runs the §6 adaptive consumer: a cadence policy watches
    the live windowed ``busy_fraction`` of ``train_step`` and retunes the
    snapshot push period mid-run — snapshots arrive fast while the rank is
    compiling/computing, slow while it idles.  Every knob turn is printed
    and recorded as an ``ust_repro:advisory`` event in the trace.

    ``slow_s`` injects extra latency *inside* every ``train_step`` span —
    the synthetic straggler the driver's cluster-scope controller must
    catch from the per-rank composites alone.  With ``seconds`` set the
    worker keeps stepping until that much wall time has passed (at least
    ``steps`` steps), so fast and slow ranks stay *concurrently* active —
    cross-rank windows only exist while ranks overlap.
    """
    import jax.numpy as jnp

    from repro.core import (
        AdaptiveController,
        StreamCadencePolicy,
        collective_span,
        traced_jit,
        train_step_span,
    )

    f = traced_jit(lambda x: (x * x).sum(), name="square_sum")
    x = jnp.arange(128.0) + rank
    ctrl = AdaptiveController(
        [
            StreamCadencePolicy(
                "ust_repro", "train_step", high=0.05, low=0.005, fast_s=0.05, slow_s=0.5
            )
        ],
        period_s=0.1,
        on_action=lambda a: print(f"[rank {rank}] {a}", flush=True),
    )
    cfg = TraceConfig(
        out_dir=out_dir,
        mode="default",
        rank=rank,
        aggregate_only=True,
        stream_to=addr,
        stream_period_s=0.1,
        adaptive=ctrl,
    )
    with Tracer(cfg) as tr:
        deadline = time.monotonic() + seconds
        s = 0
        while s < steps or (seconds > 0 and time.monotonic() < deadline):
            with train_step_span(s, 2, 64) as sp:
                sp.outs["loss"] = float(f(x))
                sp.outs["grad_norm"] = 1.0
                if slow_s > 0:
                    time.sleep(slow_s)  # the injected straggler latency
            with collective_span("all_reduce", 128, "data", 2):
                pass
            time.sleep(0.05)  # spread steps so mid-run snapshots differ
            s += 1
    st = tr.streamer
    print(
        f"[rank {rank}] streamed {st.pushed} frames "
        f"({st.delta_frames} deltas, {st.full_frames} full, {st.bytes_sent} B); "
        f"{len(ctrl.actions)} adaptive knob turns",
        flush=True,
    )


def _api_totals(t):
    """(table, provider, api) → (calls, total_ns); the acceptance currency."""
    out = {}
    for name, table in (("host", t.apis), ("device", t.device_apis)):
        for key, st in table.items():
            out[(name,) + key] = (st.calls, st.total_ns)
    return out


def run_live(args) -> int:
    from repro.core import (
        ClusterAdaptiveController,
        MasterServer,
        StragglerRankPolicy,
        StreamClient,
    )
    from repro.core.aggregate import combine_aggregates, find_aggregates, merge_tallies
    from repro.core.babeltrace import CTFSource
    from repro.core.plugins.tally import Tally, render_by_rank
    from repro.train import StragglerWatchdog

    root = tempfile.mkdtemp(prefix="thapi_live_")
    # Global master at the tree root, one local master forwarding into it —
    # the paper's rank → local master → global master chain, live.  The
    # local master forwards the per-rank breakdown (forward_ranks default),
    # so rank identities survive to the root where the cluster controller
    # reads them.
    global_m = MasterServer(port=0).start()
    local_m = MasterServer(
        port=0, forward_to=global_m.addr, forward_period_s=0.1
    ).start()
    print(f"[live] global master {global_m.addr} ← local master {local_m.addr}")
    # one authenticated-capable client, one pooled connection for every
    # driver-side read of the global master (composite + per-rank breakdown)
    gclient = StreamClient(global_m.addr)

    env = dict(os.environ)
    procs = []
    for r in range(args.live_ranks):
        out = os.path.join(root, f"r{r}")
        cmd = [
            sys.executable,
            os.path.abspath(__file__),
            "--live-worker",
            str(r),
            "--live-out",
            out,
            "--live-addr",
            local_m.addr,
            "--live-steps",
            str(args.live_steps),
        ]
        if args.live_seconds:
            cmd += ["--live-worker-seconds", str(args.live_seconds)]
        if args.live_slow_rank is not None and r == args.live_slow_rank:
            cmd += ["--live-slow", str(args.live_slow_s)]
        procs.append(subprocess.Popen(cmd, env=env))
    if args.live_slow_rank is not None:
        print(
            f"[live] rank {args.live_slow_rank} deliberately slowed by "
            f"{args.live_slow_s * 1000:.0f}ms per train_step"
        )

    # Cluster-scope adaptive control in the driver: a StragglerRankPolicy
    # polls the global master's per-rank composites over TCP (query_ranks),
    # flags ranks lagging the cluster median on train_step latency, and
    # feeds the trainer-layer watchdog — the same callback a real Trainer
    # exposes as `trainer.straggler_callback`.
    watchdog = StragglerWatchdog()
    monitor = ClusterAdaptiveController(
        [
            StragglerRankPolicy(
                "ust_repro", "train_step", ratio=1.75, metric="latency", patience=1
            )
        ],
        addr=global_m.addr,
        period_s=0.4,
        on_straggler=watchdog.note_api_evidence,
        on_action=lambda a: print(f"[cluster] {a}", flush=True),
    )

    # The driver runs its own tiny tracing session so every cluster flag is
    # also recorded as a ust_repro:advisory event — the "adaptation is
    # observable" invariant holds at cluster scope too.
    driver_dir = os.path.join(root, "driver")
    print(f"[live] {len(procs)} ranks streaming; composite while they run:")
    with Tracer(TraceConfig(out_dir=driver_dir, mode="default", online=True)) as drv:
        monitor.attach(drv)
        while any(p.poll() is None for p in procs):
            monitor.tick()
            time.sleep(0.2)
            t, meta = gclient.composite()
            if t.apis or t.device_apis:
                print(
                    f"\n[live] -- {meta['sources']} sources, "
                    f"{meta['snapshots']} snapshots --"
                )
                print(render(t, top=5))
    rc = max(p.wait() for p in procs)
    if rc != 0:
        print(f"[live] a worker failed (exit {rc})", file=sys.stderr)
        return rc

    # Final snapshots are pushed at tracer stop; wait for them to propagate
    # up the tree, then compare against the offline batch combine.
    offline = combine_aggregates(find_aggregates(root))
    want = _api_totals(offline)
    deadline = time.time() + 10.0
    live = None
    while time.time() < deadline:
        local_m.flush(force=True)
        live, _ = gclient.composite()
        if _api_totals(live) == want:
            break
        time.sleep(0.2)
    ranks, _ = gclient.ranks()
    gclient.close()
    local_m.stop()
    global_m.stop()

    lst = local_m.stats()
    print(
        f"\n[live] local master ingested {lst['snapshots']} state updates "
        f"({lst['deltas']} deltas, {lst['full_snapshots']} full snapshots, "
        f"{lst['resyncs']} resyncs)"
    )
    print("\n[live] final composite (streaming, via global master):")
    print(render(live))
    print("\n[live] per-rank breakdown at the global master (iprof top --by-rank):")
    print(render_by_rank(ranks))
    print("\n[live] offline combine of the same run's rank aggregates:")
    print(render(offline))

    ok = True
    if _api_totals(live) == want:
        print(
            f"\n[live] OK: live composite matches offline combine "
            f"({len(want)} API rows, {args.live_ranks} ranks)"
        )
    else:
        print("\n[live] MISMATCH between live composite and offline combine", file=sys.stderr)
        ok = False

    # per-rank sums must reproduce the merged composite, API for API
    rank_merge, _ = merge_tallies([Tally().merge(t) for t in ranks.values()])
    if _api_totals(rank_merge) == _api_totals(live):
        print(
            f"[live] OK: query_ranks per-rank sums equal the merged composite "
            f"({len(ranks)} ranks)"
        )
    else:
        print("[live] MISMATCH between per-rank sums and composite", file=sys.stderr)
        ok = False

    if args.live_slow_rank is not None:
        reports = watchdog.api_reports()
        advisories = [
            ev for ev in CTFSource(driver_dir) if ev.name == "ust_repro:advisory"
        ]
        wanted = f"rank{args.live_slow_rank}"
        hit = [r for r in reports if r.source.endswith(wanted)]
        if hit and advisories:
            r = hit[0]
            print(
                f"[live] OK: straggler {r.source} flagged on {r.provider}:{r.api} "
                f"at {r.ratio:.1f}x the cluster median; trainer watchdog got "
                f"{len(reports)} report(s), {len(advisories)} advisory event(s) "
                f"in the driver trace"
            )
        else:
            print(
                f"[live] FAIL: slow rank {wanted} not flagged "
                f"(reports={len(reports)}, advisories={len(advisories)})",
                file=sys.stderr,
            )
            ok = False
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--live", action="store_true", help="streaming aggregation demo")
    ap.add_argument("--live-ranks", type=int, default=2)
    ap.add_argument("--live-steps", type=int, default=20)
    ap.add_argument(
        "--live-slow-rank",
        type=int,
        default=None,
        help="slow this rank inside train_step; the cluster controller must flag it",
    )
    ap.add_argument(
        "--live-slow-s",
        type=float,
        default=0.25,
        help="injected per-step latency for --live-slow-rank (seconds)",
    )
    ap.add_argument(
        "--live-seconds",
        type=float,
        default=None,
        help="run every rank for this much wall time (keeps fast and slow "
        "ranks concurrently active; defaults to 6s in slow-rank mode)",
    )
    ap.add_argument("--live-worker", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-addr", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--live-slow", type=float, default=0.0, help=argparse.SUPPRESS)
    ap.add_argument(
        "--live-worker-seconds", type=float, default=0.0, help=argparse.SUPPRESS
    )
    args = ap.parse_args()

    if args.live_worker is not None:
        live_worker(
            args.live_worker,
            args.live_out,
            args.live_addr,
            args.live_steps,
            slow_s=args.live_slow,
            seconds=args.live_worker_seconds,
        )
        return
    if args.live and args.live_slow_rank is not None and args.live_seconds is None:
        # straggler detection needs cross-rank windows: ranks must overlap
        args.live_seconds = 6.0
    if args.live:
        sys.exit(run_live(args))

    cfg = config_100m()
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    model = Model(cfg, mesh)
    print(f"{cfg.name}: {cfg.num_params() / 1e6:.0f}M params on {mesh.shape}")

    work = tempfile.mkdtemp(prefix="thapi_e2e_")
    with Tracer(TraceConfig(out_dir=work, mode="default", sample=True)):
        trainer = Trainer(
            model,
            ShapeSpec("e2e", "train", args.seq, args.batch),
            Partitioner(mesh),
            TrainConfig(peak_lr=3e-4, warmup=20, total_steps=args.steps),
            TrainerConfig(
                steps=args.steps, ckpt_every=50, ckpt_dir=work + "/ckpt", log_every=20
            ),
        )
        res = trainer.run()

    h = res["history"]
    print(f"\nloss: {h[0]['loss']:.3f} → {h[-1]['loss']:.3f} over {res['steps_run']} steps")
    print(f"stragglers flagged: {res['straggler_steps']}, failures: {res['failures']}\n")
    print(render(tally_trace(work), top=10))
    print()
    print(vrender(validate_trace(work)))


if __name__ == "__main__":
    main()

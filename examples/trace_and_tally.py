"""The paper's running example (§1.1): rich memcpy interception.

Runs H2D/D2H transfers under tracing, pretty-prints the memcpy events to
show the full call context (src/dst pointers, size), and demonstrates the
H2D-vs-D2H deduction from pointer address classes (host 0x00…, device 0xff…)
— exactly the zeCommandListAppendMemoryCopy walkthrough.

    PYTHONPATH=src python examples/trace_and_tally.py
"""

import tempfile

import numpy as np

from repro.core import TraceConfig, Tracer, traced_device_get, traced_device_put
from repro.core.babeltrace import CTFSource
from repro.core.plugins.pretty import format_event

def main():
    trace_dir = tempfile.mkdtemp(prefix="thapi_memcpy_")
    with Tracer(TraceConfig(out_dir=trace_dir, mode="full")):
        x = np.random.default_rng(0).normal(size=(1 << 16,)).astype(np.float32)
        dev = traced_device_put(x)  # H2D
        back = traced_device_get(dev * 2)  # D2H

    src = CTFSource(trace_dir)
    print("memcpy events (full argument context, THAPI-style):\n")
    for ev in src:
        if "memcpy" not in ev.name:
            continue
        print(format_event(ev, src.meta.clock))
        if ev.name.endswith("entry"):
            f = ev.asdict()
            kind = "H2D" if f["src"] >> 56 == 0 else "D2H"
            print(
                f"  → deduced {kind}: src 0x{f['src']:012x} "
                f"({'host' if f['src'] >> 56 == 0 else 'device'}) → "
                f"dst 0x{f['dst']:012x} "
                f"({'device' if f['dst'] >> 56 == 0xFF else 'host'}), "
                f"{f['nbytes']} bytes"
            )
    print("\n(compare §1.1: TAU records name+timestamp only; THAPI records the"
          "\n full call context, enabling exactly this deduction.)")


if __name__ == "__main__":
    main()

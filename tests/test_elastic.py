"""Elastic rank replacement: remesh-plan edge cases, checkpoint discovery,
the supervisor/manager spawn-restore-splice chain, the remediation replace
rung, incarnation fencing on the master, source GC, the trainer rejoin
barrier, and the by-rank tombstone rendering."""

import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_checkpoint
from repro.configs import get_config
from repro.core.plugins.tally import ApiStat, Tally, render_by_rank
from repro.core.remediation import (
    RUNG_DRAIN,
    RUNG_ESCALATE,
    RUNG_EVICT,
    RUNG_REPLACE,
    RemediationEngine,
    RemediationHooks,
)
from repro.core.stream import (
    MasterServer,
    ServeOptions,
    SnapshotStreamer,
    StreamClient,
)
from repro.jaxcompat import make_mesh
from repro.launch.elastic import (
    ReplacementManager,
    WorkerSupervisor,
    latest_restorable_step,
)
from repro.launch.mesh import RemeshPlan, plan_eviction
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def mk_tally(rank: int, calls: int = 10) -> Tally:
    t = Tally()
    st = ApiStat()
    for i in range(calls):
        st.add(1000 + rank + i)
    t.apis[("ust_repro", "train_step")] = st
    return t


def wait_until(pred, timeout_s=5.0, period_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period_s)
    return pred()


# ---------------------------------------------------------------------------
# RemeshPlan edge cases (plan_eviction / reassign / deal_shares / splice_rank)
# ---------------------------------------------------------------------------


def test_plan_eviction_rank_zero():
    # rank 0 is not special: survivors re-densify from the remaining ids
    plan = plan_eviction(4, [0])
    assert plan.survivors == (1, 2, 3)
    assert plan.dense_rank == {1: 0, 2: 1, 3: 2}
    assert plan.evicted == (0,)


def test_plan_eviction_all_but_one():
    plan = plan_eviction(4, [0, 1, 3])
    assert plan.survivors == (2,)
    assert plan.dense_rank == {2: 0}
    # the whole orphaned load lands on the lone survivor, conserved
    out = plan.reassign({0: 3, 1: 2, 2: 5, 3: 4})
    assert out == {2: 3 + 2 + 5 + 4}


def test_plan_eviction_rejects_empty_mesh_and_bad_ranks():
    with pytest.raises(ValueError):
        plan_eviction(3, [0, 1, 2])  # cannot evict every rank
    with pytest.raises(ValueError):
        plan_eviction(3, [5])  # out of range
    with pytest.raises(ValueError):
        plan_eviction(3, [-1])


def test_plan_eviction_double_evict_is_idempotent():
    # duplicate ids collapse: evicting rank 2 twice is evicting it once
    assert plan_eviction(4, [2, 2]) == plan_eviction(4, [2])


def test_reassign_round_robin_conserves_and_spreads():
    plan = plan_eviction(5, [1, 3])
    pending = {0: 2, 1: 7, 2: 1, 3: 4, 4: 0}
    out = plan.reassign(pending)
    assert set(out) == {0, 2, 4}
    assert sum(out.values()) == sum(pending.values())  # work conserved
    # round-robin: orphan work is spread, not dumped on the first survivor
    extra = {r: out[r] - pending.get(r, 0) for r in out}
    assert max(extra.values()) - min(extra.values()) <= 1


def test_deal_shares_matches_reassign_and_rejects_survivors():
    plan = plan_eviction(4, [1])
    dealt = plan.deal_shares(1, 7)
    assert sum(dealt.values()) == 7
    assert set(dealt) <= set(plan.survivors)
    assert all(n > 0 for n in dealt.values())  # zero shares are elided
    assert plan.deal_shares(1, 0) == {}
    with pytest.raises(ValueError):
        plan.deal_shares(0, 5)  # rank 0 was not evicted


def test_splice_rank_giveback_and_restored_topology():
    plan = plan_eviction(4, [1])
    dealt = plan.deal_shares(1, 6)  # {0: 2, 2: 2, 3: 2}
    new_plan, giveback = plan.splice_rank(1, dealt, done_extra={0: 1, 2: 5})
    # finished work is never clawed back; over-done shares clamp to zero
    assert giveback == {0: 1, 3: 2}
    assert new_plan.survivors == (0, 1, 2, 3)
    assert new_plan.evicted == ()
    assert new_plan.dense_rank == {0: 0, 1: 1, 2: 2, 3: 3}


def test_splice_rank_rejects_bad_inputs():
    plan = plan_eviction(4, [1])
    with pytest.raises(ValueError):
        plan.splice_rank(0, {})  # rank 0 was never evicted
    with pytest.raises(ValueError):
        plan.splice_rank(1, {1: 3})  # dealt share names a non-survivor


def test_splice_conservation_identity():
    # the end-to-end identity the chaos harness asserts: for any survivor
    # progress, dealt-out minus clawed-back equals what the survivors keep
    plan = plan_eviction(3, [2])
    dealt = plan.deal_shares(2, 9)
    for done in ({}, {0: 1}, {0: 5, 1: 4}, {0: 100}):
        _, giveback = plan.splice_rank(2, dealt, done)
        kept = {
            s: min(int(done.get(s, 0)), dealt[s]) for s in dealt
        }
        assert sum(giveback.values()) + sum(kept.values()) == 9


# ---------------------------------------------------------------------------
# latest_restorable_step (manifest-only checkpoint discovery)
# ---------------------------------------------------------------------------


def _save_steps(root, steps):
    ck = Checkpointer(str(root), keep=10)
    for s in steps:
        ck.save(s, {"w": jnp.arange(8.0)}, extra={"steps_done": s})
    return ck


def test_latest_restorable_missing_dir():
    assert latest_restorable_step("/nonexistent/nowhere") is None


def test_latest_restorable_picks_newest(tmp_path):
    _save_steps(tmp_path, [2, 5, 3])
    path, step = latest_restorable_step(str(tmp_path))
    assert step == 5 and path.endswith("step_5")
    # agrees with the jax-backed checkpointer's own discovery
    assert latest_checkpoint(str(tmp_path)) == path


def test_latest_restorable_skips_corrupt_manifest(tmp_path):
    _save_steps(tmp_path, [2, 5])
    with open(tmp_path / "step_5" / "manifest.json", "w") as f:
        f.write("{not json")
    path, step = latest_restorable_step(str(tmp_path))
    assert step == 2 and path.endswith("step_2")


def test_latest_restorable_skips_truncated_leaf(tmp_path):
    _save_steps(tmp_path, [2, 5])
    with open(tmp_path / "step_5" / "w.npy", "wb") as f:
        f.write(b"x")  # far below the manifest's nbytes
    path, step = latest_restorable_step(str(tmp_path))
    assert step == 2


# ---------------------------------------------------------------------------
# WorkerSupervisor / ReplacementManager (fake process handles)
# ---------------------------------------------------------------------------


class FakeProc:
    """Quacks like subprocess.Popen for the supervisor."""

    def __init__(self, alive=True):
        self.rc = None if alive else 1
        self.terminated = 0

    def poll(self):
        return self.rc

    def terminate(self):
        self.terminated += 1
        if self.rc is None:
            self.rc = -15

    def kill(self):
        self.rc = -9

    def wait(self, timeout=None):
        return self.rc


def test_supervisor_incarnation_monotone():
    spawned = []

    def spawn(rank, inc):
        p = FakeProc()
        spawned.append((rank, inc))
        return p

    sup = WorkerSupervisor(spawn)
    p0 = FakeProc()
    sup.register(3, p0)
    assert sup.incarnation(3) == 0 and sup.alive(3)
    h1, inc1 = sup.spawn_replacement(3)
    h2, inc2 = sup.spawn_replacement(3)
    assert (inc1, inc2) == (1, 2)
    assert spawned == [(3, 1), (3, 2)]
    assert sup.handle(3) is h2  # latest incarnation owns the slot
    assert sup.ranks() == (3,)


def test_supervisor_doa_spawn_burns_its_number():
    sup = WorkerSupervisor(lambda r, i: FakeProc(alive=False))
    sup.register(0, FakeProc())
    _, inc1 = sup.spawn_replacement(0)
    _, inc2 = sup.spawn_replacement(0)
    # a spawn that dies instantly still consumed its incarnation: the fence
    # stays strictly monotone across retries
    assert (inc1, inc2) == (1, 2)
    assert not sup.alive(0)


def test_supervisor_terminate_is_idempotent():
    sup = WorkerSupervisor(lambda r, i: FakeProc())
    p = FakeProc()
    sup.register(1, p)
    sup.terminate(1)
    sup.terminate(1)  # already dead: no-op, no raise
    assert p.terminated == 1
    sup.terminate(99)  # unknown rank: no-op


def test_replacement_manager_success_path():
    events = []
    sup = WorkerSupervisor(lambda r, i: FakeProc())
    sup.register(1, FakeProc(alive=False))
    mgr = ReplacementManager(
        sup,
        ready=lambda r, i: True,
        on_event=lambda a, t, d, ok: events.append((a, t, ok)),
    )
    plan = plan_eviction(4, [1])
    dealt = plan.deal_shares(1, 6)
    res = mgr.replace(1, plan, dealt, done_extra={0: 1}, target="rank1")
    assert res.ok and res.incarnation == 1 and res.attempts == 1
    assert res.plan.survivors == (0, 1, 2, 3)
    assert res.giveback == {0: dealt[0] - 1, 2: dealt[2], 3: dealt[3]}
    assert (mgr.spawned, mgr.admitted, mgr.failed) == (1, 1, 0)
    assert [a for a, _, _ in events] == ["replace_spawn", "replace_admit"]
    assert all(t == "rank1" for _, t, _ in events)


def test_replacement_manager_gives_up_after_retries():
    events = []
    sup = WorkerSupervisor(lambda r, i: FakeProc(alive=False))
    sup.register(2, FakeProc(alive=False))
    mgr = ReplacementManager(
        sup,
        ready=lambda r, i: True,
        spawn_retries=1,
        on_event=lambda a, t, d, ok: events.append((a, ok)),
    )
    plan = plan_eviction(3, [2])
    res = mgr.replace(2, plan, plan.deal_shares(2, 4))
    assert not res.ok and res.plan is None and res.giveback == {}
    assert res.attempts == 2  # 1 + spawn_retries
    assert "died during startup" in res.detail
    assert (mgr.spawned, mgr.admitted, mgr.failed) == (2, 0, 1)
    assert events[-1] == ("replace_giveup", False)
    # both failed incarnations burned their numbers
    assert sup.incarnation(2) == 2


def test_replacement_manager_ready_timeout_fake_clock():
    clk = [0.0]
    slept = []

    def sleep(s):
        slept.append(s)
        clk[0] += s

    sup = WorkerSupervisor(lambda r, i: FakeProc())
    sup.register(0, FakeProc())
    mgr = ReplacementManager(
        sup,
        ready=lambda r, i: False,
        ready_timeout_s=2.0,
        poll_s=0.5,
        spawn_retries=0,
        clock=lambda: clk[0],
        sleep=sleep,
    )
    plan = plan_eviction(2, [0])
    res = mgr.replace(0, plan, plan.deal_shares(0, 2))
    assert not res.ok and "not ready within" in res.detail
    assert slept  # the injected clock drove the poll loop, not wall time


def test_replacement_manager_restore_point(tmp_path):
    _save_steps(tmp_path, [3, 7])
    sup = WorkerSupervisor(lambda r, i: FakeProc())
    mgr = ReplacementManager(sup, ckpt_root_for=lambda r: str(tmp_path))
    path, step = mgr.restore_point(0)
    assert step == 7 and path.endswith("step_7")
    # no checkpoint root → fresh start, reported as -1
    assert ReplacementManager(sup).restore_point(0) == (None, -1)
    missing = ReplacementManager(sup, ckpt_root_for=lambda r: str(tmp_path / "no"))
    assert missing.restore_point(0) == (None, -1)


# ---------------------------------------------------------------------------
# RemediationEngine replace rung
# ---------------------------------------------------------------------------


def _mk_engine(clk, hooks, **kw):
    kw.setdefault("cooldown_s", 1.0)
    kw.setdefault("backoff_cap_s", 1.0)
    kw.setdefault("escalate_after", 1)
    return RemediationEngine(hooks, clock=lambda: clk[0], **kw)


def _walk(engine, clk, target, ticks):
    for _ in range(ticks):
        clk[0] += 10.0
        engine.ingest_flag(target, "straggler", "p99 3x")
        engine.tick()


def test_engine_skips_replace_rung_without_hook():
    clk = [0.0]
    fired = []
    hooks = RemediationHooks(
        escalate=lambda t, r: fired.append(RUNG_ESCALATE) or True,
        drain=lambda t, r: fired.append(RUNG_DRAIN) or True,
        evict=lambda t, r: fired.append(RUNG_EVICT) or True,
    )
    engine = _mk_engine(clk, hooks)
    _walk(engine, clk, "r0", 4)
    # no replace hook: drain escalates straight to evict, pre-elastic shape
    assert fired == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT]
    assert [a.action for a in engine.actions] == fired
    assert engine.evicted == ("r0",)
    assert engine.replacements == 0


def test_engine_replace_fires_after_drain_and_resets_target():
    clk = [0.0]
    fired = []
    hooks = RemediationHooks(
        escalate=lambda t, r: True,
        drain=lambda t, r: True,
        replace=lambda t, r: fired.append(t) or True,
        evict=lambda t, r: True,
    )
    engine = _mk_engine(clk, hooks)
    _walk(engine, clk, "r1", 3)
    assert fired == ["r1"]
    names = [a.action for a in engine.actions]
    assert names == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE]
    # the replacement is a new process: its ladder history starts fresh
    assert engine.rung_of("r1") == -1
    assert engine.actions[-1].rung == -1
    assert engine.replacements == 1
    assert engine.evicted == ()
    # the next incident walks the ladder from the bottom again
    _walk(engine, clk, "r1", 1)
    assert engine.actions[-1].action == RUNG_ESCALATE


def test_engine_replace_budget_zero_goes_straight_to_evict():
    clk = [0.0]
    hooks = RemediationHooks(
        escalate=lambda t, r: True,
        drain=lambda t, r: True,
        replace=lambda t, r: True,
        evict=lambda t, r: True,
    )
    engine = _mk_engine(clk, hooks, max_replacements=0)
    _walk(engine, clk, "r0", 3)
    names = [a.action for a in engine.actions]
    assert names == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT]
    assert engine.replacements == 0


def test_engine_replace_budget_spent_second_incident_evicts():
    clk = [0.0]
    hooks = RemediationHooks(
        escalate=lambda t, r: True,
        drain=lambda t, r: True,
        replace=lambda t, r: True,
        evict=lambda t, r: True,
    )
    engine = _mk_engine(clk, hooks, max_replacements=1)
    _walk(engine, clk, "r0", 3)  # escalate, drain, replace (budget spent)
    _walk(engine, clk, "r0", 3)  # escalate, drain, evict (over budget)
    names = [a.action for a in engine.actions]
    assert names == [
        RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE,
        RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT,
    ]
    assert engine.replacements == 1 and engine.evicted == ("r0",)


def test_engine_failed_replace_falls_through_to_evict():
    clk = [0.0]
    attempts = []
    hooks = RemediationHooks(
        escalate=lambda t, r: True,
        drain=lambda t, r: True,
        replace=lambda t, r: attempts.append(t) and False,
        evict=lambda t, r: True,
    )
    engine = _mk_engine(clk, hooks, replace_retries=1)
    _walk(engine, clk, "r0", 5)
    # replace fired 1 + replace_retries times, then the ladder gave up on
    # replacement and evicted — the drained precondition still held
    assert len(attempts) == 2
    names = [a.action for a in engine.actions]
    assert names == [
        RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE, RUNG_REPLACE, RUNG_EVICT,
    ]
    assert not engine.actions[2].ok and not engine.actions[3].ok
    assert engine.evicted == ("r0",) and engine.replacements == 0


def test_engine_replace_requires_drain_first():
    clk = [0.0]
    hooks = RemediationHooks(
        escalate=lambda t, r: True,
        drain=lambda t, r: False,  # drain keeps failing
        replace=lambda t, r: True,
        evict=lambda t, r: True,
    )
    engine = _mk_engine(clk, hooks)
    _walk(engine, clk, "r0", 4)
    names = [a.action for a in engine.actions]
    # never past drain: replace shares evict's drained precondition
    assert RUNG_REPLACE not in names and RUNG_EVICT not in names


def test_engine_dry_run_advises_replace_rung():
    clk = [0.0]
    engine = _mk_engine(clk, None, dry_run=True)
    _walk(engine, clk, "r0", 4)
    names = [a.action for a in engine.actions]
    assert names == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE, RUNG_EVICT]
    assert all(a.dry_run for a in engine.actions)
    # advisory only: nothing actually replaced or evicted
    assert engine.replacements == 0 and engine.evicted == ()


def test_engine_note_lands_in_audit_log():
    clk = [0.0]
    seen = []
    engine = _mk_engine(clk, None, on_action=seen.append)
    act = engine.note("replace_spawn", "rankX", "incarnation 1 attempt 1")
    assert act.rung == -1 and act.ok  # unknown target: healthy rung
    engine.ingest_flag("r0")
    engine.tick(10.0)
    act2 = engine.note("replace_admit", "r0", "spliced")
    assert act2.rung == engine.rung_of("r0")
    assert [a.action for a in engine.actions] == [
        "replace_spawn", RUNG_ESCALATE, "replace_admit",
    ]
    assert seen == engine.actions


# ---------------------------------------------------------------------------
# Master fencing, source GC, and tombstones
# ---------------------------------------------------------------------------


def test_master_fences_lower_incarnation_snapshot():
    m = MasterServer(port=0)
    assert m.incarnation_of("r0") == -1  # unknown source
    assert m.submit("r0", mk_tally(0, calls=3), incarnation=1)
    assert m.incarnation_of("r0") == 1
    # a zombie's late frame: dropped, counted, state untouched
    assert not m.submit("r0", mk_tally(0, calls=99), incarnation=0)
    assert m.fence_rejects == 1
    st = m.composite().apis[("ust_repro", "train_step")]
    assert st.calls == 3


def test_master_higher_incarnation_swaps_state_atomically():
    m = MasterServer(port=0)
    assert m.submit("r0", mk_tally(0, calls=10), incarnation=0)
    # the replacement's first frame replaces the whole per-source state —
    # never merged with the predecessor's contribution
    assert m.submit("r0", mk_tally(0, calls=3), incarnation=1)
    assert m.incarnation_of("r0") == 1
    st = m.composite().apis[("ust_repro", "train_step")]
    assert st.calls == 3
    assert m.fence_rejects == 0


def test_master_delta_chain_breaks_across_incarnations():
    m = MasterServer(port=0)
    t1 = mk_tally(0, calls=2)
    assert m.submit("r0", Tally().merge(t1), seq=0, gen=7, incarnation=1)
    t2 = mk_tally(0, calls=5)
    delta = t2.delta_to(t1)
    # zombie delta: fenced, no resync path
    assert not m.submit_delta("r0", delta, seq=1, base_seq=0, gen=7, incarnation=0)
    assert m.fence_rejects == 1
    # newer incarnation without a snapshot base: chain mismatch, not fenced
    assert not m.submit_delta("r0", delta, seq=1, base_seq=0, gen=7, incarnation=2)
    assert m.fence_rejects == 1
    # the live incarnation's chain applies cleanly
    assert m.submit_delta("r0", delta, seq=1, base_seq=0, gen=7, incarnation=1)
    st = m.composite().apis[("ust_repro", "train_step")]
    assert st.calls == 5


def test_zombie_hello_is_fenced_over_the_socket():
    with MasterServer(port=0) as m:
        live = SnapshotStreamer(m.addr, source="rZ", incarnation=1)
        t = mk_tally(0, calls=4)
        assert live.push(t)
        assert wait_until(lambda: m.incarnation_of("rZ") == 1)
        # the predecessor process reconnects: fenced at hello, told why,
        # and politely stops for good (the fence is monotone)
        zombie = SnapshotStreamer(m.addr, source="rZ", incarnation=0, retry_s=0.01)
        poison = mk_tally(0, calls=1000)
        for _ in range(200):
            zombie.push(poison)
            if zombie.fenced:
                break
            time.sleep(0.02)
        assert zombie.fenced >= 1
        assert m.fence_rejects >= 1
        assert zombie.push(poison) is False  # permanently stopped
        st = m.composite().apis[("ust_repro", "train_step")]
        assert st.calls == 4  # the poison never reached the composite
        live.close()
        zombie.close()


def test_source_gc_collects_long_dead_sources():
    m = MasterServer(port=0, options=ServeOptions(source_ttl_s=0.3))
    assert m.submit("dead", mk_tally(0))
    time.sleep(0.5)
    assert m.submit("live", mk_tally(1))  # ingest triggers the throttled sweep
    assert wait_until(lambda: "dead" not in m.ranks(), timeout_s=2.0)
    assert "live" in m.ranks()
    assert m.stats()["source_gc"] == 1


def test_retire_and_unretire_visible_to_clients():
    with MasterServer(port=0) as m:
        assert m.submit("r0", mk_tally(0))
        assert m.submit("r1", mk_tally(1))
        assert not m.retire_source("ghost")  # unknown source
        assert m.retire_source("r0")
        with StreamClient(m.addr) as c:
            _, meta = c.ranks()
        assert "r0" in meta["retired"]
        # the replacement's first frame un-retires the row
        assert m.submit("r0", mk_tally(0, calls=2), incarnation=1)
        with StreamClient(m.addr) as c:
            ranks, meta = c.ranks()
        assert "r0" not in meta.get("retired", [])
        assert meta["incarnations"]["r0"] == 1
        assert set(ranks) == {"r0", "r1"}


# ---------------------------------------------------------------------------
# Trainer rejoin barrier
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def smoke_model(mesh):
    return Model(get_config("stablelm-3b").smoke(), mesh)


SHAPE = ShapeSpec("t", "train", 32, 4)


def mk_trainer(smoke_model, mesh, tmp, steps=8, **kw):
    return Trainer(
        smoke_model,
        SHAPE,
        Partitioner(mesh),
        TrainConfig(peak_lr=5e-3, warmup=2, total_steps=100),
        TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=str(tmp), **kw),
    )


def test_admit_replacement_restores_and_extends(smoke_model, mesh, tmp_path):
    # predecessor: run to 8, checkpointing along the way, then drain
    t = mk_trainer(smoke_model, mesh, tmp_path / "a", steps=8)
    t.run()
    t.checkpoint_and_drain()
    assert t.drained
    # replacement incarnation: restore, clear the drain latch, take back
    # the clawed work as extra step budget
    t2 = mk_trainer(smoke_model, mesh, tmp_path / "a", steps=8)
    restored = t2.admit_replacement(incarnation=1, extra_steps=3)
    assert restored == 8
    assert t2.incarnation == 1
    assert not t2.drained and not t2.draining.is_set()
    assert t2.cfg.steps == 11
    res = t2.run()
    assert res["steps_run"] == 3 and t2.step == 11


def test_admit_replacement_rejects_negative_incarnation(smoke_model, mesh, tmp_path):
    t = mk_trainer(smoke_model, mesh, tmp_path / "b", steps=4)
    with pytest.raises(ValueError):
        t.admit_replacement(incarnation=-1)


# ---------------------------------------------------------------------------
# Restore racing save_async
# ---------------------------------------------------------------------------


def test_restore_races_concurrent_save_async(tmp_path):
    """A replacement restoring while the predecessor's async saver is still
    committing must only ever see self-consistent checkpoints (atomic
    rename + retention GC can remove a dir mid-read, but never tear one)."""
    root = str(tmp_path / "race")
    ck = Checkpointer(root, keep=2)
    stop = threading.Event()

    def writer():
        for s in range(1, 30):
            if stop.is_set():
                break
            ck.save_async(s, {"w": np.full(16, float(s))}, extra={"steps_done": s})
        ck.wait()

    wt = threading.Thread(target=writer)
    wt.start()
    reader = Checkpointer(root, keep=2)
    successes = 0
    try:
        deadline = time.monotonic() + 20.0
        while wt.is_alive() and time.monotonic() < deadline:
            path = latest_checkpoint(root)
            if path is None:
                continue
            try:
                tree, man = reader.restore(path, {"w": np.zeros(16)})
            except Exception:
                continue  # the dir was GC'd mid-read: allowed, just retry
            # every successful restore is internally consistent
            assert float(tree["w"][0]) == float(man.extra["steps_done"])
            successes += 1
    finally:
        stop.set()
        wt.join()
    assert successes > 0


# ---------------------------------------------------------------------------
# By-rank rendering: incarnation suffix + tombstones
# ---------------------------------------------------------------------------


def test_render_by_rank_elastic_annotations():
    ranks = {"r0": mk_tally(0, calls=5), "r1": mk_tally(1, calls=5)}
    out = render_by_rank(ranks, incarnations={"r1": 2}, retired=["r0"])
    assert "r1#2" in out  # replacement: never merges with its predecessor
    assert "r0 [evicted]" in out  # tombstone, totals still counted
    assert "(1 live, 1 evicted)" in out
    plain = render_by_rank(ranks)
    assert "[evicted]" not in plain and "#" not in plain
    assert "2 ranks" in plain

"""RemediationEngine ladder mechanics + FaultInjector determinism.

Everything here runs with an injected clock — no sleeps, no wall time."""

import pytest

from repro.core.adaptive import ClusterAdaptiveController, SickHostPolicy
from repro.core.faults import FaultInjector, FaultKind, FaultSpec, parse_fault_specs
from repro.core.remediation import (
    RUNG_DRAIN,
    RUNG_ESCALATE,
    RUNG_EVICT,
    RemediationEngine,
    RemediationHooks,
)


class Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


class Recorder:
    """Hook that logs invocations and returns a scripted result."""

    def __init__(self, results=None):
        self.calls = []
        self.results = list(results or [])

    def __call__(self, target, reason):
        self.calls.append((target, reason))
        return self.results.pop(0) if self.results else True


def mk_engine(clock, **kw):
    hooks = RemediationHooks(
        escalate=kw.pop("escalate", Recorder()),
        drain=kw.pop("drain", Recorder()),
        evict=kw.pop("evict", Recorder()),
        restore=kw.pop("restore", None),
    )
    kw.setdefault("cooldown_s", 1.0)
    kw.setdefault("backoff_cap_s", 4.0)
    kw.setdefault("escalate_after", 2)
    kw.setdefault("healthy_windows", 3)
    return RemediationEngine(hooks, clock=clock, **kw)


def flag_and_tick(eng, clock, src="rank1", n=1, dt=1.0, kind="straggler"):
    out = []
    for _ in range(n):
        eng.ingest_flag(src, kind, "test")
        out += eng.tick(clock.advance(dt))
    return out


# ---------------------------------------------------------------------------
# escalation ladder
# ---------------------------------------------------------------------------


def test_first_flag_fires_cheapest_rung_immediately():
    clock = Clock()
    eng = mk_engine(clock)
    acts = flag_and_tick(eng, clock, n=1)
    assert [a.action for a in acts] == [RUNG_ESCALATE]
    assert eng.rung_of("rank1") == 0
    assert eng.hooks.escalate.calls == [("rank1", "straggler: test")]


def test_sustained_flags_walk_the_full_ladder_in_order():
    clock = Clock()
    eng = mk_engine(clock)
    acts = flag_and_tick(eng, clock, n=8)
    names = [a.action for a in acts]
    assert names == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT]
    # drain-before-evict: strictly ordered, and each hook ran exactly once
    assert len(eng.hooks.drain.calls) == 1
    assert len(eng.hooks.evict.calls) == 1
    assert eng.evicted == ("rank1",)


def test_unsustained_flag_holds_the_current_rung():
    clock = Clock()
    eng = mk_engine(clock, escalate_after=3)
    flag_and_tick(eng, clock, n=1)  # rung 0
    # alternate flagged/healthy: streak never reaches 3, rung never moves
    for _ in range(6):
        flag_and_tick(eng, clock, n=1)
        eng.observe_healthy("rank1")
        eng.tick(clock.advance(1.0))
    assert eng.rung_of("rank1") <= 0
    assert not eng.hooks.drain.calls


def test_evict_requires_prior_drain():
    clock = Clock()
    # drain hook always fails: the ladder must never reach evict
    eng = mk_engine(clock, drain=Recorder(results=[False] * 50))
    acts = flag_and_tick(eng, clock, n=20, dt=5.0)  # dt > backoff cap
    assert RUNG_EVICT not in [a.action for a in acts]
    assert not eng.hooks.evict.calls
    assert eng.evicted == ()


def test_eviction_budget_caps_evictions():
    clock = Clock()
    eng = mk_engine(clock, max_evictions=1)
    flag_and_tick(eng, clock, "rank1", n=8)
    flag_and_tick(eng, clock, "rank2", n=8)
    assert eng.evicted == ("rank1",)
    assert eng.rung_of("rank2") == 1  # drained, but eviction denied
    assert len(eng.hooks.evict.calls) == 1


def test_evicted_target_is_terminal():
    clock = Clock()
    eng = mk_engine(clock)
    flag_and_tick(eng, clock, n=8)
    before = len(eng.actions)
    flag_and_tick(eng, clock, n=5)  # flags for an evicted rank are ignored
    assert len(eng.actions) == before


# ---------------------------------------------------------------------------
# cooldown and capped-exponential backoff
# ---------------------------------------------------------------------------


def test_cooldown_blocks_refire_within_window():
    clock = Clock()
    eng = mk_engine(clock, cooldown_s=10.0, backoff_cap_s=10.0)
    eng.ingest_flag("rank1")
    assert len(eng.tick(clock.advance(1.0))) == 1
    for _ in range(5):  # 5s elapsed < 10s cooldown: nothing may fire
        eng.ingest_flag("rank1")
        assert eng.tick(clock.advance(1.0)) == []
    eng.ingest_flag("rank1")
    assert [a.action for a in eng.tick(clock.advance(10.0))] == [RUNG_DRAIN]


def test_failed_hook_retries_same_rung_with_capped_backoff():
    clock = Clock()
    drain = Recorder(results=[False, False, False, True])
    eng = mk_engine(clock, drain=drain, cooldown_s=1.0, backoff_cap_s=4.0)
    flag_and_tick(eng, clock, n=1)  # rung 0
    fire_times = []
    for _ in range(40):
        eng.ingest_flag("rank1")
        for a in eng.tick(clock.advance(0.5)):
            if a.action == RUNG_DRAIN:
                fire_times.append(a.ts)
    assert len(fire_times) == 4  # 3 failures + the success
    gaps = [b - a for a, b in zip(fire_times, fire_times[1:])]
    # backoff 2^1, 2^2, then capped at 4.0 (tick grid is 0.5s)
    assert gaps[0] == pytest.approx(2.0, abs=0.5)
    assert gaps[1] == pytest.approx(4.0, abs=0.5)
    assert gaps[2] == pytest.approx(4.0, abs=0.5)
    # the failed attempts are in the audit log, marked failed
    failed = [a for a in eng.actions if a.action == RUNG_DRAIN and not a.ok]
    assert len(failed) == 3
    assert eng.rung_of("rank1") >= 1  # success landed the rung (and may escalate on)


def test_raising_hook_counts_as_failure():
    clock = Clock()

    def boom(target, reason):
        raise RuntimeError("effector exploded")

    eng = mk_engine(clock, escalate=boom)
    acts = flag_and_tick(eng, clock, n=1)
    assert len(acts) == 1 and not acts[0].ok
    assert eng.rung_of("rank1") == -1  # rung not taken


# ---------------------------------------------------------------------------
# hysteresis / de-escalation
# ---------------------------------------------------------------------------


def test_healthy_windows_deescalate_one_rung_at_a_time():
    clock = Clock()
    restore = Recorder()
    eng = mk_engine(clock, restore=restore, healthy_windows=3)
    flag_and_tick(eng, clock, n=4)  # escalate + drain (rung 1)
    assert eng.rung_of("rank1") == 1
    acts = []
    for _ in range(6):
        eng.observe_healthy("rank1")
        acts += eng.tick(clock.advance(1.0))
    assert [a.action for a in acts] == ["deescalate", "recover"]
    assert eng.rung_of("rank1") == -1
    assert restore.calls == [("rank1", "recovered")]


def test_flag_resets_healthy_streak():
    clock = Clock()
    eng = mk_engine(clock, healthy_windows=3)
    flag_and_tick(eng, clock, n=1)
    for _ in range(4):  # healthy, healthy, flag, healthy... never 3 in a row
        eng.observe_healthy("rank1")
        eng.tick(clock.advance(1.0))
        eng.observe_healthy("rank1")
        eng.tick(clock.advance(1.0))
        flag_and_tick(eng, clock, n=1)
    assert eng.rung_of("rank1") == 0  # never de-escalated


# ---------------------------------------------------------------------------
# dry-run mode
# ---------------------------------------------------------------------------


def test_dry_run_never_invokes_hooks_but_logs_everything():
    clock = Clock()
    eng = mk_engine(clock, dry_run=True)
    acts = flag_and_tick(eng, clock, n=10)
    names = [a.action for a in acts]
    # dry-run skips the drained gate, so the advisory ladder reaches evict
    assert RUNG_ESCALATE in names and RUNG_DRAIN in names and RUNG_EVICT in names
    assert all(a.dry_run for a in acts)
    assert not eng.hooks.escalate.calls
    assert not eng.hooks.drain.calls
    assert not eng.hooks.evict.calls
    assert eng.evicted == ()  # advisory eviction doesn't remove anyone


def test_missing_hook_is_advisory_and_ladder_progresses():
    clock = Clock()
    eng = RemediationEngine(None, clock=clock, cooldown_s=1.0, escalate_after=1)
    acts = flag_and_tick(eng, clock, n=5)
    assert [a.action for a in acts] == [RUNG_ESCALATE, RUNG_DRAIN, RUNG_EVICT]
    assert all(a.ok for a in acts)


# ---------------------------------------------------------------------------
# traced decisions
# ---------------------------------------------------------------------------


def test_every_decision_is_a_trace_event(tmp_path):
    from repro.core import TraceConfig, Tracer
    from repro.core.babeltrace import CTFSource

    clock = Clock()
    out = str(tmp_path / "trace")
    with Tracer(TraceConfig(out_dir=out, mode="default")) as tr:
        eng = mk_engine(clock, dry_run=True).attach(tr)
        flag_and_tick(eng, clock, n=10)
        n_actions = len(eng.actions)
    evs = [e for e in CTFSource(out) if e.name == "ust_repro:remediation"]
    assert n_actions > 0 and len(evs) == n_actions


def test_on_action_callback_sees_every_action():
    clock = Clock()
    seen = []
    eng = mk_engine(clock, on_action=seen.append)
    flag_and_tick(eng, clock, n=8)
    assert seen == eng.actions


# ---------------------------------------------------------------------------
# engine parameter validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {"cooldown_s": 0.0},
        {"cooldown_s": 2.0, "backoff_cap_s": 1.0},
        {"escalate_after": 0},
        {"healthy_windows": 0},
    ],
)
def test_engine_rejects_bad_params(kw):
    with pytest.raises(ValueError):
        RemediationEngine(None, **kw)


# ---------------------------------------------------------------------------
# FaultSpec / FaultInjector
# ---------------------------------------------------------------------------


def test_fault_spec_parse_roundtrip():
    s = FaultSpec.parse("slowdown:rank=1,after=10,factor=8")
    assert (s.kind, s.rank, s.after, s.factor) == ("slowdown", 1, 10, 8.0)
    assert FaultSpec.parse(s.render()) == s
    multi = parse_fault_specs("kill:rank=2,after=5; drop:after=3,p=0.5")
    assert [m.kind for m in multi] == ["kill", "drop"]


@pytest.mark.parametrize(
    "text",
    ["", "frobnicate:rank=1", "slowdown:p=1.5", "slowdown:factor=0", "slowdown:nope"],
)
def test_fault_spec_rejects_bad_input(text):
    with pytest.raises(ValueError):
        FaultSpec.parse(text)


def test_fault_spec_window():
    s = FaultSpec(FaultKind.HANG, after=5, duration=3)
    assert [s.active_at(i) for i in range(4, 9)] == [False, True, True, True, False]


def test_injector_is_rank_scoped_and_deterministic():
    specs = parse_fault_specs("slowdown:rank=1,after=2,factor=5;kill:rank=0,after=7")
    r0 = FaultInjector(specs, rank=0, seed=42)
    r1 = FaultInjector(specs, rank=1, seed=42)
    # rank 0 never slows, dies at 7; rank 1 slows from 2, never dies
    assert [r0.sleep_s(i, 0.01) for i in range(10)] == [0.0] * 10
    assert [i for i in range(10) if r0.should_die(i)] == [7, 8, 9]
    sleeps = [r1.sleep_s(i, 0.01) for i in range(10)]
    assert sleeps[2] == pytest.approx(0.04)
    assert not any(r1.should_die(i) for i in range(10))
    # same seed and same call pattern → identical fault log
    r1b = FaultInjector(specs, rank=1, seed=42)
    assert [r1b.sleep_s(i, 0.01) for i in range(10)] == sleeps
    [r1b.should_die(i) for i in range(10)]
    assert r1b.log == r1.log
    assert r0.fired("kill") == 3 and r1.fired("slowdown") > 0


def test_probabilistic_fault_reproducible_per_seed():
    spec = (FaultSpec(FaultKind.DROP, p=0.5),)
    a = FaultInjector(spec, rank=0, seed=7)
    b = FaultInjector(spec, rank=0, seed=7)
    c = FaultInjector(spec, rank=0, seed=8)
    sched_a = [a.should_drop_connection(i) for i in range(50)]
    sched_b = [b.should_drop_connection(i) for i in range(50)]
    sched_c = [c.should_drop_connection(i) for i in range(50)]
    assert sched_a == sched_b
    assert sched_a != sched_c  # astronomically unlikely to collide
    assert 5 < sum(sched_a) < 45  # p=0.5 actually samples


def test_mangle_frame_corrupt_and_truncate():
    payload = bytes(range(64))
    cor = FaultInjector((FaultSpec(FaultKind.CORRUPT),), rank=0, seed=1)
    out = cor.mangle_frame(payload, 0)
    assert len(out) == len(payload) and out != payload
    assert sum(1 for x, y in zip(out, payload) if x != y) == 1  # one byte flipped
    tru = FaultInjector((FaultSpec(FaultKind.TRUNCATE),), rank=0, seed=1)
    out = tru.mangle_frame(payload, 0)
    assert 1 <= len(out) < len(payload)
    # healthy injector passes payloads through untouched
    clean = FaultInjector((), rank=0, seed=1)
    assert clean.mangle_frame(payload, 0) == payload


# ---------------------------------------------------------------------------
# SickHostPolicy (telemetry-evidence flagging)
# ---------------------------------------------------------------------------


def _controller_with(policy, flags):
    return ClusterAdaptiveController(
        [policy],
        period_s=0.0,
        on_flag=lambda s, k, d: flags.append((s, k, d)),
    )


def _observe(ctl, ranks, telemetry, now):
    ctl.observe(ranks, now, telemetry=telemetry)


def test_sick_host_policy_flags_device_memory_pressure():
    from repro.core.plugins.tally import ApiStat, Tally

    def mk():
        t = Tally()
        st = ApiStat()
        st.add(1000)
        t.apis[("ust_repro", "train_step")] = st
        return t

    flags = []
    pol = SickHostPolicy(patience=2)
    ctl = _controller_with(pol, flags)
    ranks = {"rank0": mk(), "rank1": mk()}
    telem = {
        "rank0": {"mem_in_use": 10, "mem_limit": 100, "host_rss": 100},
        "rank1": {"mem_in_use": 99, "mem_limit": 100, "host_rss": 100},
    }
    for i in range(3):
        _observe(ctl, ranks, telem, float(i))
    assert any(s == "rank1" and k == "sick-host" for s, k, _ in flags)
    assert "rank1" in pol.flagged
    # recovery: pressure drops → flag re-arms with an advisory
    telem["rank1"]["mem_in_use"] = 10
    _observe(ctl, ranks, telem, 4.0)
    assert "rank1" not in pol.flagged


def test_sick_host_policy_needs_quorum_and_patience():
    pol = SickHostPolicy(patience=3, min_ranks=2)
    flags = []
    ctl = _controller_with(pol, flags)
    bad = {"rank0": {"mem_in_use": 99, "mem_limit": 100}}
    _observe(ctl, {}, bad, 0.0)  # one rank: below quorum
    assert not flags and not pol._strikes

"""§3.7 on-node processing: aggregate tallies, local→global master tree.
The paper validated 512-node runs — we simulate a 512-rank aggregation."""

import math
import random

import pytest

from repro.core.aggregate import (
    aggregate_tree,
    combine_aggregates,
    load_tally,
    merge_tallies,
    save_tally,
)
from repro.core.plugins.tally import ApiStat, Tally


def mk_tally(rank: int, calls: int = 10) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")  # 8 ranks per node
    t.processes.add(rank)
    t.threads.add((rank, 1))
    st_ = ApiStat()
    for i in range(calls):
        st_.add(1000 + rank + i)
    t.apis[("ust_repro", "train_step")] = st_
    s2 = ApiStat()
    s2.add(50 * (rank + 1))
    t.device_apis[("ust_kernel", "k")] = s2
    return t


def test_512_rank_tree_matches_flat_merge():
    ranks = 512
    tallies = [mk_tally(r) for r in range(ranks)]
    flat = Tally()
    for t in [mk_tally(r) for r in range(ranks)]:
        flat.merge(t)
    composite, stats = merge_tallies(tallies, fanout=32)
    key = ("ust_repro", "train_step")
    assert composite.apis[key].calls == flat.apis[key].calls == 512 * 10
    assert composite.apis[key].total_ns == flat.apis[key].total_ns
    assert composite.apis[key].min_ns == 1000
    assert len(composite.hostnames) == 64
    assert len(composite.processes) == 512
    assert stats.leaves == 512
    # 512 → 16 → 1 with fanout 32
    assert stats.depth == 2
    assert stats.messages == 511  # n-1 merges total, regardless of tree shape


@pytest.mark.parametrize("fanout", [2, 8, 32, 600])
def test_tree_shape_invariance(fanout):
    tallies = [mk_tally(r, calls=3) for r in range(100)]
    composite, stats = merge_tallies(tallies, fanout=fanout)
    assert composite.apis[("ust_repro", "train_step")].calls == 300
    assert stats.depth == max(1, math.ceil(math.log(100, fanout)))


def test_save_load_roundtrip(tmp_path):
    t = mk_tally(7)
    t.discarded = 5
    p = str(tmp_path / "r7.tally")
    nbytes = save_tally(t, p)
    assert nbytes < 4096  # "typically in the range of kilobytes" (§3.7)
    back = load_tally(p)
    assert back.to_obj() == t.to_obj()


def test_combine_aggregates_files(tmp_path):
    paths = []
    for r in range(16):
        p = str(tmp_path / f"rank{r}.tally")
        save_tally(mk_tally(r), p)
        paths.append(p)
    comp = combine_aggregates(paths)
    assert comp.apis[("ust_repro", "train_step")].calls == 160


def test_aggregate_tree_empty_raises():
    with pytest.raises(ValueError):
        aggregate_tree([], lambda a, b: a)


def test_property_tree_sum_invariant_hypothesis():
    """Aggregation result is independent of tree shape (monoid property).

    Property-based version; ``hypothesis`` is an optional dev dependency
    (requirements-dev.txt) — skipped when absent, with the seeded pure-pytest
    fallback below covering the same invariant.
    """
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=30, deadline=None)
    @hypothesis.given(
        ns=st.lists(st.integers(min_value=1, max_value=10_000), min_size=1, max_size=64),
        fanout=st.integers(min_value=2, max_value=16),
    )
    def prop(ns, fanout):
        total, stats = aggregate_tree(list(ns), lambda a, b: a + b, fanout=fanout)
        assert total == sum(ns)
        assert stats.messages == len(ns) - 1

    prop()


@pytest.mark.parametrize("seed", range(10))
def test_property_tree_sum_invariant_fallback(seed):
    """Pure-pytest fallback for the monoid invariant: seeded random lists and
    fanouts instead of hypothesis-generated ones."""
    rng = random.Random(seed)
    ns = [rng.randint(1, 10_000) for _ in range(rng.randint(1, 64))]
    fanout = rng.randint(2, 16)
    total, stats = aggregate_tree(list(ns), lambda a, b: a + b, fanout=fanout)
    assert total == sum(ns)
    assert stats.messages == len(ns) - 1

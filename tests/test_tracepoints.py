"""Tracepoint codegen (THAPI §3.3) — generated recorders and unpackers must
be exact inverses for every event schema, including varlen str/bytes fields
and meta-parameter-derived out fields."""

import struct

import pytest
from tests.hypothesis_optional import given, settings, st

from repro.core.api_model import (
    APIModel,
    APISpec,
    P,
    build_trace_model,
    builtin_trace_model,
)
from repro.core.ringbuffer import RECORD_HEADER, RingRegistry
from repro.core.tracepoints import Tracepoints, codegen_recorder


def drain_records(registry):
    out = []
    for ring in registry.rings():
        blob = ring.drain()
        off = 0
        while off < len(blob):
            total, eid, ts = RECORD_HEADER.unpack_from(blob, off)
            out.append((eid, ts, blob[off + RECORD_HEADER.size : off + total]))
            off += total
    return out


@pytest.fixture()
def model():
    return build_trace_model(
        [
            APIModel(
                provider="ust_test",
                apis=(
                    APISpec(
                        "mix",
                        params=(P("a", "u32"), P("s", "str"), P("b", "u64"), P("blob", "bytes"), P("f", "f64")),
                        result=P("rc", "i32"),
                        meta=(("OutScalar", P("out", "f32")),),
                    ),
                    APISpec("spanny", params=(P("n", "u64"),), span=True),
                ),
            )
        ]
    )


def test_builtin_model_events_dense_and_named():
    m = builtin_trace_model()
    names = [e.name for e in m.events]
    assert names[0] == "ctf:events_discarded"
    assert "ust_jaxrt:memcpy_entry" in names
    assert "ust_kernel:launch_span" in names
    assert "ust_thapi:sample" in names
    assert len(set(names)) == len(names)
    for i, e in enumerate(m.events):
        assert e.eid == i


def test_roundtrip_mixed_fields(model):
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 16, pid=1)
    tp.attach(reg, range(len(model.events)))
    tp.record["ust_test:mix_entry"](7, "héllo", 2**40, b"\x00\xff", 3.25)
    tp.record["ust_test:mix_exit"](-3, 1.5)
    tp.record["ust_test:spanny_span"](100, 250, 2**33)
    recs = drain_records(reg)
    assert len(recs) == 3
    by_eid = {e.eid: e for e in model.events}
    eid, ts, payload = recs[0]
    assert by_eid[eid].name == "ust_test:mix_entry"
    vals = tp.unpack[eid](memoryview(payload))
    assert vals == (7, "héllo", 2**40, b"\x00\xff", 3.25)
    eid, _, payload = recs[1]
    assert tp.unpack[eid](memoryview(payload)) == (-3, 1.5)
    eid, _, payload = recs[2]
    assert tp.unpack[eid](memoryview(payload)) == (100, 250, 2**33)


def test_disabled_event_records_nothing(model):
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 16, pid=1)
    entry_eid = model.by_name()["ust_test:mix_entry"].eid
    tp.attach(reg, [e.eid for e in model.events if e.eid != entry_eid])
    tp.record["ust_test:mix_entry"](1, "x", 2, b"", 0.0)
    tp.record["ust_test:mix_exit"](0, 0.0)
    recs = drain_records(reg)
    assert len(recs) == 1  # only the exit


def test_detach_makes_recorders_noop(model):
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 16, pid=1)
    tp.attach(reg, range(len(model.events)))
    tp.detach()
    tp.record["ust_test:mix_exit"](0, 0.0)  # must not raise, must not write
    assert drain_records(reg) == []


def test_codegen_source_structure(model):
    ev = model.by_name()["ust_test:mix_entry"]
    src = codegen_recorder(ev)
    assert f"_e[{ev.eid}]" in src
    # reserve variant: pack_into directly into ring storage, helpers as defaults
    assert src.startswith("def ust_test__mix_entry(a, s, b, blob, f, _e=_enabled")
    assert "pack_into" not in src  # bound methods ride in the _pk* defaults
    assert "_rb.reserve(_n)" in src and "_rb.commit(_n)" in src
    assert "_rb._lim" in src  # single-compare fast path
    # legacy variant keeps the historical bytes-build + write shape
    legacy = codegen_recorder(ev, reserve=False)
    assert "_rings.get().write(_H.pack(" in legacy
    assert "_rb.reserve" not in legacy


def test_meta_out_scalars_on_exit_schema(model):
    exit_ev = model.by_name()["ust_test:mix_exit"]
    assert [p.name for p in exit_ev.fields] == ["rc", "out"]  # result + OutScalar


@settings(max_examples=60, deadline=None)
@given(
    a=st.integers(min_value=0, max_value=2**32 - 1),
    s=st.text(max_size=40),
    b=st.integers(min_value=0, max_value=2**64 - 1),
    blob=st.binary(max_size=64),
    f=st.floats(allow_nan=False, allow_infinity=False, width=64),
)
def test_property_roundtrip(a, s, b, blob, f):
    model = build_trace_model(
        [
            APIModel(
                provider="ust_p",
                apis=(
                    APISpec(
                        "m",
                        params=(P("a", "u32"), P("s", "str"), P("b", "u64"), P("blob", "bytes"), P("f", "f64")),
                    ),
                ),
            )
        ]
    )
    tp = Tracepoints(model)
    reg = RingRegistry(1 << 16, pid=1)
    tp.attach(reg, range(len(model.events)))
    tp.record["ust_p:m_entry"](a, s, b, blob, f)
    (eid, _, payload), = drain_records(reg)
    assert tp.unpack[eid](memoryview(payload)) == (a, s, b, blob, f)

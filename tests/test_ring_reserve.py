"""Reserve/commit ring protocol tests (the zero-allocation collection path).

The contracts under test:

  * ``RingBuffer.reserve``/``commit`` frame records byte-identically to the
    legacy ``write()`` path — across event schemas, varlen payloads, wrap
    boundaries (scratch staging) and full-ring drops;
  * ``drain_view``/``release`` expose the committed region zero-copy without
    ever letting the producer overwrite unread bytes;
  * the generated reserve-mode recorders survive a threaded SPSC stress run
    crossing many wrap boundaries with no torn or reordered records;
  * fused pair recorders emit the same bytes as the two single recorders,
    fall back cleanly when enablement splits the pair, and drop atomically;
  * ``iprof tally`` over a reserve/commit trace equals a legacy-path trace.

Property-based when hypothesis is installed, seeded-loop fallback otherwise
(mirroring tests/test_fold.py).
"""

import os
import random
import threading

from repro.core.api_model import APIModel, APISpec, P, build_trace_model
from repro.core.clock import ClockInfo
from repro.core.ctf import StreamWriter, write_metadata
from repro.core.iprof import main as iprof
from repro.core.ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE, RingBuffer, RingRegistry
from repro.core.tracepoints import Tracepoints
from repro.core.tracer import TraceConfig, Tracer
from tests.hypothesis_optional import given, settings, st

_MODEL = build_trace_model(
    [
        APIModel(
            provider="ust_r",
            apis=(
                APISpec(
                    "mix",
                    params=(P("a", "u32"), P("s", "str"), P("b", "u64"), P("blob", "bytes")),
                    result=P("rc", "i32"),
                ),
                APISpec("fixed", params=(P("x", "u64"), P("y", "u32")), result=P("rc", "u32")),
                APISpec("seq", params=(P("n", "u64"), P("fill", "bytes")), result=P("rc", "u32")),
                APISpec("launch", params=(P("name", "str"), P("flops", "u64")), span=True),
            ),
        )
    ]
)


def frame(eid, ts, payload):
    return RECORD_HEADER.pack(RECORD_HEADER_SIZE + len(payload), eid, ts) + payload


def unframe(blob):
    out = []
    off = 0
    while off < len(blob):
        total, eid, ts = RECORD_HEADER.unpack_from(blob, off)
        out.append((eid, ts, bytes(blob[off + RECORD_HEADER_SIZE : off + total])))
        off += total
    return out


def ticking_clock(start=1000, step=7):
    c = [start]

    def clock():
        c[0] += step
        return c[0]

    return clock


# ---------------------------------------------------------------------------
# RingBuffer.reserve/commit unit behavior
# ---------------------------------------------------------------------------


def test_reserve_commit_roundtrip_matches_write():
    a, b = RingBuffer(1 << 10), RingBuffer(1 << 10)
    for i in range(1, 30):
        rec = frame(i % 5, i, bytes([i]) * (i % 17))
        assert b.write(rec)
        off = a.reserve(len(rec))
        assert off >= 0
        a.wbuf[off : off + len(rec)] = rec
        a.commit(len(rec))
    assert a.drain() == b.drain()


def test_reserve_wrap_goes_through_scratch():
    rb = RingBuffer(1 << 8)
    rec = frame(1, 1, b"q" * 50)
    n = len(rec)
    seen = []
    for i in range(40):  # many wraps through the 256-byte ring
        off = rb.reserve(n)
        assert off >= 0
        staged = rb.wbuf is not rb._buf
        if staged:  # wrap path: the reusable scratch buffer
            assert off == 0
        rb.wbuf[off : off + n] = rec
        rb.commit(n)
        assert rb.wbuf is rb._buf  # invariant restored after commit
        seen.extend(unframe(rb.drain()))
    assert len(seen) == 40
    assert all(payload == b"q" * 50 for _, _, payload in seen)


def test_reserve_drop_when_full_and_lim_recovers():
    rb = RingBuffer(1 << 8)
    rec = frame(2, 0, b"z" * 40)
    n = len(rec)
    written = 0
    while True:
        off = rb.reserve(n)
        if off < 0:
            break
        rb.wbuf[off : off + n] = rec
        rb.commit(n)
        written += 1
    assert rb.dropped == 1
    assert rb.reserve(n) < 0 and rb.dropped == 2  # discard mode: counted, not blocked
    rb.drain()
    assert rb.reserve(n) >= 0  # space released → reservations resume
    rb.commit(n)
    assert rb.reserve(len(frame(0, 0, b"x" * 300))) < 0  # bigger than capacity


def test_drain_view_zero_copy_and_release():
    rb = RingBuffer(1 << 8)
    r1 = frame(1, 10, b"abc")
    rb.write(r1)
    regions = rb.drain_view()
    assert len(regions) == 1
    assert bytes(regions[0]) == r1
    assert rb.used == len(r1)  # not yet released
    rb.release()
    assert rb.used == 0
    assert rb.drain_view() == ()


def test_drain_view_wrap_returns_two_regions():
    rb = RingBuffer(1 << 8)
    filler = frame(1, 1, b"f" * 100)
    rb.write(filler)
    rb.drain()
    rec = frame(2, 2, b"w" * 180)  # straddles the 256-byte boundary
    assert rb.write(rec)
    regions = rb.drain_view()
    assert len(regions) == 2
    assert b"".join(regions) == rec
    rb.release()
    assert rb.used == 0


def test_release_guard_against_drain_mix():
    rb = RingBuffer(1 << 8)
    rb.write(frame(1, 1, b"a"))
    rb.drain_view()
    rb.write(frame(1, 2, b"b"))
    rb.drain()  # consumed past the snapshot
    rb.release()  # must not rewind tail
    assert rb.used == 0


# ---------------------------------------------------------------------------
# Generated recorders: reserve path == legacy path, byte for byte
# ---------------------------------------------------------------------------


def _drive(ring_reserve, seed, cap, clock):
    """Run a seeded op mix through one path; return (stream bytes, drops, events)."""
    rng = random.Random(seed)
    tp = Tracepoints(_MODEL, clock=clock)
    reg = RingRegistry(cap, pid=1)
    tp.attach(reg, range(len(_MODEL.events)), ring_reserve=ring_reserve)
    mix = tp.record["ust_r:mix_entry"]
    mix_x = tp.record["ust_r:mix_exit"]
    fixed = tp.record["ust_r:fixed_entry"]
    pair = tp.record_pair["ust_r:fixed"]
    span = tp.record["ust_r:launch_span"]
    out = []
    for i in range(rng.randrange(50, 250)):
        op = rng.randrange(0, 6)
        if op == 0:
            mix(i, "s" * rng.randrange(0, 40), 2**40 + i, bytes(rng.randrange(0, 60)))
        elif op == 1:
            mix_x(-i)
        elif op == 2:
            fixed(i, i * 2)
        elif op == 3:
            pair(i, i * 3, 777, i % 5)
        elif op == 4:
            span(i, i + 50, "k" * rng.randrange(0, 9), 99)
        else:
            for ring in reg.rings():
                out.append(ring.drain())
    for ring in reg.rings():
        out.append(ring.drain())
    tp.detach()
    return b"".join(out), reg.total_dropped, reg.total_events


def _assert_paths_identical(seed, cap, constant_clock):
    mk = (lambda: (lambda: 5_000)) if constant_clock else (lambda: ticking_clock())
    a, da, ea = _drive(True, seed, cap, mk())
    b, db, eb = _drive(False, seed, cap, mk())
    assert a == b, f"stream bytes diverged (seed={seed}, cap={cap})"
    assert (da, ea) == (db, eb)


def test_paths_identical_seeded():
    """Seeded fallback: ample ring + ticking clock (no drops) and tiny ring +
    constant clock (drops + wraps; constant because the legacy path consumes
    a clock tick building a record that then drops — timestamps of surviving
    records would diverge under a ticking fake clock)."""
    for seed in range(25):
        _assert_paths_identical(seed, 1 << 16, constant_clock=False)
        _assert_paths_identical(seed, 1 << 9, constant_clock=True)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000), tiny=st.booleans())
def test_property_paths_identical(seed, tiny):
    """Property: reserve/commit framing is byte-identical to legacy write()."""
    if tiny:
        _assert_paths_identical(seed, 1 << 9, constant_clock=True)
    else:
        _assert_paths_identical(seed, 1 << 16, constant_clock=False)


# ---------------------------------------------------------------------------
# Fused pair recorders
# ---------------------------------------------------------------------------


def test_pair_equals_two_singles():
    # singles consume one clock tick each; the pair takes the entry timestamp
    # as an argument and ticks once for the exit — same byte stream
    tp1 = Tracepoints(_MODEL, clock=ticking_clock())
    reg1 = RingRegistry(1 << 12, pid=1)
    tp1.attach(reg1, range(len(_MODEL.events)))
    tp1.record["ust_r:fixed_entry"](7, 8)
    tp1.record["ust_r:fixed_exit"](9)
    one = reg1.rings()[0].drain()

    clock = ticking_clock()
    ts_entry = clock()  # 1007: what the first single stamped
    tp2 = Tracepoints(_MODEL, clock=clock)
    reg2 = RingRegistry(1 << 12, pid=1)
    tp2.attach(reg2, range(len(_MODEL.events)))
    tp2.record_pair["ust_r:fixed"](7, 8, ts_entry, 9)
    two = reg2.rings()[0].drain()
    assert one == two


def test_pair_fallback_when_enablement_splits():
    tp = Tracepoints(_MODEL, clock=ticking_clock())
    reg = RingRegistry(1 << 12, pid=1)
    by = _MODEL.by_name()
    entry_eid, exit_eid = by["ust_r:fixed_entry"].eid, by["ust_r:fixed_exit"].eid
    tp.attach(reg, [e.eid for e in _MODEL.events if e.eid != exit_eid])
    tp.record_pair["ust_r:fixed"](1, 2, 500, 3)
    recs = unframe(reg.rings()[0].drain())
    assert [eid for eid, _, _ in recs] == [entry_eid]  # only the entry event
    # the fallback must preserve the caller's entry timestamp: disabling the
    # *exit* must not shift the entry stamp from pre-work to record time
    assert recs[0][1] == 500
    tp.set_event("ust_r:fixed_exit", True)
    tp.record_pair["ust_r:fixed"](1, 2, 500, 3)
    recs = unframe(reg.rings()[0].drain())
    assert [(eid, ts) for eid, ts, _ in recs][0] == (entry_eid, 500)
    assert recs[1][0] == exit_eid


def test_thread_ident_recycling_cannot_alias_rings():
    """CPython recycles thread idents: a new thread reusing a joined thread's
    ident must still get its own ring (the binding cache is per-thread
    storage, not ident-keyed)."""
    tp = Tracepoints(_MODEL, clock=ticking_clock())
    reg = RingRegistry(1 << 12, pid=1)
    tp.attach(reg, range(len(_MODEL.events)))
    rec = tp.record["ust_r:fixed_entry"]
    idents = []

    def worker(i):
        idents.append(threading.get_ident())
        rec(i, i)

    for i in range(4):  # sequential start/join: idents typically recycle
        t = threading.Thread(target=worker, args=(i,))
        t.start()
        t.join()
    assert len(reg.rings()) == 4  # one ring per thread, even on ident reuse
    per_ring = [unframe(r.drain()) for r in reg.rings()]
    assert all(len(rs) == 1 for rs in per_ring)
    tp.detach()


def test_pair_drop_is_atomic():
    tp = Tracepoints(_MODEL, clock=ticking_clock())
    reg = RingRegistry(1 << 6, pid=1)  # 64 bytes: pair (26 + 18 = 44) fits, big one not
    tp.attach(reg, range(len(_MODEL.events)))
    pair = tp.record_pair["ust_r:mix"]
    pair(1, "x" * 40, 2, b"y" * 30, 100, -1)  # entry alone exceeds capacity
    rb = reg.rings()[0]
    assert rb.used == 0 and rb.events == 0
    assert rb.dropped == 2  # both records of the pair accounted
    tp.record_pair["ust_r:fixed"](1, 2, 100, 3)  # small pair still fits
    assert rb.events == 2 and rb.dropped == 2


# ---------------------------------------------------------------------------
# Threaded SPSC stress across wrap boundaries
# ---------------------------------------------------------------------------


def test_threaded_spsc_stress_no_torn_records():
    """Producer thread on generated recorders + consumer on drain_view/release
    crossing many wrap boundaries: every surviving record arrives exactly
    once, well-framed, in order."""
    tp = Tracepoints(_MODEL)
    reg = RingRegistry(1 << 12, pid=1)  # 4 KiB: thousands of wraps
    tp.attach(reg, range(len(_MODEL.events)))
    rec = tp.record["ust_r:seq_entry"]
    N = 20_000
    chunks = []
    stop = threading.Event()
    ring_ready = threading.Event()

    def producer():
        for i in range(N):
            rec(i, b"x" * (i % 33))
            if i == 0:
                ring_ready.set()
        stop.set()

    def consumer():
        ring_ready.wait(5)
        ring = reg.rings()[0]
        while not stop.is_set() or ring.used:
            regions = ring.drain_view()
            if regions:
                chunks.append(b"".join(regions))
                ring.release()

    pt = threading.Thread(target=producer)
    ct = threading.Thread(target=consumer)
    pt.start(); ct.start()
    pt.join(); ct.join()
    ring = reg.rings()[0]
    chunks.append(b"".join(ring.drain_view()))
    ring.release()
    seq_eid = _MODEL.by_name()["ust_r:seq_entry"].eid
    unpack = tp.unpack[seq_eid]
    seqs = []
    for eid, _, payload in unframe(b"".join(chunks)):
        assert eid == seq_eid
        n, fill, _rc_absent = *unpack(memoryview(payload)), None
        assert fill == b"x" * (n % 33), "torn record"
        seqs.append(n)
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)  # in order, once
    assert len(seqs) + ring.dropped == N
    assert len(seqs) == ring.events
    tp.detach()


# ---------------------------------------------------------------------------
# Tracer consumer integration
# ---------------------------------------------------------------------------


def test_idle_thread_leaves_no_stream_file(tmp_path):
    out = str(tmp_path / "t")
    with Tracer(TraceConfig(out_dir=out, mode="default")) as tr:
        # a thread touches the registry (gets a ring) but never records
        th = threading.Thread(target=tr.registry.get)
        th.start(); th.join()
        tr.tp.record["ust_repro:data_next_entry"](1)
        tr.tp.record["ust_repro:data_next_exit"](0, 42)
    streams = [n for n in os.listdir(out) if n.endswith(".ctf")]
    assert len(streams) == 1  # only the producing thread's stream exists
    assert tr.handle.events == 2


def test_legacy_ring_reserve_escape_hatch(tmp_path):
    out = str(tmp_path / "t")
    with Tracer(TraceConfig(out_dir=out, mode="default", ring_reserve=False)) as tr:
        assert tr.tp.ring_reserve is False
        tr.tp.record["ust_repro:data_next_entry"](1)
        tr.tp.record["ust_repro:data_next_exit"](0, 42)
    assert tr.handle.events == 2
    assert iprof(["tally", out]) == 0


# ---------------------------------------------------------------------------
# iprof tally equality over reserve vs legacy traces
# ---------------------------------------------------------------------------


def _build_trace_dir(trace_dir, ring_reserve):
    os.makedirs(trace_dir, exist_ok=True)
    stream, dropped, _ = _drive(ring_reserve, seed=4242, cap=1 << 16, clock=ticking_clock())
    w = StreamWriter(os.path.join(trace_dir, "stream_1_100.ctf"), 1, 100)
    w.append(stream)
    if dropped:
        w.note_drops(dropped, 10_000)
    w.close()
    write_metadata(trace_dir, _MODEL, ClockInfo.capture(), env={}, mode="full")


def test_iprof_tally_identical_across_paths(tmp_path, capsys):
    a, b = str(tmp_path / "reserve"), str(tmp_path / "legacy")
    _build_trace_dir(a, ring_reserve=True)
    _build_trace_dir(b, ring_reserve=False)
    capsys.readouterr()
    assert iprof(["tally", a]) == 0
    out_a = capsys.readouterr().out
    assert iprof(["tally", b]) == 0
    out_b = capsys.readouterr().out
    assert out_a == out_b
    assert "UST_R" in out_a

"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
sharding rules, schedules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_optional import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.jaxcompat import make_abstract_mesh, make_mesh
from repro.data import DataConfig, SyntheticPipeline
from repro.models import Model, ShapeSpec
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    dequantize_int8,
    global_norm,
    quantize_int8,
    topk_sparsify,
    warmup_cosine,
)
from repro.optim.compression import topk_densify
from repro.sharding import Partitioner, logical_to_pspec


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def mk_pipe(**kw):
    m = Model(get_config("h2o-danube-1.8b").smoke())
    return SyntheticPipeline(m, ShapeSpec("t", "train", 16, 4), **kw)


def test_pipeline_deterministic_per_step():
    a, b = mk_pipe(), mk_pipe()
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_rank_disjoint():
    a = mk_pipe(dp_rank=0, dp_size=2)
    b = mk_pipe(dp_rank=1, dp_size=2)
    assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])
    assert a.local_batch == 2


def test_pipeline_state_restore_resumes_exactly():
    p = mk_pipe()
    next(p)
    next(p)
    state = p.state_dict()
    want = next(p)
    q = mk_pipe()
    q.load_state_dict(state)
    got = next(q)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_pipeline_prefetch_matches_sync():
    sync = mk_pipe()
    pre = mk_pipe().start()
    try:
        for _ in range(4):
            np.testing.assert_array_equal(next(sync)["tokens"], next(pre)["tokens"])
    finally:
        pre.stop()


def test_pipeline_labels_are_shifted_tokens():
    b = next(mk_pipe())
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_pipeline_frontend_stubs():
    m = Model(get_config("whisper-medium").smoke())
    p = SyntheticPipeline(m, ShapeSpec("t", "train", 16, 2))
    b = next(p)
    assert "frames" in b and b["frames"].shape[0] == 2
    mv = Model(get_config("llava-next-34b").smoke())
    pv = SyntheticPipeline(mv, ShapeSpec("t", "train", 16, 2))
    bv = next(pv)
    assert "patch_embeds" in bv
    assert bv["tokens"].shape[1] == 16 - mv.cfg.vision_tokens


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, 0.05, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_clips_gradients():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(g, state, params, 0.1, cfg)
    assert float(gnorm) == pytest.approx(200.0)  # reported pre-clip


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=1e-3)
    assert np.argmax(lrs) == 10
    assert lrs[-1] < 0.2


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=64), st.floats(min_value=0.01, max_value=100))
def test_property_int8_quantization_error_bound(n, scale):
    x = jnp.asarray(np.random.default_rng(n).normal(size=(4, n)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    maxerr = float(jnp.max(jnp.abs(back.reshape(x.shape) - x)))
    bound = float(jnp.max(s)) * 0.5 + 1e-6  # half an int8 step per row
    assert maxerr <= bound


def test_quantize_zero_tensor():
    q, s = quantize_int8(jnp.zeros((3, 5)))
    assert float(jnp.max(jnp.abs(dequantize_int8(q, s)))) == 0.0


def test_topk_roundtrip():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx = topk_sparsify(x, 2)
    dense = topk_densify(vals, idx, 5)
    np.testing.assert_allclose(np.asarray(dense), [0, -5.0, 0, 3.0, 0])


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_pspec_divisible(mesh11):
    mesh = make_mesh((1, 1), ("data", "model"))
    # with axis size 1, everything falls back to replication
    assert logical_to_pspec(("vocab", "embed"), (32000, 128), mesh) == P()


def test_pspec_nondivisible_falls_back():
    # simulate a 16-way model axis via an abstract mesh
    mesh = make_abstract_mesh((16,), ("model",))
    assert logical_to_pspec(("heads", None, None), (40, 1, 1), mesh) == P()  # 40 % 16 ≠ 0
    assert logical_to_pspec(("heads", None, None), (64, 1, 1), mesh) == P("model")


def test_pspec_batch_axes_multi_pod():
    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert logical_to_pspec(("batch", "seq"), (256, 4096), mesh) == P(("pod", "data"))
    # batch=1 cannot shard
    assert logical_to_pspec(("batch",), (1,), mesh) == P()


def test_pspec_no_axis_reuse():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    # both dims want "model": only the first gets it
    spec = logical_to_pspec(("mlp", "channels"), (1600, 1600), mesh)
    assert spec == P("model")


def test_fsdp_rules_shard_embed_over_data():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    part = Partitioner(mesh, fsdp=True)
    spec = part.pspec(("embed", "mlp"), (4096, 1600))
    assert spec == P("data", "model")

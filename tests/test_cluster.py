"""Cluster-scope adaptive control: the per-rank breakdown (retention through
delta/resync frames, ``query_ranks`` over a forwarder tree, by-rank
subscribe) and the policies that read it (StragglerRankPolicy,
RankImbalanceAdvisoryPolicy) — all clock-driven, no sleeps in the policy
tests."""

import socket
import time

import pytest

from repro.core.adaptive import (
    ClusterAdaptiveController,
    ClusterContext,
    ClusterPolicy,
    RankImbalanceAdvisoryPolicy,
    StragglerRankPolicy,
    build_cluster_controller,
)
from repro.core.aggregate import merge_tallies
from repro.core.plugins.tally import ApiStat, Tally, render_by_rank
from repro.core.stream import MasterServer, SnapshotStreamer, StreamClient


def mk_tally(rank: int, calls: int = 10, ns: int = 1000) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 1))
    st = ApiStat()
    for _ in range(calls):
        st.add(ns)
    t.apis[("ust_repro", "train_step")] = st
    return t


def grow(t: Tally, calls: int, ns: int = 1000) -> Tally:
    for _ in range(calls):
        t.apis[("ust_repro", "train_step")].add(ns)
    return t


def totals(t: Tally):
    out = {}
    for label, table in (("host", t.apis), ("device", t.device_apis)):
        for key, st in table.items():
            out[(label,) + key] = (st.calls, st.total_ns)
    return out


def wait_until(pred, timeout_s=5.0, period_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period_s)
    return pred()


# ---------------------------------------------------------------------------
# Per-rank retention at a single master
# ---------------------------------------------------------------------------


def test_master_retains_per_rank_across_delta_frames():
    """The stored per-source map must track each sender's cumulative state
    exactly while deltas, not full snapshots, carry the updates."""
    with MasterServer(port=0) as m:
        streamers = {}
        tallies = {}
        for r in range(3):
            s = SnapshotStreamer(m.addr, source=f"rank{r}")
            t = mk_tally(r, calls=3 + r)
            assert s.push(t)
            streamers[r], tallies[r] = s, t
        for s in streamers.values():
            assert wait_until(lambda s=s: (s.poll_control() or True) and s.peer_version == 2)
        for _ in range(4):  # steady state: every update a delta
            for r, s in streamers.items():
                grow(tallies[r], calls=1, ns=100 * (r + 1))
                assert s.push(tallies[r])
        assert all(s.delta_frames >= 3 for s in streamers.values())
        assert wait_until(
            lambda: all(
                m.ranks().get(f"rank{r}", Tally()).to_obj() == tallies[r].to_obj()
                for r in range(3)
            )
        )
        for s in streamers.values():
            s.close()


def test_master_retains_per_rank_across_resync():
    """Master-side state loss on one source: resync heals that source's
    entry, the other sources' entries stay intact."""
    with MasterServer(port=0) as m:
        s0 = SnapshotStreamer(m.addr, source="rank0")
        s1 = SnapshotStreamer(m.addr, source="rank1")
        t0, t1 = mk_tally(0, calls=2), mk_tally(1, calls=5)
        assert s0.push(t0) and s1.push(t1)
        assert wait_until(lambda: (s0.poll_control() or True) and s0.peer_version == 2)
        grow(t0, 1)
        assert s0.push(t0)
        assert s0.delta_frames >= 1
        # simulate master losing rank0's state with the connection still up
        assert wait_until(lambda: len(m.ranks()) == 2)
        with m._lock:
            del m._latest["rank0"]
        grow(t0, 1)
        assert s0.push(t0)  # delta lands on no state → rejected → resync
        assert wait_until(lambda: (s0.poll_control() or True) and s0.resyncs >= 1)
        grow(t0, 1)
        assert s0.push(t0)  # forced full snapshot heals rank0
        assert wait_until(
            lambda: m.ranks().get("rank0", Tally()).to_obj() == t0.to_obj()
        )
        assert m.ranks()["rank1"].to_obj() == t1.to_obj()  # untouched bystander
        s0.close()
        s1.close()


def test_ranks_returns_defensive_copies():
    m = MasterServer(port=0)
    m.submit("r0", mk_tally(0))
    r1 = m.ranks()
    grow(r1["r0"], calls=50)  # mutating the copy must not corrupt the store
    assert m.ranks()["r0"].apis[("ust_repro", "train_step")].calls == 10


# ---------------------------------------------------------------------------
# query_ranks over the forwarder tree
# ---------------------------------------------------------------------------


def test_query_ranks_two_level_tree_matches_per_rank_truth():
    """rank → local master → global master: `query_ranks` at the root must
    equal the per-rank truth, and its merge must equal the composite."""
    truth = {}
    with MasterServer(port=0) as g:
        with MasterServer(port=0, forward_to=g.addr, forward_period_s=0.05) as l:
            for r in range(4):
                s = SnapshotStreamer(l.addr, source=f"rank{r}")
                t = mk_tally(r, calls=5 + r, ns=1000 + r)
                assert s.push(t)
                s.close()
                truth[f"rank{r}"] = t
            with StreamClient(g.addr) as c:
                assert wait_until(
                    lambda: set(c.ranks()[0]) == set(truth)
                    and all(
                        c.ranks()[0][k].to_obj() == truth[k].to_obj()
                        for k in truth
                    )
                )
                ranks, meta = c.ranks()
                assert meta["sources"] == 4
                assert set(meta["ts"]) == set(truth)
                # per-rank sums equal the merged composite, API for API
                comp, _ = c.composite()
            merged, _ = merge_tallies([Tally().merge(t) for t in ranks.values()])
            assert totals(merged) == totals(comp)
            assert merged.hostnames == comp.hostnames


def test_query_ranks_empty_master():
    with MasterServer(port=0) as m:
        with StreamClient(m.addr) as c:
            ranks, meta = c.ranks()
        assert ranks == {} and meta["sources"] == 0


def test_subscribe_by_rank_pushes_breakdown():
    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0, calls=3))
        m.submit("r1", mk_tally(1, calls=7))
        got = []
        with StreamClient(m.addr) as c:
            for t, meta in c.subscribe(period_s=0.05, by_rank=True):
                got.append((t, meta))
                if len(got) >= 2:
                    break
        ranks = got[0][1]["ranks"]
        assert set(ranks) == {"r0", "r1"}
        assert ranks["r0"].apis[("ust_repro", "train_step")].calls == 3
        assert ranks["r1"].apis[("ust_repro", "train_step")].calls == 7
        # heartbeat re-yields the cached breakdown
        assert got[1][1].get("unchanged") and set(got[1][1]["ranks"]) == {"r0", "r1"}


def test_render_by_rank_table():
    out = render_by_rank({"r0": mk_tally(0, calls=2), "r1": mk_tally(1, calls=8)})
    assert "2 ranks" in out and "r0" in out and "r1" in out
    assert "train_step" in out  # top API column
    lines = out.splitlines()
    assert lines[2].startswith("-")  # header separator
    # sorted by time: r1 (8 calls) first
    assert lines.index([l for l in lines if l.startswith("r1")][0]) < lines.index(
        [l for l in lines if l.startswith("r0")][0]
    )


def test_iprof_top_by_rank_poll_mode(capsys):
    from repro.core.iprof import main as iprof

    with MasterServer(port=0) as m:
        m.submit("rank0", mk_tally(0))
        m.submit("rank1", mk_tally(1))
        rc = iprof(["top", m.addr, "--by-rank", "--iterations", "1", "--no-clear"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "-- ranks --" in out and "rank0" in out and "rank1" in out
    assert "2 sources" in out


def test_iprof_top_by_rank_live_mode(capsys):
    from repro.core.iprof import main as iprof

    with MasterServer(port=0) as m:
        m.submit("rank0", mk_tally(0))
        rc = iprof(
            [
                "top",
                m.addr,
                "--live",
                "--by-rank",
                "--interval",
                "0.05",
                "--iterations",
                "2",
                "--no-clear",
            ]
        )
    out = capsys.readouterr().out
    assert rc == 0
    assert out.count("-- ranks --") == 2 and "rank0" in out


# ---------------------------------------------------------------------------
# Cluster controller + policies (explicit clocks, no sleeps)
# ---------------------------------------------------------------------------


def rank_map(latencies_ns, base=None, calls=5):
    """Synthetic per-rank map: each rank's train_step grew `calls` calls at
    its given latency since `base` (cumulative, the wire shape)."""
    out = {}
    for src, ns in latencies_ns.items():
        t = Tally().merge(base[src]) if base and src in base else mk_tally(0, calls=0)
        grow(t, calls=calls, ns=ns)
        out[src] = t
    return out


def test_straggler_rank_policy_fires_on_synthetic_slow_rank():
    flagged = []
    pol = StragglerRankPolicy(
        "ust_repro", "train_step", ratio=2.0, metric="latency", patience=2
    )
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    lat = {"r0": 1000, "r1": 1100, "r2": 900, "r3": 20_000}
    cur = rank_map(lat)
    assert not ctrl.observe(cur, now=0.0)  # baseline
    prev = cur
    cur = rank_map(lat, base=prev)
    assert ctrl.observe(cur, now=1.0)  # strike 1: patience not yet met
    assert not flagged and pol._strikes["r3"] == 1
    prev = cur
    cur = rank_map(lat, base=prev)
    assert ctrl.observe(cur, now=2.0)  # strike 2: flag fires
    assert len(flagged) == 1
    source, provider, api, ratio, reason = flagged[0]
    assert source == "r3" and (provider, api) == ("ust_repro", "train_step")
    assert ratio == pytest.approx(20_000 / 1050, rel=0.01)  # vs cluster median
    assert "median" in reason
    acts = [a for a in ctrl.actions if a.knob == "straggler:r3"]
    assert acts and "train_step" in acts[0].value
    # flag fires once, not every window
    prev = cur
    cur = rank_map(lat, base=prev)
    ctrl.observe(cur, now=3.0)
    assert len(flagged) == 1


def test_straggler_rank_policy_recovery_rearms():
    flagged = []
    pol = StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=1)
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    slow = {"r0": 1000, "r1": 1000, "r2": 30_000}
    healthy = {"r0": 1000, "r1": 1000, "r2": 1000}
    cur = rank_map(slow)
    ctrl.observe(cur, now=0.0)
    cur = rank_map(slow, base=cur)
    ctrl.observe(cur, now=1.0)
    assert len(flagged) == 1 and "r2" in pol.flagged
    cur = rank_map(healthy, base=cur)
    ctrl.observe(cur, now=2.0)  # recovery window
    assert "r2" not in pol.flagged
    assert any(a.value == "recovered" for a in ctrl.actions)
    cur = rank_map(slow, base=cur)
    ctrl.observe(cur, now=3.0)  # re-armed: lagging again re-flags
    assert len(flagged) == 2


def test_straggler_policy_needs_min_ranks_and_activity():
    """A rank idle in the window (no calls) is excluded; a single active
    rank can never be a straggler relative to itself."""
    flagged = []
    pol = StragglerRankPolicy("ust_repro", "train_step", ratio=1.5, patience=1)
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    cur = rank_map({"r0": 1000, "r1": 50_000})
    ctrl.observe(cur, now=0.0)
    # only r1 active this window: r0's tally did not move
    nxt = {"r0": Tally().merge(cur["r0"]), "r1": grow(Tally().merge(cur["r1"]), 5, 50_000)}
    ctrl.observe(nxt, now=1.0)
    assert not flagged


def test_straggler_streak_broken_by_idle_window():
    """'patience consecutive windows' means consecutive: a window where the
    lagging rank is idle (or the cluster lacks a quorum) resets its strikes."""
    flagged = []
    pol = StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=2)
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    slow = {"r0": 1000, "r1": 1000, "r2": 30_000}
    cur = rank_map(slow)
    ctrl.observe(cur, now=0.0)
    cur = rank_map(slow, base=cur)
    ctrl.observe(cur, now=1.0)  # strike 1
    assert pol._strikes["r2"] == 1
    # r2 idle this window: only r0/r1 move
    idle = {
        "r0": grow(Tally().merge(cur["r0"]), 5, 1000),
        "r1": grow(Tally().merge(cur["r1"]), 5, 1000),
        "r2": Tally().merge(cur["r2"]),
    }
    ctrl.observe(idle, now=2.0)
    assert pol._strikes.get("r2", 0) == 0  # streak broken
    cur = rank_map(slow, base=idle)
    ctrl.observe(cur, now=3.0)  # strike 1 again — patience 2 not met
    assert not flagged
    cur = rank_map(slow, base=cur)
    ctrl.observe(cur, now=4.0)  # strike 2: now it fires
    assert len(flagged) == 1


def test_flag_rearms_after_idle_so_new_excursion_reports():
    """A flagged rank that goes idle ends its excursion: when it resumes
    and lags again, the new excursion must be reported again."""
    flagged = []
    pol = StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=1)
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    slow = {"r0": 1000, "r1": 1000, "r2": 30_000}
    cur = rank_map(slow)
    ctrl.observe(cur, now=0.0)
    cur = rank_map(slow, base=cur)
    ctrl.observe(cur, now=1.0)
    assert len(flagged) == 1 and "r2" in pol.flagged
    # r2 idle: excursion over, flag re-arms without a recovery window
    idle = {
        "r0": grow(Tally().merge(cur["r0"]), 5, 1000),
        "r1": grow(Tally().merge(cur["r1"]), 5, 1000),
        "r2": Tally().merge(cur["r2"]),
    }
    ctrl.observe(idle, now=2.0)
    assert "r2" not in pol.flagged
    cur = rank_map(slow, base=idle)
    ctrl.observe(cur, now=3.0)  # lagging again: second excursion reported
    assert len(flagged) == 2


def test_subscribe_by_rank_frame_internally_consistent():
    """Invariant 7 inside one frame: the pushed ranks map merges to exactly
    the pushed composite."""
    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0, calls=3))
        m.submit("r1", mk_tally(1, calls=7))
        msg = m._composite_msg(by_rank=True)
        ranks = {s: Tally.from_obj(o) for s, o in msg["ranks"].items()}
        merged, _ = merge_tallies([Tally().merge(t) for t in ranks.values()])
        assert merged.to_obj() == Tally.from_obj(msg["tally"]).to_obj()


def test_new_rank_baselines_not_flagged():
    """A rank joining mid-run must not have its whole cumulative history
    (jit compiles included) counted as one window — no false flag."""
    flagged = []
    pol = StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=1)
    ctrl = ClusterAdaptiveController(
        [pol], on_straggler=lambda *a: flagged.append(a), clock=lambda: 0.0
    )
    lat = {"r0": 1000, "r1": 1100}
    cur = rank_map(lat)
    ctrl.observe(cur, now=0.0)
    # r2 appears with a huge compile-heavy cumulative tally
    nxt = rank_map(lat, base=cur)
    nxt["r2"] = mk_tally(2, calls=3, ns=500_000)
    ctrl.observe(nxt, now=1.0)
    assert not flagged  # r2 baselined, not judged on its history
    # from its next window on, r2 is judged on fresh activity only
    fin = rank_map({**lat, "r2": 1200}, base=nxt)
    ctrl.observe(fin, now=2.0)
    assert not flagged


def test_tick_backoff_applies_to_failed_fetches():
    """An unreachable master is retried once per period_s, not once per
    caller iteration — the consumer/decode loop must not stall every pass."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    clock = {"t": 0.0}
    fetches = []
    ctrl = ClusterAdaptiveController(
        [], addr=f"127.0.0.1:{port}", period_s=1.0, timeout_s=0.2,
        clock=lambda: clock["t"],
    )
    orig = ctrl._fetch
    ctrl._fetch = lambda: fetches.append(clock["t"]) or orig()
    for t in (0.0, 0.1, 0.2, 1.5, 1.6):
        clock["t"] = t
        ctrl.tick()
    assert fetches == [0.0, 1.5]  # one attempt per period, failures included


def test_forward_ranks_flush_skips_clean_sources():
    """Per-source dirty tracking: a flush after one source updated pushes
    only that source's frame upstream."""
    with MasterServer(port=0) as g:
        with MasterServer(port=0, forward_to=g.addr, forward_period_s=30) as l:
            l.submit("r0", mk_tally(0, calls=3))
            l.submit("r1", mk_tally(1, calls=4))
            assert l.flush(force=True)
            base_pushed = l.forwarder.pushed
            t = mk_tally(0, calls=9)
            l.submit("r0", t)  # only r0 moves
            assert l.flush()
            assert l.forwarder.pushed == base_pushed + 1  # r1 not re-sent
            assert not l.flush()  # nothing dirty: no-op
            assert wait_until(
                lambda: g.ranks().get("r0", Tally()).to_obj() == t.to_obj()
            )


def test_rank_imbalance_advisory_hysteresis():
    pol = RankImbalanceAdvisoryPolicy("ust_repro", "train_step", high=2.0, low=1.2)
    ctrl = ClusterAdaptiveController([pol], clock=lambda: 0.0)
    skewed = {"r0": 500, "r1": 600, "r2": 10_000}
    flat = {"r0": 1000, "r1": 1000, "r2": 1000}
    cur = rank_map(skewed)
    ctrl.observe(cur, now=0.0)
    cur = rank_map(skewed, base=cur)
    ctrl.observe(cur, now=1.0)
    highs = [a for a in ctrl.actions if a.value == "high"]
    assert len(highs) == 1 and highs[0].knob == "imbalance:ust_repro:train_step"
    cur = rank_map(skewed, base=cur)
    ctrl.observe(cur, now=2.0)  # still high: no duplicate advisory
    assert len([a for a in ctrl.actions if a.value == "high"]) == 1
    cur = rank_map(flat, base=cur)
    ctrl.observe(cur, now=3.0)
    assert any(a.value == "low" for a in ctrl.actions)


def test_cluster_context_metrics():
    prev = {"r0": mk_tally(0, calls=10, ns=1000), "r1": mk_tally(1, calls=10, ns=1000)}
    cur = {
        "r0": grow(Tally().merge(prev["r0"]), calls=4, ns=1000),
        "r1": grow(Tally().merge(prev["r1"]), calls=2, ns=9000),
        "r2": mk_tally(2, calls=3, ns=500),  # appeared mid-run
    }
    ctx = ClusterContext(ClusterAdaptiveController([]), prev, cur, window_s=2.0)
    assert ctx.rank_ids() == ["r0", "r1", "r2"]
    assert ctx.window("r0", "ust_repro", "train_step") == (4, 4000)
    # r2 joined mid-run: its cumulative history (compiles included) is not a
    # window — it baselines now and contributes from the next observation
    assert ctx.window("r2", "ust_repro", "train_step") == (0, 0)
    assert ctx.window("r9", "ust_repro", "train_step") == (0, 0)
    assert ctx.latency_ns("r1", "ust_repro", "train_step") == 9000
    assert ctx.busy_fraction("r1", "ust_repro", "train_step") == pytest.approx(
        18_000 / 2e9
    )
    lat = ctx.latency_by_rank("ust_repro", "train_step")
    assert lat == {"r0": 1000.0, "r1": 9000.0}  # r2 baselining, excluded
    skew = ctx.skew_by_rank("ust_repro", "train_step")
    assert skew["r1"] == pytest.approx(9000.0 / 5000.0)  # vs median of r0/r1
    assert ctx.skew_by_rank("ust_repro", "nothing") == {}


def test_cluster_controller_ticks_from_in_process_master_with_clock():
    """tick() against a live (socketless) MasterServer store, clock-driven:
    rate limiting and window math use the injected clock only."""
    clock = {"t": 0.0}
    flagged = []
    m = MasterServer(port=0)  # not started: pure in-process state store
    ctrl = ClusterAdaptiveController(
        [StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=1)],
        master=m,
        period_s=1.0,
        on_straggler=lambda *a: flagged.append(a),
        clock=lambda: clock["t"],
    )
    lat = {"r0": 1000, "r1": 1000, "r2": 25_000}
    state = rank_map(lat)
    for src, t in state.items():
        m.submit(src, Tally().merge(t))
    assert not ctrl.tick()  # baseline
    assert not ctrl.tick()  # rate-limited: clock has not advanced
    state = rank_map(lat, base=state)
    for src, t in state.items():
        m.submit(src, Tally().merge(t))
    clock["t"] = 1.5
    assert ctrl.tick()
    assert flagged and flagged[0][0] == "r2"


def test_policy_exception_isolated():
    class Exploding(ClusterPolicy):
        name = "exploding"

        def tick(self, ctx):
            raise RuntimeError("boom")

    survivor = RankImbalanceAdvisoryPolicy("ust_repro", "train_step", high=1.5)
    ctrl = ClusterAdaptiveController([Exploding(), survivor], clock=lambda: 0.0)
    skewed = rank_map({"r0": 500, "r1": 10_000})
    ctrl.observe(skewed, now=0.0)
    ctrl.observe(rank_map({"r0": 500, "r1": 10_000}, base=skewed), now=1.0)
    assert any(a.policy == "rank-imbalance" for a in ctrl.actions)


def test_build_cluster_controller_normalization():
    ctrl = ClusterAdaptiveController([], period_s=0.2)
    assert build_cluster_controller(ctrl) is ctrl
    assert build_cluster_controller(None) is None
    built = build_cluster_controller(
        [StragglerRankPolicy("p", "a")], period_s=0.7
    )
    assert isinstance(built, ClusterAdaptiveController) and built.period_s == 0.7


def test_straggler_policy_rejects_unknown_metric():
    with pytest.raises(ValueError):
        StragglerRankPolicy("p", "a", metric="vibes")


def test_cluster_controller_fetch_unreachable_addr_is_quiet():
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    ctrl = ClusterAdaptiveController(
        [], addr=f"127.0.0.1:{port}", timeout_s=0.2, clock=lambda: 0.0
    )
    assert not ctrl.tick()  # master absent: adaptation pauses, never raises


# ---------------------------------------------------------------------------
# Tracer + trainer wiring
# ---------------------------------------------------------------------------


def test_traceconfig_cluster_adaptive_requires_serve_port(tmp_path):
    from repro.core import TraceConfig

    with pytest.raises(ValueError):
        TraceConfig(
            out_dir=str(tmp_path),
            cluster_adaptive=[StragglerRankPolicy("ust_repro", "train_step")],
        )


def test_tracer_ticks_cluster_controller_and_records_advisory(tmp_path):
    """End to end inside one process: a serve_port session ingests two fake
    remote ranks, the cluster controller (clock-driven) flags the slow one,
    the advisory lands in this session's trace, and the trainer-layer
    watchdog receives the evidence."""
    from repro.core import TraceConfig, Tracer
    from repro.core.babeltrace import CTFSource
    from repro.train import StragglerWatchdog

    clock = {"t": 0.0}
    watchdog = StragglerWatchdog()
    ctrl = ClusterAdaptiveController(
        [StragglerRankPolicy("ust_repro", "train_step", ratio=2.0, patience=1)],
        period_s=0.0,  # every consumer tick; windows advance via the clock
        on_straggler=watchdog.note_api_evidence,
        clock=lambda: clock["t"],
    )
    cfg = TraceConfig(
        out_dir=str(tmp_path / "t"),
        mode="default",
        serve_port=0,
        cluster_adaptive=ctrl,
        flush_period_s=0.01,
    )
    lat = {"rankA": 1000, "rankB": 1000, "rankC": 40_000}
    with Tracer(cfg) as tr:
        assert tr.cluster is ctrl and ctrl.master is tr.server
        state = rank_map(lat)
        for src, t in state.items():
            tr.server.submit(src, Tally().merge(t))
        assert wait_until(lambda: ctrl._prev is not None)  # baseline consumed
        state = rank_map(lat, base=state)
        for src, t in state.items():
            tr.server.submit(src, Tally().merge(t))
        clock["t"] = 1.0
        assert wait_until(lambda: len(watchdog.api_reports()) >= 1)
    rep = watchdog.api_reports()[0]
    assert rep.source == "rankC" and rep.api == "train_step" and rep.ratio > 2.0
    advisories = [
        ev for ev in CTFSource(tr.handle.trace_dir) if ev.name == "ust_repro:advisory"
    ]
    assert advisories and advisories[0].fields[0] == "straggler-rank"
    assert "straggler:rankC" in advisories[0].fields[1]


def test_straggler_watchdog_ewma_and_api_channels():
    from repro.train import StragglerWatchdog

    w = StragglerWatchdog(factor=3.0)
    assert not w.observe_step(1.0)  # first step seeds the EWMA
    assert not w.observe_step(1.1)
    assert w.observe_step(10.0)  # > 3x EWMA
    assert w.slow_steps == 1
    w.note_api_evidence("host:1:rank2", "ust_repro", "train_step", 3.4, "test")
    reps = w.api_reports()
    assert len(reps) == 1 and reps[0].source == "host:1:rank2"
    assert reps[0].ratio == pytest.approx(3.4)

"""Chaos end-to-end: fault-injected multi-process runs through the closed
remediation loop (``pytest -m chaos``).

Each test launches ``examples/distributed_train.py --chaos`` as a real
subprocess tree: a driver with a MasterServer + ClusterAdaptiveController +
RemediationEngine, and N streaming worker processes with a seeded
FaultInjector.  The example self-verifies (work conservation, live == offline
per rank, every decision traced, ladder order) and exits non-zero on any
failure — the assertions here pin the headline invariants to stdout so a
regression reads as a specific missing line, not just "exit 1"."""

import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.chaos, pytest.mark.slow]

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLE = os.path.join(REPO_ROOT, "examples", "distributed_train.py")


def run_chaos(*extra, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    proc = subprocess.run(
        [
            sys.executable,
            EXAMPLE,
            "--chaos",
            "--chaos-ranks", "3",
            "--chaos-steps", "25",
            "--chaos-seed", "0",
            *extra,
        ],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, (
        f"chaos run failed (exit {proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
    )
    return proc.stdout


def test_chaos_slowdown_walks_full_ladder():
    out = run_chaos("--inject-fault", "slowdown:rank=1,after=5,factor=8")
    assert "OK: ladder walked" in out
    assert "(drain before evict)" in out
    assert "steps re-dealt" in out
    assert "every one traced" in out
    assert "steps completed = 3 ranks" in out  # work conserved across eviction
    assert "FAIL" not in out


def test_chaos_kill_recovers_from_checkpoint():
    out = run_chaos("--inject-fault", "kill:rank=2,after=8")
    assert "OK: ladder walked" in out
    assert "steps re-dealt" in out  # the dead rank's remainder went to survivors
    assert "every one traced" in out
    # the killed rank never flushed an on-disk aggregate; the example must
    # notice and skip it rather than fail the live-vs-offline comparison
    assert "no offline aggregate (died mid-run), skipped" in out
    assert "FAIL" not in out


def test_chaos_dry_run_advises_without_touching():
    out = run_chaos(
        "--inject-fault", "slowdown:rank=0,after=3,factor=8", "--chaos-dry-run"
    )
    assert "OK: dry-run — full ladder advised, nothing touched" in out
    assert "every one traced" in out
    assert "[dry-run]" in out  # the advisory decisions themselves were printed
    assert "FAIL" not in out


def test_chaos_no_fault_baseline_is_quiet():
    out = run_chaos()
    assert "steps completed = 3 ranks" in out
    assert "0 remediation decisions" in out
    assert "FAIL" not in out


def test_chaos_kill_then_replace_elastic():
    """The elastic tentpole e2e: a killed rank is replaced, not evicted.

    The example self-verifies the full elastic story — replacement admitted
    within the remediation budget, final healthy rank count == N, work
    conservation through deal → splice → claw-back, live tally == offline
    fold per rank, and a zombie frame from the dead incarnation fenced with
    its poison row absent from the composite.  The tighter subprocess
    timeout is the hard per-test bound: a hung replacement spawn fails this
    test fast instead of stalling the whole chaos job."""
    out = run_chaos(
        "--chaos-replace",
        "--inject-fault", "kill:rank=1,after=8",
        "--chaos-timeout", "90",
        timeout=180,
    )
    assert "replace_admit" in out
    assert "(drain before replace, no eviction)" in out
    assert "3/3 ranks healthy at exit" in out
    assert "steps completed = 3 ranks" in out  # work conserved through splice
    assert "1 replacement admitted" in out
    assert "zombie fenced (fence_rejects=" in out
    assert "poison row absent from the composite" in out
    assert "every one traced" in out
    assert "FAIL" not in out

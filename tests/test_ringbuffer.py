"""Ring buffer (LTTng collection layer) tests — THAPI §3.1 properties:
lockless SPSC operation, wrap-around correctness, discard (never block)."""

import struct
import threading

import pytest
from tests.hypothesis_optional import given, settings, st

from repro.core.ringbuffer import RECORD_HEADER, RingBuffer, RingRegistry


def frame(eid: int, ts: int, payload: bytes) -> bytes:
    return RECORD_HEADER.pack(RECORD_HEADER.size + len(payload), eid, ts) + payload


def unframe(blob: bytes):
    out = []
    off = 0
    while off < len(blob):
        total, eid, ts = RECORD_HEADER.unpack_from(blob, off)
        out.append((eid, ts, blob[off + RECORD_HEADER.size : off + total]))
        off += total
    return out


def test_capacity_must_be_pow2():
    with pytest.raises(ValueError):
        RingBuffer(1000)


def test_write_drain_roundtrip():
    rb = RingBuffer(1 << 12)
    recs = [frame(i, i * 10, bytes([i]) * i) for i in range(1, 20)]
    for r in recs:
        assert rb.write(r)
    got = unframe(rb.drain())
    assert [g[0] for g in got] == list(range(1, 20))
    assert rb.used == 0


def test_wraparound_preserves_records():
    rb = RingBuffer(1 << 8)  # tiny: force wraps
    seen = []
    for i in range(200):
        r = frame(i % 7, i, b"x" * (i % 23))
        if not rb.write(r):
            # full: drain and retry
            seen.extend(unframe(rb.drain()))
            assert rb.write(r)
        if i % 13 == 0:
            seen.extend(unframe(rb.drain()))
    seen.extend(unframe(rb.drain()))
    assert [ts for _, ts, _ in seen] == list(range(200))


def test_drop_on_full_never_blocks():
    rb = RingBuffer(1 << 8)
    r = frame(1, 0, b"y" * 40)
    writes = 0
    while rb.write(r):
        writes += 1
    assert rb.dropped == 1  # the terminating failed write
    for _ in range(5):
        assert not rb.write(r)
    assert rb.dropped == 6  # discard mode: counted, not blocked
    assert rb.events == writes


def test_record_larger_than_capacity_is_dropped():
    rb = RingBuffer(1 << 6)
    assert not rb.write(frame(1, 0, b"z" * 200))
    assert rb.dropped == 1


def test_concurrent_producer_consumer():
    rb = RingBuffer(1 << 14)
    N = 5000
    got = []
    stop = threading.Event()

    def consume():
        while not stop.is_set() or rb.used:
            got.extend(unframe(rb.drain()))

    t = threading.Thread(target=consume)
    t.start()
    dropped_before = rb.dropped
    sent = 0
    for i in range(N):
        if rb.write(frame(2, i, b"p" * 8)):
            sent += 1
    stop.set()
    t.join()
    got.extend(unframe(rb.drain()))
    # every non-dropped record arrives exactly once, in order
    ts = [g[1] for g in got]
    assert len(ts) == sent
    assert ts == sorted(ts)
    assert sent + rb.dropped - dropped_before == N


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=64), min_size=1, max_size=100))
def test_property_fifo_roundtrip(payloads):
    """Property: what goes in comes out, byte-identical and in order."""
    rb = RingBuffer(1 << 13)
    written = []
    for i, p in enumerate(payloads):
        if rb.write(frame(i % 100, i, p)):
            written.append((i % 100, i, p))
    got = [(e, t, bytes(p)) for e, t, p in unframe(rb.drain())]
    assert got == written


def test_registry_per_thread_rings():
    reg = RingRegistry(1 << 10, pid=123)
    rings = {}

    def worker(k):
        rb = reg.get()
        assert reg.get() is rb  # stable per thread
        rings[k] = rb
        rb.write(frame(k, k, b""))

    ths = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert len({id(r) for r in rings.values()}) == 4  # one ring per thread
    assert reg.total_events == 4
    assert reg.total_dropped == 0

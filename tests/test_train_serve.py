"""Trainer fault tolerance + serving engine integration tests."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_checkpoint
from repro.configs import get_config
from repro.jaxcompat import make_mesh
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer, TrainerConfig
from repro.train.train_step import build_train_artifacts, init_state


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def smoke_model(mesh):
    cfg = get_config("stablelm-3b").smoke()
    return Model(cfg, mesh)


SHAPE = ShapeSpec("t", "train", 32, 4)


def mk_trainer(smoke_model, mesh, tmp, steps=8, **kw):
    return Trainer(
        smoke_model,
        SHAPE,
        Partitioner(mesh),
        TrainConfig(peak_lr=5e-3, warmup=2, total_steps=100),
        TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=str(tmp), **kw),
    )


def test_loss_decreases(smoke_model, mesh, tmp_path):
    res = mk_trainer(smoke_model, mesh, tmp_path / "a", steps=10).run()
    assert res["steps_run"] == 10
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]
    assert all(np.isfinite(h["loss"]) for h in res["history"])


def test_checkpoint_resume_bitwise(smoke_model, mesh, tmp_path):
    """Run 8 steps straight vs 4 + restart + 4 — final loss must match."""
    r1 = mk_trainer(smoke_model, mesh, tmp_path / "one", steps=8).run()
    t2 = mk_trainer(smoke_model, mesh, tmp_path / "two", steps=4)
    t2.run()
    t3 = mk_trainer(smoke_model, mesh, tmp_path / "two", steps=8)
    r3 = t3.run()
    assert r3["steps_run"] == 4  # resumed from step 4
    assert r1["history"][-1]["loss"] == pytest.approx(r3["history"][-1]["loss"], rel=1e-5)


def test_trainer_recovers_from_transient_failure(smoke_model, mesh, tmp_path):
    t = mk_trainer(smoke_model, mesh, tmp_path / "f", steps=8)
    orig = t.step_fn
    calls = {"n": 0}

    class Flaky:
        def __call__(self, state, batch):
            calls["n"] += 1
            if calls["n"] == 6:
                raise RuntimeError("injected node failure")
            return orig(state, batch)

    t.step_fn = Flaky()
    res = t.run()
    assert res["failures"] == 1
    assert t.step == 8  # finished despite the fault


def test_trainer_straggler_callback_feeds_run_report(smoke_model, mesh, tmp_path):
    """The cluster-scope feedback channel: API-level straggler evidence
    delivered through `trainer.straggler_callback` (the ClusterAdaptive-
    Controller `on_straggler` hook) surfaces in the run result."""
    t = mk_trainer(smoke_model, mesh, tmp_path / "s", steps=4)
    t.straggler_callback(
        "host:7:rank3", "ust_repro", "train_step", 2.7, "2.70x cluster median"
    )
    res = t.run()
    assert res["steps_run"] == 4
    reps = res["straggler_reports"]
    assert len(reps) == 1
    assert reps[0].source == "host:7:rank3" and reps[0].api == "train_step"
    assert reps[0].ratio == pytest.approx(2.7)
    # the wall-clock EWMA channel still reports through the same watchdog
    assert res["straggler_steps"] == t.watchdog.slow_steps


def test_trainer_gives_up_after_max_failures(smoke_model, mesh, tmp_path):
    t = mk_trainer(smoke_model, mesh, tmp_path / "g", steps=8, max_failures=1)

    def always_fail(state, batch):
        raise RuntimeError("permanent failure")

    t.step_fn = always_fail
    with pytest.raises(RuntimeError, match="permanent"):
        t.run()


def test_checkpointer_integrity_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    path = ck.save(5, tree)
    # corrupt one leaf
    target = os.path.join(path, "b__c.npy")
    arr = np.load(target)
    arr[0, 0] = 777.0
    np.save(target, arr)
    with pytest.raises(ValueError, match="integrity"):
        ck.restore(path, tree)


def test_checkpointer_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_3", "step_4"]
    assert latest_checkpoint(str(tmp_path)).endswith("step_4")


def test_checkpointer_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, {"a": jnp.arange(4.0)}, extra={"data": {"step": 1}})
    ck.wait()
    restored, man = ck.restore(latest_checkpoint(str(tmp_path)), {"a": jnp.zeros(4)})
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(4.0))
    assert man.extra["data"]["step"] == 1


def test_microbatch_accumulation_matches_full_batch(mesh):
    """grad-accum over 2 microbatches ≈ one full-batch step."""
    cfg = get_config("stablelm-3b").smoke()
    model = Model(cfg, mesh)
    part = Partitioner(mesh)
    t_full = TrainConfig(peak_lr=1e-3, warmup=0, total_steps=10, microbatches=1)
    t_micro = TrainConfig(peak_lr=1e-3, warmup=0, total_steps=10, microbatches=2)
    step_f, *_ = build_train_artifacts(model, part, SHAPE, t_full)
    step_m, *_ = build_train_artifacts(model, part, SHAPE, t_micro)
    state_f = init_state(model, t_full, jax.random.PRNGKey(0))
    state_m = init_state(model, t_micro, jax.random.PRNGKey(0))
    from repro.data import SyntheticPipeline

    batch = {k: jnp.asarray(v) for k, v in next(SyntheticPipeline(model, SHAPE)).items()}
    sf, mf = step_f(state_f, batch)
    sm, mm = step_m(state_m, batch)
    assert float(mf["loss"]) == pytest.approx(float(mm["loss"]), rel=1e-4)
    wf = jax.tree_util.tree_leaves(sf["params"])[0]
    wm = jax.tree_util.tree_leaves(sm["params"])[0]
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wm), rtol=1e-3, atol=1e-5)


def test_grad_compression_step_still_learns(mesh, tmp_path):
    cfg = get_config("stablelm-3b").smoke()
    model = Model(cfg, mesh)
    t = Trainer(
        model,
        SHAPE,
        Partitioner(mesh),
        TrainConfig(peak_lr=5e-3, warmup=2, total_steps=100, grad_compression=True),
        TrainerConfig(steps=8, ckpt_every=100, ckpt_dir=None),
    )
    res = t.run()
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_engine_batched_decode(smoke_model):
    params = smoke_model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(smoke_model, params, ServeConfig(batch_slots=3, cache_len=40, max_new_tokens=6))
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, smoke_model.cfg.vocab_size, size=(10,))) for _ in range(5)]
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(0 <= t < smoke_model.cfg.vocab_size for r in done for t in r.out_tokens)


def test_engine_matches_sequential_decode(smoke_model):
    """Batched engine output for one request == naive prefill+decode loop."""
    params = smoke_model.init(jax.random.PRNGKey(0))
    prompt = np.arange(10) % smoke_model.cfg.vocab_size
    eng = ServeEngine(smoke_model, params, ServeConfig(batch_slots=2, cache_len=40, max_new_tokens=4))
    r = eng.submit(prompt)
    eng.run_until_drained()
    # naive reference
    logits, cache = smoke_model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, 40)
    toks = [int(jnp.argmax(logits[0, 0, : smoke_model.cfg.vocab_size]))]
    for _ in range(3):
        logits, cache = smoke_model.decode_step(
            params, cache, {"token": jnp.asarray([toks[-1]], jnp.int32)}
        )
        toks.append(int(jnp.argmax(logits[0, 0, : smoke_model.cfg.vocab_size])))
    assert r.out_tokens == toks

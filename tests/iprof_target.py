"""Tiny traced workload used by the iprof CLI tests."""

import jax.numpy as jnp

from repro.core import collective_span, traced_jit, train_step_span

_f = traced_jit(lambda x: (x * x).sum(), name="square_sum")


def main():
    x = jnp.arange(64.0)
    for step in range(3):
        with train_step_span(step, 2, 32) as sp:
            sp.outs["loss"] = float(_f(x))
            sp.outs["grad_norm"] = 1.0
        with collective_span("all_reduce", 256, "data", 4):
            pass

"""Cross-mode conformance suite for the fidelity ladder (repro.trace API).

One deterministic workload (private model + injected ticking clock, so every
run is byte-reproducible) executed under every fidelity rung × both recorder
paths (ring_reserve on/off) × compressed/uncompressed streams.  The
invariants locked down here:

  * ``full`` is byte-identical across the reserve/commit and legacy write
    recorder paths — the rung must not perturb the existing contract;
  * ``tally-only`` produces NO stream files yet its in-process folded tally
    equals the offline fold of a ``full`` run of the same workload *exactly*
    (same fold engine, same records, no stream round-trip drift);
  * ``off`` emits zero streams and zero ring writes — not "empty streams",
    literally no producer-side activity;
  * ``sampled`` records a subset: its tally's key set is contained in the
    full run's, counts are scaled (estimated) and exact when the sampling
    interval divides the per-API call count;
  * the rungs are live: a mid-run ``set_mode`` walk through all four rungs
    keeps drains consistent and merges post-flip tallies cleanly.

Plus the unknown-eid passthrough regression: folds and timelines over traces
containing events the local model does not know (e.g. a newer producer's
user events) must tolerate them — name-keyed passthrough rows in the fold,
silent skip in the timeline — instead of crashing or silently corrupting.
"""

import json
import os
import struct

import pytest

import repro.trace as trace
from repro.core.api_model import APIModel, APISpec, P, build_trace_model
from repro.core.clock import ClockInfo
from repro.core.ctf import StreamReader, StreamWriter, stream_files, write_metadata
from repro.core.plugins.tally import tally_trace
from repro.core.plugins.timeline import timeline_events
from repro.core.tracepoints import FIDELITY_MODES
from repro.core.tracer import TraceConfig, Tracer
from tests.test_ring_reserve import frame, ticking_clock

_MODEL = build_trace_model(
    [
        APIModel(
            provider="ust_m",
            apis=(
                APISpec("alpha", params=(P("a", "u32"),), result=P("rc", "i32")),
                APISpec(
                    "beta",
                    params=(P("n", "u64"), P("s", "str")),
                    result=P("rc", "u32"),
                ),
                APISpec("launch", params=(P("name", "str"), P("flops", "u64")), span=True),
            ),
        )
    ]
)

REPS = 40  # divisible by every interval used below → exact scaled counts


def _drive(tp, reps=REPS):
    """Deterministic op mix: two host pairs + one device span per rep."""
    alpha = tp.record["ust_m:alpha_entry"]
    alpha_x = tp.record["ust_m:alpha_exit"]
    beta = tp.record_pair["ust_m:beta"]
    span = tp.record["ust_m:launch_span"]
    for i in range(reps):
        alpha(i)
        alpha_x(-i)
        beta(i, "s" * (i % 7), 10_000 + i, 0)
        span(i * 10, i * 10 + 5, "k", 99)


def _run(tmp_path, fidelity, ring_reserve=True, compress=False, interval=4, reps=REPS):
    d = str(tmp_path / f"{fidelity}_{int(ring_reserve)}_{int(compress)}")
    cfg = TraceConfig(
        out_dir=d,
        mode="full",
        fidelity=fidelity,
        sampling_interval=interval,
        ring_reserve=ring_reserve,
        compress=compress,
    )
    tr = Tracer(cfg, model=_MODEL, clock=ticking_clock()).start()
    try:
        _drive(tr.tp, reps)
    finally:
        tr.stop()
    return d, tr


VARIANTS = [(rr, comp) for rr in (True, False) for comp in (False, True)]


def _variant_id(v):
    rr, comp = v
    return f"{'reserve' if rr else 'legacy'}-{'zst' if comp else 'raw'}"


# ---------------------------------------------------------------------------
# full ≡ legacy byte path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compress", [False, True], ids=["raw", "zst"])
def test_full_byte_identical_across_recorder_paths(tmp_path, compress):
    streams = {}
    for rr in (True, False):
        d, _ = _run(tmp_path, "full", ring_reserve=rr, compress=compress)
        files = stream_files(d)
        assert len(files) == 1
        r = StreamReader(files[0])
        region, release = r.records_region()
        streams[rr] = bytes(region)
        release()
    assert streams[True] == streams[False]


# ---------------------------------------------------------------------------
# every rung × every variant: the conformance matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS, ids=_variant_id)
def test_tally_only_equals_full_fold_exactly(tmp_path, variant):
    rr, comp = variant
    d_full, _ = _run(tmp_path, "full", ring_reserve=rr, compress=comp)
    t_full = tally_trace(d_full)
    d_to, tr = _run(tmp_path, "tally-only", ring_reserve=rr, compress=comp)
    assert not stream_files(d_to)  # no .ctf streams at all
    t_live = tr.final_tally
    assert t_live is not None
    assert t_live.apis == t_full.apis
    assert t_live.device_apis == t_full.device_apis
    assert not t_live.estimated
    # the aggregate sidecar carries the same tally for offline merging
    assert tr.handle.aggregate_path and os.path.exists(tr.handle.aggregate_path)
    from repro.core.aggregate import load_tally

    assert load_tally(tr.handle.aggregate_path).apis == t_full.apis


@pytest.mark.parametrize("variant", VARIANTS, ids=_variant_id)
def test_off_emits_nothing(tmp_path, variant):
    rr, comp = variant
    d = str(tmp_path / f"off_{_variant_id(variant)}")
    cfg = TraceConfig(
        out_dir=d, mode="full", fidelity="off", ring_reserve=rr, compress=comp
    )
    tr = Tracer(cfg, model=_MODEL, clock=ticking_clock()).start()
    try:
        _drive(tr.tp)
        c = tr.registry.counters()
        assert c["events"] == 0 and c["used"] == 0  # zero ring writes
    finally:
        tr.stop()
    assert tr.handle.events == 0
    assert tr.handle.fidelity == "off"
    assert not stream_files(d)


@pytest.mark.parametrize("variant", VARIANTS, ids=_variant_id)
def test_sampled_subset_and_exact_scaling(tmp_path, variant):
    rr, comp = variant
    d_full, _ = _run(tmp_path, "full", ring_reserve=rr, compress=comp)
    t_full = tally_trace(d_full)
    d_s, _ = _run(tmp_path, "sampled", ring_reserve=rr, compress=comp, interval=4)
    t_s = tally_trace(d_s)
    assert t_s.estimated and t_s.sample_interval == 4
    assert set(t_s.apis) <= set(t_full.apis)
    # systematic per-pair sampling: interval | calls → scaled count is exact
    for key in t_s.apis:
        assert t_s.apis[key].calls == t_full.apis[key].calls
    # device spans are never sampled: the device table is exact
    assert t_s.device_apis == t_full.device_apis
    meta = json.load(open(os.path.join(d_s, "metadata.json")))
    assert meta["env"]["fidelity"] == {
        "final": "sampled",
        "interval": 4,
        "modes_used": ["sampled"],
    }


def test_sampled_wire_roundtrip_keeps_estimated_flag(tmp_path):
    d_s, _ = _run(tmp_path, "sampled", interval=4)
    t = tally_trace(d_s)
    from repro.core.plugins.tally import Tally

    rt = Tally.from_obj(t.to_obj())
    assert rt.estimated and rt.sample_interval == 4
    assert rt.apis == t.apis
    # rendering marks host rows as estimates
    from repro.core.plugins.tally import render

    out = render(t)
    assert "estimated" in out and "~" in out


# ---------------------------------------------------------------------------
# the ladder is live: mid-run switching drains consistently
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rr", [True, False], ids=["reserve", "legacy"])
def test_midrun_switch_walks_all_rungs(tmp_path, rr):
    d = str(tmp_path / f"walk_{int(rr)}")
    cfg = TraceConfig(out_dir=d, mode="full", online=True, ring_reserve=rr)
    tr = Tracer(cfg, model=_MODEL, clock=ticking_clock()).start()
    try:
        _drive(tr.tp, reps=5)
        assert tr.fidelity == "full"
        assert tr.set_mode("tally-only") == "full"
        _drive(tr.tp, reps=5)
        assert tr.set_mode("off") == "tally-only"
        _drive(tr.tp, reps=5)  # recorded nowhere
        assert tr.set_mode("full") == "off"
        _drive(tr.tp, reps=5)
    finally:
        tr.stop()
    meta = json.load(open(os.path.join(d, "metadata.json")))
    assert meta["env"]["fidelity"]["modes_used"] == ["full", "tally-only", "off"]
    # streams carry the two full windows; the live tally carries full +
    # tally-only windows; the off window appears nowhere
    t_stream = tally_trace(d)
    assert t_stream.apis[("ust_m", "alpha")].calls == 10
    assert tr.final_tally.apis[("ust_m", "alpha")].calls == 15
    assert not t_stream.estimated and not tr.final_tally.estimated


def test_public_api_set_mode_and_annotate(tmp_path):
    d = str(tmp_path / "api")
    with Tracer(TraceConfig(out_dir=d, mode="full")):
        assert trace.get_mode() == "full"
        assert trace.annotate("marker", step=1)
        with trace.phase("warm"):
            pass
        prev = trace.set_mode("sampled")
        assert prev == "full" and trace.get_mode() == "sampled"
        trace.set_mode("full")
        with pytest.raises(ValueError):
            trace.set_mode("bogus")
    assert trace.get_mode() is None
    assert not trace.annotate("no_session")  # silent no-op without a session
    with pytest.raises(RuntimeError):
        trace.set_mode("off")
    t = tally_trace(d)
    assert ("ust_user", "phase") in t.apis


def test_fidelity_modes_exported():
    assert trace.FIDELITY_MODES == FIDELITY_MODES
    assert FIDELITY_MODES == ("full", "sampled", "tally-only", "off")


def test_config_rejects_bad_fidelity(tmp_path):
    with pytest.raises(ValueError):
        TraceConfig(out_dir=str(tmp_path), fidelity="medium")
    with pytest.raises(ValueError):
        TraceConfig(out_dir=str(tmp_path), sampling_interval=0)


# ---------------------------------------------------------------------------
# unknown-eid passthrough (forward compatibility regression)
# ---------------------------------------------------------------------------


def _unknown_trace(tmp_path, payload):
    d = str(tmp_path / "unk")
    os.makedirs(d, exist_ok=True)
    by = _MODEL.by_name()
    chunks = b"".join(
        [
            frame(by["ust_m:alpha_entry"].eid, 100, struct.pack("<I", 1)),
            frame(by["ust_m:alpha_exit"].eid, 200, struct.pack("<i", 0)),
            frame(250, 300, payload),  # eid 250: not in the model
        ]
    )
    w = StreamWriter(os.path.join(d, "stream_1_1.ctf"), 1, 1)
    w.append(chunks)
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={}, mode="full")
    return d


def test_fold_unknown_eid_passthrough_row(tmp_path):
    name = b"newer:event"
    d = _unknown_trace(tmp_path, struct.pack("<I", len(name)) + name)
    t = tally_trace(d)
    assert t.apis[("ust_m", "alpha")].calls == 1
    row = t.apis[("unknown", "newer:event")]
    assert row.calls == 1 and row.total_ns == 0  # calls-only passthrough


def test_fold_unknown_eid_garbage_payload_skipped(tmp_path):
    # payload that cannot be a length-prefixed name: skipped, not crashed
    d = _unknown_trace(tmp_path, b"\xff\xff\xff\xff")
    t = tally_trace(d)
    assert t.apis[("ust_m", "alpha")].calls == 1
    assert not any(p == "unknown" for p, _ in t.apis)


def test_timeline_tolerates_unknown_eid(tmp_path):
    name = b"newer:event"
    d = _unknown_trace(tmp_path, struct.pack("<I", len(name)) + name)
    evs = timeline_events(d)  # must not raise
    assert any(e.get("name") == "ust_m:alpha" for e in evs)


# ---------------------------------------------------------------------------
# mid-run mode-switch stress: the torn-free handoff under fire
# ---------------------------------------------------------------------------


def test_mode_switch_stress_spsc_no_torn_records():
    """Producer hammers a recorder while another thread flips the fidelity
    ladder thousands of times and a consumer drains concurrently (the
    test_ring_reserve SPSC harness, plus the flipper).  Every surviving
    record must be well-framed with a self-consistent payload and the kept
    sequence numbers strictly increasing — a torn ``__code__`` swap or a
    mid-record drain would break one or the other."""
    import threading

    from repro.core.ringbuffer import RingRegistry
    from repro.core.tracepoints import Tracepoints
    from tests.test_ring_reserve import _MODEL as RMODEL
    from tests.test_ring_reserve import unframe

    tp = Tracepoints(RMODEL)
    reg = RingRegistry(1 << 13, pid=1)
    tp.attach(reg, range(len(RMODEL.events)))
    rec = tp.record["ust_r:seq_entry"]
    FLIPS = 3_000  # the flipper paces the test: producer runs until done
    chunks = []
    stop = threading.Event()
    ring_ready = threading.Event()
    produced = [0]

    def producer():
        i = 0
        while not stop.is_set():
            rec(i, b"x" * (i % 33))
            if i == 0:
                ring_ready.set()
            i += 1
        produced[0] = i

    def consumer():
        ring_ready.wait(5)
        ring = reg.rings()[0]
        while not stop.is_set() or ring.used:
            regions = ring.drain_view()
            if regions:
                chunks.append(b"".join(regions))
                ring.release()

    def flipper():
        cycle = ("sampled", "full", "tally-only", "off", "full")
        for k in range(FLIPS):
            tp.set_fidelity(cycle[k % len(cycle)], interval=4)
        stop.set()

    threads = [threading.Thread(target=t) for t in (producer, consumer, flipper)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tp.set_fidelity("full")
    ring = reg.rings()[0]
    chunks.append(b"".join(ring.drain_view()))
    ring.release()
    assert produced[0] > 0
    seq_eid = RMODEL.by_name()["ust_r:seq_entry"].eid
    unpack = tp.unpack[seq_eid]
    seqs = []
    for eid, _, payload in unframe(b"".join(chunks)):
        assert eid == seq_eid
        n, fill, _rc = *unpack(memoryview(payload)), None
        assert fill == b"x" * (n % 33), "torn record"
        seqs.append(n)
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert 0 < len(seqs) <= produced[0]
    tp.detach()


def test_mode_switch_stress_tracer_tallies_merge(tmp_path):
    """Tracer-level flips while producer threads record: each set_mode drains
    under the handoff lock, so stream windows and the live tally must stay
    mutually consistent and merge cleanly after hundreds of flips."""
    import threading

    from repro.core.plugins.tally import Tally

    d = str(tmp_path / "stress")
    cfg = TraceConfig(out_dir=d, mode="full", online=True, sampling_interval=4)
    tr = Tracer(cfg, model=_MODEL).start()
    stop = threading.Event()
    counts = [0, 0]

    def producer(slot):
        alpha = tr.tp.record["ust_m:alpha_entry"]
        alpha_x = tr.tp.record["ust_m:alpha_exit"]
        i = 0
        while not stop.is_set():
            alpha(i)
            alpha_x(0)
            i += 1
        counts[slot] = i

    threads = [threading.Thread(target=producer, args=(s,)) for s in (0, 1)]
    for t in threads:
        t.start()
    cycle = ("sampled", "tally-only", "off", "full")
    nflips = 0
    try:
        for _ in range(75):
            for mode in cycle:
                assert tr.set_mode(mode) in FIDELITY_MODES
                nflips += 1
    finally:
        stop.set()
        for t in threads:
            t.join()
        tr.stop()
    assert nflips == 300
    attempts = sum(counts)
    t_stream = tally_trace(d)
    t_live = tr.final_tally
    key = ("ust_m", "alpha")
    # ends on "full": both views saw events; the live tally folds a superset
    # of the stream windows (it also saw the tally-only windows)
    assert 0 < t_stream.apis[key].calls <= t_live.apis[key].calls <= attempts
    # mixed-fidelity session: nothing may claim estimation
    assert not t_stream.estimated and not t_live.estimated
    merged = Tally().merge(t_stream).merge(t_live)  # must merge cleanly
    assert merged.apis[key].calls == t_stream.apis[key].calls + t_live.apis[key].calls
    meta = json.load(open(os.path.join(d, "metadata.json")))
    assert set(meta["env"]["fidelity"]["modes_used"]) == set(FIDELITY_MODES)

"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill→decode round to exercise the serving path.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model, ShapeSpec
from repro.models.param import count as param_count, init as spec_init, shapes as spec_shapes

SMOKE_SHAPE = ShapeSpec("smoke_train", "train", 32, 2)
SMOKE_PREFILL = ShapeSpec("smoke_prefill", "prefill", 16, 2)
SMOKE_DECODE = ShapeSpec("smoke_decode", "decode", 24, 2)


def make_batch(model: Model, shape: ShapeSpec, rng):
    """Materialize a random batch matching batch_specs."""
    specs = model.batch_specs(shape)
    out = {}
    for k, s in specs.items():
        if s.dtype == "int32":
            out[k] = jnp.asarray(
                rng.integers(0, model.cfg.vocab_size, size=s.shape), jnp.int32
            )
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape) * 0.1, jnp.float32)
    return out


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, SMOKE_SHAPE, rng)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert float(loss) > 0
    # a plausible LM init sits near ln(V)
    assert float(metrics["ce"]) < 2 * np.log(cfg.padded_vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_step(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, SMOKE_SHAPE, rng)

    def loss_fn(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree_util.tree_leaves(grads))
    )
    assert jnp.isfinite(loss) and jnp.isfinite(gnorm)
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = make_batch(m, SMOKE_PREFILL, rng)
    cache_len = SMOKE_DECODE.seq_len
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, cache_len))(params, batch)
    B = SMOKE_PREFILL.global_batch
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # greedy-decode 3 steps
    step = jax.jit(m.decode_step)
    tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = step(params, cache, {"token": tok})
        assert logits.shape[0] == B
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_consistent(arch):
    """Spec tree, shapes tree and logical axes tree stay in lockstep."""
    cfg = get_config(arch)
    m = Model(cfg)
    shapes = m.shapes()
    axes = m.axes()
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_a = jax.tree_util.tree_leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_s) == len(flat_a)
    for sds, ax in zip(flat_s, flat_a):
        assert len(sds.shape) == len(ax)
    # analytic count vs spec-tree count within 2% (analytic skips tiny terms)
    spec_total = param_count(m.param_specs())
    analytic = cfg.num_params()
    assert abs(spec_total - analytic) / analytic < 0.02, (arch, spec_total, analytic)


def test_decode_matches_prefill_continuation(rng):
    """Decoding token-by-token must equal teacher-forced prefill logits."""
    cfg = get_config("h2o-danube-1.8b").smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 12)), jnp.int32)
    cache_len = 32
    # full prefill over 12 tokens
    full_logits, _ = m.prefill(params, {"tokens": toks}, cache_len)
    # prefill over 11 then decode the 12th
    _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, cache_len)
    step_logits, _ = m.decode_step(params, cache, {"token": toks[:, -1]})
    np.testing.assert_allclose(
        np.asarray(full_logits[:, 0]), np.asarray(step_logits[:, 0]), rtol=2e-4, atol=2e-4
    )


def test_ssm_decode_matches_prefill_continuation(rng):
    cfg = get_config("mamba2-1.3b").smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 8)), jnp.int32)
    full_logits, _ = m.prefill(params, {"tokens": toks}, 16)
    _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, 16)
    step_logits, _ = m.decode_step(params, cache, {"token": toks[:, -1]})
    np.testing.assert_allclose(
        np.asarray(full_logits[:, 0]), np.asarray(step_logits[:, 0]), rtol=2e-4, atol=2e-4
    )


def test_hybrid_decode_matches_prefill_continuation(rng):
    cfg = get_config("recurrentgemma-2b").smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(4))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 8)), jnp.int32)
    full_logits, _ = m.prefill(params, {"tokens": toks}, 16)
    _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, 16)
    step_logits, _ = m.decode_step(params, cache, {"token": toks[:, -1]})
    np.testing.assert_allclose(
        np.asarray(full_logits[:, 0]), np.asarray(step_logits[:, 0]), rtol=2e-4, atol=2e-4
    )


def test_moe_dense_oracle_consistency(rng):
    """Single-device MoE path: top-k combine weights sum to 1, loss finite."""
    from repro.models.moe import _moe_dense, _router

    cfg = get_config("moonshot-v1-16b-a3b").smoke()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(5))
    xt = jnp.asarray(rng.normal(size=(6, cfg.d_model)), jnp.float32)
    p_layer = jax.tree_util.tree_map(lambda x: x[0], params["blocks"])
    w, idx, aux = _router(cfg, p_layer["router"], xt)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # aux loss lower bound at uniform routing
    out, _ = _moe_dense(
        cfg, {k: p_layer[k] for k in ("router", "w_gate", "w_up", "w_down")}, xt
    )
    assert out.shape == xt.shape and bool(jnp.all(jnp.isfinite(out)))

"""Analysis-graph internals: muxer ordering, Metababel dispatch, CTF
robustness to truncated streams (crash mid-write), interval filter edges."""

import os
import random
import struct

import jax.numpy as jnp
import pytest

from repro.core import TraceConfig, Tracer, traced_jit, train_step_span
from repro.core.babeltrace import CTFSource, IntervalFilter, muxer
from repro.core.ctf import STREAM_HEADER, StreamReader, stream_files
from repro.core.metababel import Dispatcher


def make_trace(tmp_path, steps=3):
    d = str(tmp_path / "t")
    f = traced_jit(lambda x: x.sum(), name="s")
    with Tracer(TraceConfig(out_dir=d, mode="default")):
        for s in range(steps):
            with train_step_span(s, 1, 8) as sp:
                sp.outs["loss"] = float(f(jnp.ones(8)))
                sp.outs["grad_norm"] = 1.0
    return d


def test_muxer_emits_global_time_order(tmp_path):
    d = make_trace(tmp_path)
    ts = [ev.ts for ev in CTFSource(d)]
    assert ts == sorted(ts)
    assert len(ts) > 0


class _E:  # minimal Event stand-in for muxer property tests
    def __init__(self, ts):
        self.ts = ts


def _check_muxer_merges(streams):
    its = [iter([_E(t) for t in sorted(s)]) for s in streams]
    merged = [e.ts for e in muxer(its)]
    assert merged == sorted(t for s in streams for t in s)


def test_property_muxer_merges_sorted_streams_hypothesis():
    """Property-based; hypothesis is optional (see requirements-dev.txt)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(
        st.lists(
            st.lists(st.integers(0, 10_000), min_size=0, max_size=20),
            min_size=1,
            max_size=5,
        )
    )
    def prop(streams):
        _check_muxer_merges(streams)

    prop()


@pytest.mark.parametrize("seed", range(8))
def test_property_muxer_merges_sorted_streams_fallback(seed):
    """Seeded pure-pytest fallback for the muxer merge invariant."""
    rng = random.Random(seed)
    streams = [
        [rng.randint(0, 10_000) for _ in range(rng.randint(0, 20))]
        for _ in range(rng.randint(1, 5))
    ]
    _check_muxer_merges(streams)


def test_metababel_dispatch_callbacks(tmp_path):
    d = make_trace(tmp_path, steps=4)
    src = CTFSource(d)
    seen = {"entry": 0, "other": 0}
    disp = Dispatcher(src.model, default=lambda ev: seen.__setitem__("other", seen["other"] + 1))
    disp.on("ust_repro:train_step_entry", lambda ev: seen.__setitem__("entry", seen["entry"] + 1))
    n = disp.run(iter(src))
    assert seen["entry"] == 4
    assert n == seen["entry"] + seen["other"]


def test_metababel_on_provider(tmp_path):
    d = make_trace(tmp_path)
    src = CTFSource(d)
    count = {"n": 0}
    Dispatcher(src.model).on_provider(
        "ust_jaxrt", lambda ev: count.__setitem__("n", count["n"] + 1)
    ).run(iter(src))
    assert count["n"] > 0


def test_truncated_stream_reads_cleanly(tmp_path):
    """A crash mid-record must not break post-mortem analysis (§4.2 spirit)."""
    d = make_trace(tmp_path)
    path = stream_files(d)[0]
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 5)  # cut into the last record
    events = list(StreamReader(path))  # no exception; tail dropped
    assert len(events) > 0
    # full pipeline still works
    from repro.core.plugins.tally import tally_trace

    t = tally_trace(d)
    assert t.apis or t.device_apis


def test_stream_reader_rejects_wrong_magic(tmp_path):
    p = str(tmp_path / "bogus_1_2.ctf")
    with open(p, "wb") as f:
        f.write(STREAM_HEADER.pack(b"NOTTHAPI", 1, 0))
    with pytest.raises(ValueError, match="not a THAPI"):
        list(StreamReader(p))


def test_interval_filter_unmatched_exit_counted():
    from repro.core.api_model import builtin_trace_model
    from repro.core.babeltrace import Event

    model = builtin_trace_model()
    exit_ev = model.by_name()["ust_repro:train_step_exit"]
    ev = Event(100, exit_ev, (0, 1.0, 1.0), 1, 1)
    filt = IntervalFilter(iter([ev]))
    assert list(filt) == []
    assert filt.unmatched_exits == 1

"""Fold-engine equivalence + composite-cache tests.

The contract under test: the single-pass fold engine (``core/fold.py``, the
default behind ``tally_trace``) produces a tally identical to the legacy
Babeltrace-style graph (``CTFSource → IntervalFilter → tally_intervals``)
on *any* trace — including compressed streams, truncated tails, unmatched
entries/exits, and discard records.  Property-based when hypothesis is
installed (seed-driven trace generation), seeded-loop fallback otherwise.

Plus the read-path scaling layer: MasterServer's incremental composite and
rollup groups must equal the rebuild-per-read result through full snapshots,
deltas, and non-monotone restarts.
"""

import os
import random
import struct

from repro.core.api_model import (
    APIModel,
    APISpec,
    DISCARD_EVENT_ID,
    P,
    build_trace_model,
)
from repro.core.clock import ClockInfo
from repro.core.ctf import StreamWriter, write_metadata
from repro.core.fold import FoldEngine, fold_trace
from repro.core.plugins.tally import ApiStat, Tally, tally_trace
from repro.core.ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE
from tests.hypothesis_optional import given, settings, st

# ---------------------------------------------------------------------------
# Trace generator (shared by the hypothesis and seeded-fallback tests)
# ---------------------------------------------------------------------------

_MODEL = build_trace_model(
    [
        APIModel(
            provider="ust_a",
            apis=(
                APISpec("alpha", params=(P("x", "u32"),), result=P("status", "u32")),
                APISpec("beta", params=(P("msg", "str"),), result=P("status", "u32")),
                APISpec(
                    "launch",
                    params=(P("name", "str"), P("flops", "u64")),
                    span=True,
                ),
                APISpec("xfer", params=(P("nbytes", "u64"),), span=True),
                APISpec("tick", params=(P("v", "f32"),), counter=True),
            ),
        )
    ]
)
_BYNAME = _MODEL.by_name()
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_F32 = struct.Struct("<f")


def _rec(eid: int, ts: int, payload: bytes) -> bytes:
    return RECORD_HEADER.pack(RECORD_HEADER_SIZE + len(payload), eid, ts) + payload


def _pstr(s: str) -> bytes:
    b = s.encode()
    return _U32.pack(len(b)) + b


def _gen_stream(rng: random.Random, pid: int, tid: int) -> bytes:
    """One thread's record bytes: entries/exits (nested, unmatched both
    ways), spans, counters, discards — timestamps monotone per thread."""
    out = []
    ts = rng.randrange(1, 1000)
    open_calls = {"alpha": 0, "beta": 0}
    for _ in range(rng.randrange(0, 120)):
        ts += rng.randrange(0, 50)
        op = rng.randrange(0, 10)
        if op <= 2:  # entry
            api = rng.choice(("alpha", "beta"))
            ev = _BYNAME[f"ust_a:{api}_entry"]
            payload = _pstr("m" * rng.randrange(0, 5)) if api == "beta" else _U32.pack(7)
            out.append(_rec(ev.eid, ts, payload))
            open_calls[api] += 1
        elif op <= 5:  # exit — sometimes unmatched on purpose
            api = rng.choice(("alpha", "beta"))
            ev = _BYNAME[f"ust_a:{api}_exit"]
            out.append(_rec(ev.eid, ts, _U32.pack(0)))
            open_calls[api] = max(0, open_calls[api] - 1)
        elif op <= 7:  # device span (named launch or plain transfer)
            if rng.random() < 0.5:
                ev = _BYNAME["ust_a:launch_span"]
                name = rng.choice(("k_gemm", "k_scan", "k_io"))
                dur = rng.randrange(0, 500)
                payload = (
                    _U64.pack(ts) + _U64.pack(ts + dur) + _pstr(name) + _U64.pack(99)
                )
            else:
                ev = _BYNAME["ust_a:xfer_span"]
                # ts_end < ts_begin occasionally: negative durations clamp
                t1 = ts + rng.randrange(-20, 300)
                payload = _U64.pack(ts) + _U64.pack(max(0, t1)) + _U64.pack(4096)
            out.append(_rec(ev.eid, ts, payload))
        elif op == 8:  # telemetry counter (skipped by the tally fold)
            ev = _BYNAME["ust_a:tick"]
            out.append(_rec(ev.eid, ts, _F32.pack(1.5)))
        else:  # discard record
            out.append(_rec(DISCARD_EVENT_ID, ts, _U64.pack(rng.randrange(1, 9))))
    return b"".join(out)


def _build_trace(seed: int, trace_dir: str) -> None:
    rng = random.Random(seed)
    os.makedirs(trace_dir, exist_ok=True)
    n_streams = rng.randrange(1, 4)
    for i in range(n_streams):
        pid, tid = 100 + i, 7000 + i
        compress = rng.random() < 0.3
        w = StreamWriter(
            os.path.join(trace_dir, f"stream_{pid}_{tid}.ctf"), pid, tid, compress
        )
        w.append(_gen_stream(rng, pid, tid))
        if not compress and rng.random() < 0.3:
            # torn tail: a partial record header (crash mid-write)
            w.append(RECORD_HEADER.pack(64, 1, 42)[: rng.randrange(1, 13)])
        w.close()
    write_metadata(
        trace_dir, _MODEL, ClockInfo.capture(), env={"hostname": "foldhost"}
    )


def canon(t: Tally) -> dict:
    """Order-independent tally form (dict insertion order differs by path)."""
    o = t.to_obj()
    o["apis"] = sorted(o["apis"])
    o["device_apis"] = sorted(o["device_apis"])
    return o


def _assert_paths_agree(trace_dir: str) -> None:
    fast = tally_trace(trace_dir)
    legacy = tally_trace(trace_dir, legacy_graph=True)
    assert canon(fast) == canon(legacy)


# ---------------------------------------------------------------------------
# Equivalence: property-based + seeded fallback
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_fold_matches_legacy_property(seed):
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        _build_trace(seed, d)
        _assert_paths_agree(d)


def test_fold_matches_legacy_seeded(tmp_path):
    """Seeded corpus (runs everywhere, hypothesis or not): 20 random traces
    spanning compression, torn tails, unmatched entries/exits, discards."""
    for seed in range(20):
        d = str(tmp_path / f"t{seed}")
        _build_trace(seed, d)
        _assert_paths_agree(d)


def test_fold_unmatched_and_discard_semantics(tmp_path):
    """Unmatched entries flush as zero-duration calls; unmatched exits are
    dropped (counted); discards accumulate — exactly the legacy behavior."""
    d = str(tmp_path / "t")
    os.makedirs(d)
    ev_in = _BYNAME["ust_a:alpha_entry"]
    ev_out = _BYNAME["ust_a:alpha_exit"]
    w = StreamWriter(os.path.join(d, "stream_5_6.ctf"), 5, 6)
    w.append(_rec(ev_out.eid, 50, _U32.pack(0)))  # unmatched exit first
    w.append(_rec(ev_in.eid, 100, _U32.pack(1)))
    w.append(_rec(ev_out.eid, 175, _U32.pack(0)))  # pairs: dur 75
    w.append(_rec(ev_in.eid, 200, _U32.pack(2)))  # never exits: dur 0
    w.append(_rec(DISCARD_EVENT_ID, 300, _U64.pack(4)))
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    t = tally_trace(d)
    assert canon(t) == canon(tally_trace(d, legacy_graph=True))
    st_ = t.apis[("ust_a", "alpha")]
    assert st_.calls == 2 and st_.total_ns == 75
    assert st_.min_ns == 0 and st_.max_ns == 75  # the unmatched entry's 0
    assert t.discarded == 4


def test_fold_named_launch_varlen_prefix_matches_legacy(tmp_path):
    """A launch span whose name sits *behind* a varlen field cannot use the
    fixed-offset fast path — the plan must fall back to a full unpack and
    still produce per-kernel rows identical to the legacy graph."""
    model = build_trace_model(
        [
            APIModel(
                provider="ust_x",
                apis=(
                    APISpec(
                        "launch",
                        params=(P("tag", "str"), P("name", "str"), P("flops", "u64")),
                        span=True,
                    ),
                ),
            )
        ]
    )
    ev = model.by_name()["ust_x:launch_span"]
    d = str(tmp_path / "t")
    os.makedirs(d)
    w = StreamWriter(os.path.join(d, "stream_1_2.ctf"), 1, 2)
    for tag, name, dur in (("a", "k_x", 5), ("bb", "k_y", 7), ("c", "k_x", 9)):
        w.append(
            _rec(
                ev.eid,
                0,
                _U64.pack(10) + _U64.pack(10 + dur) + _pstr(tag) + _pstr(name) + _U64.pack(1),
            )
        )
    w.close()
    write_metadata(d, model, ClockInfo.capture(), env={})
    fast = tally_trace(d)
    legacy = tally_trace(d, legacy_graph=True)
    assert canon(fast) == canon(legacy)
    assert fast.device_apis[("ust_x", "k_x")].calls == 2
    assert fast.device_apis[("ust_x", "k_y")].total_ns == 7


def test_fold_named_launch_rows(tmp_path):
    """Launch spans tally per kernel name without unpacking the rest."""
    d = str(tmp_path / "t")
    os.makedirs(d)
    ev = _BYNAME["ust_a:launch_span"]
    w = StreamWriter(os.path.join(d, "stream_1_2.ctf"), 1, 2)
    for name, dur in (("k_a", 10), ("k_b", 30), ("k_a", 20)):
        w.append(
            _rec(ev.eid, 0, _U64.pack(100) + _U64.pack(100 + dur) + _pstr(name) + _U64.pack(1))
        )
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    t = fold_trace(d)
    assert t.device_apis[("ust_a", "k_a")].calls == 2
    assert t.device_apis[("ust_a", "k_a")].total_ns == 30
    assert t.device_apis[("ust_a", "k_b")].total_ns == 30
    assert ("ust_a", "launch") not in t.device_apis


# ---------------------------------------------------------------------------
# Online analyzer rides the same engine
# ---------------------------------------------------------------------------


def test_online_feed_keys_stacks_by_pid():
    """Multi-process feeds must not cross-match pairs: an entry from pid 1
    cannot be closed by an exit from pid 2 on the same tid (the bug the
    (tid, api)-keyed stacks had)."""
    from repro.core.online import OnlineAnalyzer

    a = OnlineAnalyzer(_MODEL)
    ev_in = _BYNAME["ust_a:alpha_entry"]
    ev_out = _BYNAME["ust_a:alpha_exit"]
    a.feed(_rec(ev_in.eid, 100, _U32.pack(1)), pid=1, tid=9)
    a.feed(_rec(ev_out.eid, 900, _U32.pack(0)), pid=2, tid=9)  # foreign exit
    assert ("ust_a", "alpha") not in a.snapshot().apis
    a.feed(_rec(ev_out.eid, 150, _U32.pack(0)), pid=1, tid=9)  # the real exit
    st_ = a.snapshot().apis[("ust_a", "alpha")]
    assert st_.calls == 1 and st_.total_ns == 50


def test_online_matches_offline_fold(tmp_path):
    """Feeding the analyzer a trace's stream bytes reproduces the offline
    fold (fully-matched corpus: no unmatched-entry flush involved)."""
    from repro.core.ctf import StreamReader, stream_files
    from repro.core.online import OnlineAnalyzer

    d = str(tmp_path / "t")
    os.makedirs(d)
    rng = random.Random(7)
    w = StreamWriter(os.path.join(d, "stream_3_4.ctf"), 3, 4)
    ts = 0
    for _ in range(200):
        ts += rng.randrange(1, 30)
        dur = rng.randrange(0, 100)
        w.append(_rec(_BYNAME["ust_a:alpha_entry"].eid, ts, _U32.pack(1)))
        w.append(_rec(_BYNAME["ust_a:alpha_exit"].eid, ts + dur, _U32.pack(0)))
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    a = OnlineAnalyzer(_MODEL)
    for path in stream_files(d):
        r = StreamReader(path)
        buf, release = r.records_region()
        a.feed(bytes(buf), pid=r.pid, tid=r.tid)
        release()
    assert canon(a.snapshot()) == canon(fold_trace(d))
    assert a.events_seen == 400


# ---------------------------------------------------------------------------
# FoldEngine chunk semantics
# ---------------------------------------------------------------------------


def test_fold_chunk_truncated_tail_stops_cleanly():
    eng = FoldEngine(_MODEL)
    state = eng.new_state()
    ev_in = _BYNAME["ust_a:alpha_entry"]
    good = _rec(ev_in.eid, 10, _U32.pack(1))
    torn = RECORD_HEADER.pack(500, ev_in.eid, 20)  # claims 500B, has 14
    assert eng.fold_chunk(state, good + torn, 1, 1) == 1
    assert state.events_seen == 1


def test_fold_chunk_unknown_eid_skipped():
    eng = FoldEngine(_MODEL)
    state = eng.new_state()
    unknown = _rec(250, 10, b"xxxx")  # eid beyond the model: newer writer
    assert eng.fold_chunk(state, unknown, 1, 1) == 1
    assert not state.rows and not state.drows


# ---------------------------------------------------------------------------
# MasterServer: incremental composite + rollup groups
# ---------------------------------------------------------------------------


def _mk_tally(rank: int, calls: int = 3, apis: int = 6) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 4}")
    t.processes.add(rank)
    t.threads.add((rank, 0))
    for a in range(apis):
        s = ApiStat()
        for c in range(calls):
            s.add(100 + 13 * a + c + rank)
        t.apis[("ust_a", f"api_{a}")] = s
    return t


def _rebuild_reference(m) -> Tally:
    """What the composite must equal: a fresh merge of every stored source."""
    ref = Tally()
    for src, t in m.ranks().items():
        ref.merge(t)
    return ref


def test_composite_cache_tracks_submits_and_deltas():
    from repro.core.stream import MasterServer

    m = MasterServer(port=0)  # never started: pure state machine
    for r in range(8):
        m.submit(f"r{r}", _mk_tally(r))
    assert canon(m.composite()) == canon(_rebuild_reference(m))
    rebuilds_before = m.comp_rebuilds
    # grow rank 3 via a delta (the steady-state O(changed) path)
    base = Tally().merge(m.ranks()["r3"])
    grown = Tally().merge(base)
    grown.apis[("ust_a", "api_0")].add(5_000)
    grown.apis[("ust_a", "api_new")] = ApiStat(calls=1, total_ns=9, min_ns=9, max_ns=9)
    d = grown.delta_to(base)
    assert m.submit_delta("r3", d, seq=1, base_seq=0, gen=None)
    assert canon(m.composite()) == canon(_rebuild_reference(m))
    # full-snapshot monotone growth applies incrementally too
    grown2 = Tally().merge(grown)
    grown2.apis[("ust_a", "api_1")].add(77)
    m.submit("r3", grown2, seq=2, gen=None)
    assert canon(m.composite()) == canon(_rebuild_reference(m))
    assert m.comp_rebuilds == rebuilds_before  # never rebuilt along the way


def test_composite_cache_rebuilds_on_non_monotone_restart():
    from repro.core.stream import MasterServer

    m = MasterServer(port=0)
    m.submit("r0", _mk_tally(0, calls=9), gen=1)
    m.submit("r1", _mk_tally(1, calls=9), gen=1)
    m.composite()
    # rank restarts: counters reset (smaller tally, new generation)
    m.submit("r0", _mk_tally(0, calls=2), seq=0, gen=2)
    assert canon(m.composite()) == canon(_rebuild_reference(m))
    assert m.comp_rebuilds >= 2  # initial build + non-monotone fallback


def test_composite_cache_row_ops_beat_rebuild_per_read():
    """The acceptance criterion: ≥10× fewer merge row-ops in steady state
    at scale vs the rebuild-per-read baseline, identical results."""
    from repro.core.stream import MasterServer

    ranks, rounds, width = 64, 12, 40
    cached = MasterServer(port=0, composite_cache=True)
    rebuild = MasterServer(port=0, composite_cache=False)
    for r in range(ranks):
        t = _mk_tally(r, apis=width)
        cached.submit(f"r{r}", Tally().merge(t))
        rebuild.submit(f"r{r}", Tally().merge(t))
    cached.composite(), rebuild.composite()
    c0, b0 = cached.comp_row_ops, rebuild.comp_row_ops
    for i in range(rounds):
        src = f"r{i % ranks}"
        grown = Tally().merge(cached.ranks()[src])
        grown.apis[("ust_a", "api_0")].add(1_000 + i)
        cached.submit(src, Tally().merge(grown))
        rebuild.submit(src, Tally().merge(grown))
        assert canon(cached.composite()) == canon(rebuild.composite())
    c_ops = cached.comp_row_ops - c0
    b_ops = rebuild.comp_row_ops - b0
    assert b_ops >= 10 * max(1, c_ops), (c_ops, b_ops)


def test_rollup_groups_by_host_and_bucket():
    from repro.core.stream import MasterServer

    m = MasterServer(port=0, rollup_groups="host")
    m.submit("nodeA:1:rank0", _mk_tally(0))
    m.submit("nodeA:2:rank1", _mk_tally(1))
    m.submit("nodeB:3:rank2", _mk_tally(2))
    g = m.groups()
    assert set(g) == {"nodeA", "nodeB"}
    merged = Tally()
    for t in g.values():
        merged.merge(t)
    assert canon(merged) == canon(m.composite())
    # growth lands in the right group incrementally
    grown = Tally().merge(m.ranks()["nodeA:1:rank0"])
    grown.apis[("ust_a", "api_0")].add(9_999)
    m.submit("nodeA:1:rank0", grown, seq=1, gen=None)
    g2 = m.groups()
    assert g2["nodeA"].apis[("ust_a", "api_0")].calls > g[
        "nodeA"
    ].apis[("ust_a", "api_0")].calls
    assert canon(g2["nodeB"]) == canon(g["nodeB"])  # bystander untouched

    b = MasterServer(port=0, rollup_groups=2)
    for r in range(5):
        b.submit(f"h:{r}:rank{r}", _mk_tally(r))
    assert set(b.groups()) == {"group0", "group1", "group2"}


def test_query_groups_over_tcp():
    from repro.core.stream import MasterServer, StreamClient

    with MasterServer(port=0, rollup_groups="host") as m:
        m.submit("nodeA:1:rank0", _mk_tally(0))
        m.submit("nodeB:2:rank1", _mk_tally(1))
        with StreamClient(m.addr) as c:
            groups, meta = c.groups()
        assert meta["rollup"] and set(groups) == {"nodeA", "nodeB"}
        merged = Tally()
        for t in groups.values():
            merged.merge(t)
        assert canon(merged) == canon(m.composite())
    with MasterServer(port=0) as m2:  # rollup off: empty map, flagged
        m2.submit("x:1:rank0", _mk_tally(0))
        with StreamClient(m2.addr) as c:
            groups, meta = c.groups()
        assert not meta["rollup"] and groups == {}


def test_rollup_local_master_forwards_groups(tmp_path):
    """A local master with rollup_groups forwards group tallies upstream —
    the >1k-rank pre-aggregation: the global master sees O(groups) sources."""
    import time as _time

    from repro.core.stream import MasterServer

    with MasterServer(port=0) as top:
        local = MasterServer(
            port=0,
            forward_to=top.addr,
            forward_period_s=0.05,
            rollup_groups="host",
        ).start()
        try:
            for r in range(6):
                local.submit(f"node{r % 2}:1:rank{r}", _mk_tally(r))
            deadline = _time.monotonic() + 5.0
            while _time.monotonic() < deadline:
                if set(top.ranks()) == {"node0", "node1"}:
                    break
                _time.sleep(0.02)
            assert set(top.ranks()) == {"node0", "node1"}
            assert canon(top.composite()) == canon(local.composite())
        finally:
            local.stop()


def test_ranks_copy_false_returns_frozen_snapshots():
    from repro.core.stream import MasterServer

    m = MasterServer(port=0)
    m.submit("r0", _mk_tally(0))
    first = m.ranks(copy=False)["r0"]
    assert m.ranks(copy=False)["r0"] is first  # unchanged: same snapshot
    grown = Tally().merge(first)
    grown.apis[("ust_a", "api_0")].add(1)
    m.submit("r0", grown, seq=1, gen=None)
    second = m.ranks(copy=False)["r0"]
    assert second is not first  # replaced wholesale, never mutated in place
    assert first.apis[("ust_a", "api_0")].calls == 3

"""Tracer session + analysis plugins (THAPI §3.2/§3.4/§4.2/§5.2)."""

import json
import math
import os
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    TraceConfig,
    Tracer,
    collective_span,
    kernel_span,
    traced_device_put,
    traced_jit,
    train_step_span,
)
from repro.core.api_model import builtin_trace_model
from repro.core.plugins.pretty import pretty_print
from repro.core.plugins.tally import Tally, fmt_ns, render, tally_trace
from repro.core.plugins.timeline import timeline_events, write_timeline
from repro.core.plugins.validate import validate_trace
from repro.core.tracer import events_for_mode, get_tracepoints


def run_session(tmp_path, mode="default", sample=False, steps=3, **kw):
    d = str(tmp_path / f"trace_{mode}_{sample}")
    f = traced_jit(lambda x: (x * 2).sum(), name="double_sum")
    x = jnp.arange(128.0)
    cfg = TraceConfig(out_dir=d, mode=mode, sample=sample, sample_period_s=0.005, **kw)
    with Tracer(cfg) as tr:
        for step in range(steps):
            with train_step_span(step, 4, 128) as sp:
                y = f(x)
                sp.outs["loss"] = float(y)
                sp.outs["grad_norm"] = 0.5
            with collective_span("all_reduce", 4096, "data", 8):
                pass
            with kernel_span("my_kernel", grid=(4, 2), flops=1000, bytes_accessed=4096):
                pass
        if sample:
            time.sleep(0.05)
    return d, tr.handle


# -- modes (§5.2) ------------------------------------------------------------


def test_mode_event_sets_nested():
    m = builtin_trace_model()
    mn = events_for_mode(m, "minimal", False)
    df = events_for_mode(m, "default", False)
    fl = events_for_mode(m, "full", False)
    assert mn < df < fl  # strictly increasing detail
    by_name = m.by_name()
    # minimal keeps device spans only
    assert by_name["ust_kernel:launch_span"].eid in mn
    assert by_name["ust_repro:train_step_entry"].eid not in mn
    # default excludes polling ("non-spawned") APIs
    assert by_name["ust_repro:poll_ready_entry"].eid not in df
    assert by_name["ust_repro:poll_ready_entry"].eid in fl
    # sampling flag controls telemetry independent of mode
    assert by_name["ust_thapi:sample"].eid not in fl
    assert by_name["ust_thapi:sample"].eid in events_for_mode(m, "minimal", True)


def test_minimal_traces_device_only(tmp_path):
    d, h = run_session(tmp_path, mode="minimal")
    t = tally_trace(d)
    assert not t.apis  # no host-side intervals
    assert ("ust_kernel", "my_kernel") in t.device_apis
    assert ("ust_collective", "all_reduce") in t.device_apis


def test_default_captures_host_and_device(tmp_path):
    d, h = run_session(tmp_path, mode="default")
    t = tally_trace(d)
    assert ("ust_repro", "train_step") in t.apis
    assert ("ust_jaxrt", "dispatch") in t.apis
    assert t.apis[("ust_repro", "train_step")].calls == 3
    assert ("ust_kernel", "double_sum") in t.device_apis
    assert h.events > 0 and h.dropped == 0


def test_full_mode_polling_events(tmp_path):
    d, _ = run_session(tmp_path, mode="full")
    t = tally_trace(d)
    # the spin-lock pattern of §4.3's zeEventHostSynchronize analogue
    assert ("ust_repro", "poll_ready") in t.apis


def test_space_ordering_minimal_default_full(tmp_path):
    """Fig 8: minimal < default < full space requirement."""
    sizes = {}
    for mode in ("minimal", "default", "full"):
        d, h = run_session(tmp_path, mode=mode, steps=5)
        sizes[mode] = h.size_bytes
    assert sizes["minimal"] < sizes["default"] < sizes["full"]


def test_rank_filter_disables_tracing(tmp_path):
    d = str(tmp_path / "ranksel")
    cfg = TraceConfig(out_dir=d, rank=3, ranks=[0, 1])  # rank 3 not selected
    with Tracer(cfg) as tr:
        with train_step_span(0, 1, 1) as sp:
            sp.outs["loss"] = 1.0
    assert tr.handle.events == 0
    assert not os.path.exists(os.path.join(d, "metadata.json"))


def test_event_overrides(tmp_path):
    d = str(tmp_path / "ovr")
    cfg = TraceConfig(out_dir=d, mode="default", event_overrides={"ust_repro:train_step_entry": False})
    with Tracer(cfg):
        with train_step_span(0, 1, 1) as sp:
            sp.outs["loss"] = 1.0
    t = tally_trace(d)
    # entry disabled → unmatched exit only, no train_step interval
    assert ("ust_repro", "train_step") not in t.apis


def test_aggregate_only_mode(tmp_path):
    d = str(tmp_path / "agg")
    cfg = TraceConfig(out_dir=d, mode="default", aggregate_only=True)
    with Tracer(cfg) as tr:
        with train_step_span(0, 1, 1) as sp:
            sp.outs["loss"] = 1.0
    h = tr.handle
    assert h.aggregate_path and os.path.exists(h.aggregate_path)
    assert not [f for f in os.listdir(d) if f.endswith(".ctf")]  # streams pruned
    from repro.core.aggregate import load_tally

    t = load_tally(h.aggregate_path)
    assert ("ust_repro", "train_step") in t.apis


def test_nested_sessions_rejected(tmp_path):
    cfg = TraceConfig(out_dir=str(tmp_path / "a"))
    with Tracer(cfg):
        with pytest.raises(RuntimeError):
            Tracer(TraceConfig(out_dir=str(tmp_path / "b"))).start()


# -- transfers (§1.1 running example) -----------------------------------------


def test_traced_device_put_records_memcpy(tmp_path):
    import numpy as np

    d = str(tmp_path / "memcpy")
    with Tracer(TraceConfig(out_dir=d, mode="default")):
        traced_device_put(np.ones((256,), dtype=np.float32))
    t = tally_trace(d)
    assert ("ust_jaxrt", "memcpy") in t.apis
    assert ("ust_kernel", "transfer") in t.device_apis
    # H2D deducible from pointer classes, like the paper's example
    from repro.core.babeltrace import CTFSource

    ev = next(e for e in CTFSource(d) if e.name == "ust_jaxrt:memcpy_entry")
    f = ev.asdict()
    assert f["src"] >> 56 == 0x00 and f["dst"] >> 56 == 0xFF
    assert f["nbytes"] == 1024


# -- plugins -------------------------------------------------------------------


def test_pretty_print_format(tmp_path, capsys):
    d, _ = run_session(tmp_path)
    n = pretty_print(d, limit=5)
    out = capsys.readouterr().out
    assert n == 5
    assert "ust_" in out and "vpid:" in out and "vtid:" in out


def test_tally_render_table(tmp_path):
    d, _ = run_session(tmp_path)
    txt = render(tally_trace(d))
    assert "Time(%)" in txt and "Calls" in txt and "train_step" in txt
    assert "Hostnames" in txt and "Processes" in txt and "Threads" in txt


def test_fmt_ns():
    assert fmt_ns(4_730_000_000) == "4.73s"
    assert fmt_ns(295_890_000) == "295.89ms"
    assert fmt_ns(471.8) == "471.80ns"
    assert fmt_ns(9_710) == "9.71us"


def test_tally_merge_monoid(tmp_path):
    d, _ = run_session(tmp_path, steps=2)
    a, b = tally_trace(d), tally_trace(d)
    merged = Tally().merge(a).merge(b)
    key = ("ust_repro", "train_step")
    assert merged.apis[key].calls == 2 * a.apis[key].calls
    assert merged.apis[key].total_ns == 2 * a.apis[key].total_ns
    assert merged.apis[key].max_ns == a.apis[key].max_ns


def test_timeline_json_loadable(tmp_path):
    d, _ = run_session(tmp_path, sample=True)
    out = str(tmp_path / "tl.json")
    n = write_timeline(d, out)
    doc = json.load(open(out))
    assert n == len(doc["traceEvents"]) > 0
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phases and "C" in phases and "M" in phases
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] > 0 for e in xs)


def test_telemetry_sampled(tmp_path):
    d, _ = run_session(tmp_path, sample=True)
    from repro.core.babeltrace import CTFSource

    samples = [e for e in CTFSource(d) if e.name == "ust_thapi:sample"]
    assert len(samples) >= 2
    assert all(e.field("host_rss") > 0 for e in samples)


# -- validation plugin (§4.2) --------------------------------------------------


def test_validate_clean_trace(tmp_path):
    d, _ = run_session(tmp_path)
    assert validate_trace(d) == []


def test_validate_detects_nan_loss(tmp_path):
    d = str(tmp_path / "nan")
    with Tracer(TraceConfig(out_dir=d)):
        with train_step_span(0, 1, 1) as sp:
            sp.outs["loss"] = float("nan")
            sp.outs["grad_norm"] = 1.0
    rules = {f.rule for f in validate_trace(d)}
    assert "nan_loss" in rules


def test_validate_detects_unreleased_alloc(tmp_path):
    from repro.core.interception import record_alloc

    d = str(tmp_path / "leak")
    with Tracer(TraceConfig(out_dir=d)):
        record_alloc(1 << 20)
    rules = {f.rule for f in validate_trace(d)}
    assert "unreleased_alloc" in rules


def test_validate_detects_unmatched_entry(tmp_path):
    d = str(tmp_path / "open")
    with Tracer(TraceConfig(out_dir=d)) as tr:
        tr.tp.record["ust_repro:train_step_entry"](0, 1, 1)  # never exits
    rules = {f.rule for f in validate_trace(d)}
    assert "unmatched_entry" in rules

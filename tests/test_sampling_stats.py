"""Statistical correctness of the "sampled" fidelity rung.

The sampled rung keeps 1 of every N entry/exit pairs per API (systematic
sampling with a uniform random initial phase per pair) and the analysis side
multiplies calls and total durations by N.  Two kinds of guarantees are
locked down here:

  * **exact unbiasedness** — systematic sampling with a uniform phase in
    ``[0, N)`` selects every call in exactly one of the N phase offsets, so
    the *ensemble mean* of the scaled estimates over all N phases equals the
    full-fidelity ground truth as an integer identity, not approximately.
    ``Tracepoints.set_fidelity(..., phase=p)`` forces the phase, making the
    whole ensemble enumerable in-process;
  * **convergence** — with the phase drawn randomly (the production path),
    estimates across many seeds stay within tight deterministic bounds
    (|error| < N per API for counts) and their average converges on the
    truth across sampling rates.

Deterministic-clock tests run everywhere; the ``statistical`` marker tags
the ensemble sweeps that are meaningless without numpy-style repetition
budgets — CI's minimal-deps leg deselects them with ``-m "not statistical"``.
"""

import pytest

from repro.core.api_model import APIModel, APISpec, P, build_trace_model
from repro.core.online import OnlineAnalyzer
from repro.core.ringbuffer import RingRegistry
from repro.core.tracepoints import Tracepoints
from tests.hypothesis_optional import given, settings, st

_MODEL = build_trace_model(
    [
        APIModel(
            provider="ust_s",
            apis=(
                APISpec(
                    "work",
                    params=(P("n", "u64"), P("s", "str")),
                    result=P("rc", "u32"),
                ),
            ),
        )
    ]
)

_EXIT_TS = 1_000_000  # constant clock: durations depend only on the entry ts


def _run_sampled(interval, reps, phase=None, seed=None, durations=None):
    """Drive ``reps`` explicit-timestamp pairs through one sampled session.

    The clock is *constant*, so call ``i``'s duration is exactly
    ``durations[i]`` no matter which other calls the gate kept — selection
    cannot perturb the measurements it samples (the property the ensemble
    identity needs).  Returns the scaled (estimated) tally.
    """
    durations = durations or [100 * (i + 1) for i in range(reps)]
    tp = Tracepoints(_MODEL, clock=lambda: _EXIT_TS)
    reg = RingRegistry(1 << 20, pid=1)
    tp.attach(reg, range(len(_MODEL.events)))
    tp.set_fidelity("sampled", interval=interval, phase=phase, seed=seed)
    pair = tp.record_pair["ust_s:work"]
    for i in range(reps):
        pair(i, "", _EXIT_TS - durations[i], 0)
    online = OnlineAnalyzer(_MODEL)
    for ring in reg.rings():
        online.feed(ring.drain(), pid=1, tid=1)
    tp.detach()
    return online.finish(scale=interval)


def _ground_truth(reps, durations=None):
    durations = durations or [100 * (i + 1) for i in range(reps)]
    return reps, sum(durations)


KEY = ("ust_s", "work")


# ---------------------------------------------------------------------------
# exact unbiasedness over the phase ensemble (integer identity, always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("interval", [2, 3, 5, 8])
@pytest.mark.parametrize("reps", [1, 7, 40])
def test_phase_ensemble_mean_is_exactly_unbiased(interval, reps):
    true_calls, true_total = _ground_truth(reps)
    sum_calls = sum_total = 0
    for phase in range(interval):
        t = _run_sampled(interval, reps, phase=phase)
        row = t.apis.get(KEY)
        if row is not None:
            sum_calls += row.calls
            sum_total += row.total_ns
        assert t.estimated and t.sample_interval == interval
    # every call is selected in exactly one phase, scaled by N → the sum of
    # the N estimates is N × truth, i.e. the ensemble mean is exactly truth
    assert sum_calls == interval * true_calls
    assert sum_total == interval * true_total


def test_interval_one_is_full_fidelity():
    t = _run_sampled(1, 25, phase=0)
    assert t.apis[KEY].calls == 25
    assert not t.estimated or t.sample_interval == 1


def test_forced_phase_count_formula():
    # the counter starts AT the phase and a call is kept when its counter
    # value is ≡ 0 (mod N): call i is kept iff (p + i) % N == 0
    reps, N = 23, 5
    for p in range(N):
        t = _run_sampled(N, reps, phase=p)
        kept = sum(1 for c in range(p, p + reps) if c % N == 0)
        got = t.apis[KEY].calls if KEY in t.apis else 0
        assert got == N * kept


# ---------------------------------------------------------------------------
# deterministic error bounds (always run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("interval", [2, 4, 16])
def test_count_error_bounded_by_interval(interval):
    reps = 50
    for seed in range(10):
        t = _run_sampled(interval, reps, seed=seed)
        got = t.apis[KEY].calls if KEY in t.apis else 0
        assert abs(got - reps) < interval  # systematic sampling's hard bound


@settings(max_examples=40, deadline=None)
@given(
    reps=st.integers(min_value=0, max_value=200),
    interval=st.integers(min_value=1, max_value=32),
    phase=st.integers(min_value=0, max_value=31),
)
def test_property_scaled_count_identity(reps, interval, phase):
    """Property: the forced-phase estimate obeys the closed form and the
    whole-ensemble sum telescopes to N × reps for every (reps, N)."""
    phase %= interval
    t = _run_sampled(interval, reps, phase=phase)
    got = t.apis[KEY].calls if KEY in t.apis else 0
    kept = sum(1 for c in range(phase, phase + reps) if c % interval == 0)
    assert got == interval * kept
    assert abs(got - reps) <= interval  # bias bound (ties at the boundary)


# ---------------------------------------------------------------------------
# statistical sweeps (excluded from the minimal-deps CI leg)
# ---------------------------------------------------------------------------


@pytest.mark.statistical
@pytest.mark.parametrize("interval", [2, 8, 64])
def test_random_phase_estimates_converge(interval):
    """Across many seeded runs the mean estimate converges on the truth —
    counts AND duration totals — at every sampling rate."""
    import random

    reps = 256
    rng = random.Random(1234)
    durations = [rng.randrange(50, 5000) for _ in range(reps)]
    true_calls, true_total = _ground_truth(reps, durations)
    runs = 48
    est_calls = []
    est_total = []
    for seed in range(runs):
        t = _run_sampled(interval, reps, seed=seed, durations=durations)
        row = t.apis.get(KEY)
        est_calls.append(row.calls if row else 0)
        est_total.append(row.total_ns if row else 0)
    mean_calls = sum(est_calls) / runs
    mean_total = sum(est_total) / runs
    # counts: systematic sampling bounds every estimate within ±N of truth,
    # so the sample mean sits well inside ±N/2 with 48 draws
    assert abs(mean_calls - true_calls) <= interval
    # durations: the estimator's per-run spread is bounded by N × max(dur);
    # a generous 5σ-style envelope that still catches a biased estimator
    tol = 5 * interval * max(durations) / (runs ** 0.5)
    assert abs(mean_total - true_total) <= tol


@pytest.mark.statistical
def test_min_max_are_observed_not_scaled():
    """Scaling multiplies calls/total_ns only: min/max stay raw observations
    (an estimated min would be a lie — we *saw* that duration)."""
    reps = 64
    durations = [100 * (i + 1) for i in range(reps)]
    t = _run_sampled(4, reps, phase=0, durations=durations)
    row = t.apis[KEY]
    assert row.min_ns in durations and row.max_ns in durations
    assert row.min_ns >= min(durations) and row.max_ns <= max(durations)


@pytest.mark.statistical
@settings(max_examples=25, deadline=None)
@given(
    interval=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_random_phase_bias_bound(interval, seed):
    """Property (random production path): any single random-phase run's
    count estimate is within one interval of the truth."""
    reps = 100
    t = _run_sampled(interval, reps, seed=seed)
    got = t.apis[KEY].calls if KEY in t.apis else 0
    assert abs(got - reps) < interval

"""Multi-device correctness (8 fake XLA host devices in a subprocess —
smoke tests in the parent must keep seeing 1 device, per the dry-run rules).

Validates:
  * MoE expert-parallel dispatch (both the all_to_all sequence path and the
    replicated decode path) against the dense oracle;
  * int8-compressed DP mean against plain pmean;
  * sharded train step == single-device train step (GSPMD correctness).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.configs import get_config
    from repro.jaxcompat import device_mesh, make_mesh, shard_map
    from repro.models import Model, ShapeSpec
    from repro.models.moe import _moe_dense, moe_ffn
    from repro.sharding import Partitioner

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("moonshot-v1-16b-a3b").smoke()   # 8 experts, top-2
    model = Model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    pl = jax.tree_util.tree_map(lambda x: x[0], params["blocks"])
    moe_p = {k: pl[k] for k in ("router", "w_gate", "w_up", "w_down")}
    rng = np.random.default_rng(0)

    # --- EP seq path (S divisible by ep=4) vs dense oracle -------------------
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)) * 0.3, jnp.float32)
    dense_out, dense_aux = _moe_dense(cfg, moe_p, x.reshape(-1, cfg.d_model))
    dense_out = dense_out.reshape(x.shape)
    import dataclasses
    cfg_hi = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    with mesh:
        ep_out, ep_aux = jax.jit(lambda p, v: moe_ffn(cfg_hi, p, v, mesh))(moe_p, x)
    err = float(jnp.max(jnp.abs(ep_out - dense_out)))
    # aux is a per-shard estimator (GShard-style local load-balance): it only
    # approximates the global-token estimate — require agreement, not equality
    aux_rel = abs(float(ep_aux) - float(dense_aux)) / float(dense_aux)
    assert err < 2e-4, f"EP seq path mismatch: {err}"
    assert aux_rel < 0.2, f"aux estimator diverged: {aux_rel}"
    print("EP-seq OK", err)

    # --- EP replicated path (S=1 decode) vs dense oracle ---------------------
    x1 = jnp.asarray(rng.normal(size=(8, 1, cfg.d_model)) * 0.3, jnp.float32)
    dense1, _ = _moe_dense(cfg, moe_p, x1.reshape(-1, cfg.d_model))
    with mesh:
        rep1, _ = jax.jit(lambda p, v: moe_ffn(cfg_hi, p, v, mesh))(moe_p, x1)
    err1 = float(jnp.max(jnp.abs(rep1 - dense1.reshape(x1.shape))))
    assert err1 < 2e-4, f"EP replicated path mismatch: {err1}"
    print("EP-replicated OK", err1)

    # --- compressed_mean vs pmean --------------------------------------------
    from repro.optim.compression import compressed_mean
    g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    mesh1 = make_mesh((8,), ("data",))
    want = jnp.mean(g, axis=0)
    got = shard_map(
        lambda v: compressed_mean(v[0], "data"),
        mesh1, P("data"), P(),
    )(g)
    cerr = float(jnp.max(jnp.abs(got - want)))
    # int8 quantization error bound: half a step of the largest row scale
    bound = float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6
    assert cerr <= bound, f"compressed mean err {cerr} > {bound}"
    print("compressed_mean OK", cerr)

    # --- sharded vs single-device train step ----------------------------------
    from repro.train.train_step import TrainConfig, build_train_artifacts, init_state
    from repro.data import SyntheticPipeline
    shape = ShapeSpec("t", "train", 16, 4)
    dcfg = get_config("stablelm-3b").smoke()
    tc = TrainConfig(peak_lr=1e-3, warmup=0, total_steps=10)

    m_sh = Model(dcfg, mesh)
    part = Partitioner(mesh)
    step_sh, *_ = build_train_artifacts(m_sh, part, shape, tc)
    state_sh = init_state(m_sh, tc, jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in next(SyntheticPipeline(m_sh, shape)).items()}
    with mesh:
        _, met_sh = step_sh(state_sh, batch)

    mesh1x1 = device_mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    m_1 = Model(dcfg, mesh1x1)
    step_1, *_ = build_train_artifacts(m_1, Partitioner(mesh1x1), shape, tc)
    state_1 = init_state(m_1, tc, jax.random.PRNGKey(1))
    _, met_1 = step_1(state_1, batch)
    dl = abs(float(met_sh["loss"]) - float(met_1["loss"]))
    assert dl < 1e-4, f"sharded vs single loss differs: {dl}"
    print("sharded-train OK", dl)
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_multidevice_semantics(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True, timeout=900
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "ALL_OK" in proc.stdout

"""Columnar trace sidecar (``.ctfcol``): round-trip, staleness, forward-compat.

The sidecar is a *cache, never a source of truth*: every property here is a
statement about when it may be trusted and what it must equal when it is.

  * round-trip — tallies and timeline interval queries through the columnar
    fast path equal the record-parse paths exactly, for generated traces
    (compressed streams, torn tails, unmatched pairs, discards) and for
    traces written live by the tracer (``TraceConfig.columnar``);
  * staleness — truncating or appending to a stream after indexing
    invalidates its sidecar (byte-count mismatch) and reads transparently
    fall back to record parsing, still correct;
  * forward-compat — a sidecar with an unknown version (or arbitrary
    garbage) is skipped, never crashed on.
"""

import json
import os
import struct

from repro.core.clock import ClockInfo
from repro.core.ctf import (
    COL_HEADER,
    COL_MAGIC,
    COL_VERSION,
    StreamWriter,
    build_sidecars,
    load_sidecar,
    sidecar_path,
    stream_files,
    write_metadata,
)
from repro.core.fold import fold_trace
from repro.core.plugins.tally import tally_trace
from repro.core.plugins.timeline import query_intervals
from tests.hypothesis_optional import given, settings, st
from tests.test_fold import _BYNAME, _MODEL, _U32, _U64, _build_trace, _pstr, _rec, canon


def _assert_roundtrip(trace_dir: str) -> None:
    """Columnar reads == record-parse reads, tallies and interval queries."""
    ref_tally = canon(fold_trace(trace_dir, use_sidecar=False))
    ref_rows = query_intervals(trace_dir, use_sidecar=False)
    assert canon(fold_trace(trace_dir, use_sidecar=True)) == ref_tally
    assert query_intervals(trace_dir, use_sidecar=True) == ref_rows
    if ref_rows:
        # windowed queries agree too (begin/end straddling the middle row)
        mid = ref_rows[len(ref_rows) // 2][0]
        for begin, end in ((None, mid), (mid, None), (mid // 2, mid * 2 + 1)):
            assert query_intervals(
                trace_dir, begin, end, use_sidecar=True
            ) == query_intervals(trace_dir, begin, end, use_sidecar=False)


# ---------------------------------------------------------------------------
# Round-trip: property-based + seeded fallback
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_columnar_roundtrip_property(seed):
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        _build_trace(seed, d)
        assert build_sidecars(d) == len(stream_files(d))
        _assert_roundtrip(d)


def test_columnar_roundtrip_seeded(tmp_path):
    for seed in range(8):
        d = str(tmp_path / f"t{seed}")
        _build_trace(seed, d)
        build_sidecars(d)
        _assert_roundtrip(d)


def test_columnar_tally_through_tally_trace(tmp_path):
    """The public entry point takes the fast path too."""
    d = str(tmp_path / "t")
    _build_trace(5, d)
    ref = canon(tally_trace(d, use_sidecar=False))
    build_sidecars(d)
    assert canon(tally_trace(d)) == ref
    assert canon(tally_trace(d, legacy_graph=True)) == ref  # sidecar-blind


def test_columnar_unmatched_and_discard_rows(tmp_path):
    """Hand-built stream exercising every row kind: paired call, unmatched
    exit (no interval), unmatched entry (zero-duration flush), named span,
    discard record."""
    from repro.core.api_model import DISCARD_EVENT_ID

    d = str(tmp_path / "t")
    os.makedirs(d)
    ev_in = _BYNAME["ust_a:alpha_entry"]
    ev_out = _BYNAME["ust_a:alpha_exit"]
    launch = _BYNAME["ust_a:launch_span"]
    w = StreamWriter(os.path.join(d, "stream_5_6.ctf"), 5, 6)
    w.append(_rec(ev_out.eid, 50, _U32.pack(0)))  # unmatched exit
    w.append(_rec(ev_in.eid, 100, _U32.pack(1)))
    w.append(_rec(ev_out.eid, 175, _U32.pack(0)))  # pairs: dur 75
    w.append(
        _rec(launch.eid, 200, _U64.pack(200) + _U64.pack(230) + _pstr("k_q") + _U64.pack(1))
    )
    w.append(_rec(ev_in.eid, 300, _U32.pack(2)))  # never exits
    w.append(_rec(DISCARD_EVENT_ID, 400, _U64.pack(3)))
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    build_sidecars(d)
    _assert_roundtrip(d)
    rows = query_intervals(d)
    assert (100, 75, 5, 6, "ust_a:alpha", False) in rows
    assert (200, 30, 5, 6, "k_q", True) in rows
    assert (300, 0, 5, 6, "ust_a:alpha", False) in rows  # flushed entry
    assert len([r for r in rows if r[0] == 50]) == 0  # unmatched exit: none
    assert fold_trace(d).discarded == 3


# ---------------------------------------------------------------------------
# Tracer integration: TraceConfig.columnar writes sidecars at drain time
# ---------------------------------------------------------------------------


def _traced_dir(tmp_path, name, **cfg_kw):
    import jax.numpy as jnp

    from repro.core import TraceConfig, Tracer, kernel_span, traced_jit

    d = str(tmp_path / name)
    f = traced_jit(lambda x: (x * 3).sum(), name="triple_sum")
    x = jnp.arange(64.0)
    with Tracer(TraceConfig(out_dir=d, mode="default", columnar=True, **cfg_kw)):
        for _ in range(3):
            f(x)
            with kernel_span("k_t", grid=(2,), flops=64, bytes_accessed=256):
                pass
    return d


def test_tracer_columnar_writes_valid_sidecars(tmp_path):
    d = _traced_dir(tmp_path, "t")
    paths = stream_files(d)
    assert paths
    for p in paths:
        sc = load_sidecar(p)
        assert sc is not None
        assert sc.footer["stream_bytes"] == os.path.getsize(p)
    _assert_roundtrip(d)


def test_tracer_columnar_compressed_streams(tmp_path):
    """Staleness keys on the *container* size, so compression still works."""
    d = _traced_dir(tmp_path, "t", compress=True)
    for p in stream_files(d):
        assert load_sidecar(p) is not None
    _assert_roundtrip(d)


def test_tracer_aggregate_only_prunes_sidecars(tmp_path):
    d = _traced_dir(tmp_path, "t", aggregate_only=True)
    left = [n for n in os.listdir(d) if n.endswith((".ctf", ".ctfcol"))]
    assert left == []


# ---------------------------------------------------------------------------
# Staleness: byte-count mismatch invalidates; reads fall back, stay correct
# ---------------------------------------------------------------------------


def test_stale_sidecar_truncated_stream(tmp_path):
    d = str(tmp_path / "t")
    _build_trace(9, d)
    build_sidecars(d)
    p0 = stream_files(d)[0]
    size = os.path.getsize(p0)
    with open(p0, "r+b") as f:
        f.truncate(size - 7)
    assert load_sidecar(p0) is None  # detected
    # transparent fallback: reads still agree with pure record parsing
    _assert_roundtrip(d)


def test_stale_sidecar_appended_stream(tmp_path):
    d = str(tmp_path / "t")
    _build_trace(10, d)
    build_sidecars(d)
    p0 = stream_files(d)[0]
    with open(p0, "ab") as f:
        f.write(_rec(_BYNAME["ust_a:alpha_entry"].eid, 99_999, _U32.pack(1)))
    assert load_sidecar(p0) is None
    _assert_roundtrip(d)
    # re-indexing revalidates
    build_sidecars(d)
    assert load_sidecar(p0) is not None
    _assert_roundtrip(d)


def test_missing_sidecar_is_silent_fallback(tmp_path):
    d = str(tmp_path / "t")
    _build_trace(12, d)
    build_sidecars(d)
    os.unlink(sidecar_path(stream_files(d)[0]))
    _assert_roundtrip(d)  # partial coverage: fold per-stream, query wholesale


# ---------------------------------------------------------------------------
# Forward compatibility: unknown versions skipped, garbage never crashes
# ---------------------------------------------------------------------------


def test_unknown_sidecar_version_skipped(tmp_path):
    d = str(tmp_path / "t")
    _build_trace(13, d)
    build_sidecars(d)
    p0 = stream_files(d)[0]
    sp = sidecar_path(p0)
    with open(sp, "r+b") as f:  # bump the header version in place
        f.write(COL_HEADER.pack(COL_MAGIC, COL_VERSION + 1, 0))
    assert load_sidecar(p0) is None
    _assert_roundtrip(d)


def test_unknown_footer_version_skipped(tmp_path):
    """Header version ok but footer claims a newer format: also skipped
    (a future writer may extend only the footer)."""
    d = str(tmp_path / "t")
    _build_trace(14, d)
    build_sidecars(d)
    p0 = stream_files(d)[0]
    sp = sidecar_path(p0)
    raw = open(sp, "rb").read()
    (flen,) = struct.unpack("<I", raw[-4:])
    footer = json.loads(raw[-4 - flen : -4])
    footer["version"] = COL_VERSION + 9
    fb = json.dumps(footer, sort_keys=True).encode()
    with open(sp, "wb") as f:
        f.write(raw[: -4 - flen] + fb + struct.pack("<I", len(fb)))
    assert load_sidecar(p0) is None
    _assert_roundtrip(d)


def test_garbage_sidecar_never_crashes(tmp_path):
    d = str(tmp_path / "t")
    _build_trace(15, d)
    for p in stream_files(d):
        for junk in (b"", b"short", COL_MAGIC, COL_MAGIC + b"\xff" * 40, b"x" * 64):
            with open(sidecar_path(p), "wb") as f:
                f.write(junk)
            assert load_sidecar(p) is None
    _assert_roundtrip(d)

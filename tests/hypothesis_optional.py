"""Optional-hypothesis shim: keeps property-based tests collectable when
``hypothesis`` (a dev extra, see requirements-dev.txt) is not installed.

    from tests.hypothesis_optional import given, settings, st

With hypothesis installed these are the real decorators/strategies; without
it, ``@given(...)``-wrapped tests skip at call time via
``pytest.importorskip`` and every other test in the module runs normally.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy-construction call; never actually sampled."""

        def __getattr__(self, name):
            return lambda *a, **k: self

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.importorskip("hypothesis")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

"""Streaming aggregation service (§3.7+§6): framing, master merge vs the
offline batch combine, the forwarding tree, and tracer-driven end-to-end."""

import os
import socket
import subprocess
import sys
import time

import pytest

from repro.core.aggregate import combine_aggregates, save_tally
from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import (
    MasterServer,
    ProtocolError,
    SnapshotStreamer,
    pack_frame,
    parse_addr,
    query_composite,
    recv_frame,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mk_tally(rank: int, calls: int = 10) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 1))
    st = ApiStat()
    for i in range(calls):
        st.add(1000 + rank + i)
    t.apis[("ust_repro", "train_step")] = st
    s2 = ApiStat()
    s2.add(50 * (rank + 1))
    t.device_apis[("ust_kernel", "k")] = s2
    return t


def totals(t: Tally):
    out = {}
    for label, table in (("host", t.apis), ("device", t.device_apis)):
        for key, st in table.items():
            out[(label,) + key] = (st.calls, st.total_ns)
    return out


def wait_until(pred, timeout_s=5.0, period_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period_s)
    return pred()


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        msgs = [
            {"type": "hello", "source": "r0"},
            {"type": "snapshot", "seq": 3, "tally": mk_tally(2).to_obj()},
            {"type": "query"},
        ]
        for m in msgs:
            a.sendall(pack_frame(m))
        got = [recv_frame(b) for _ in msgs]
        assert got == msgs
        back = Tally.from_obj(got[1]["tally"])
        assert back.to_obj() == mk_tally(2).to_obj()
        a.close()
        assert recv_frame(b) is None  # clean EOF
    finally:
        b.close()


def test_frame_torn_mid_body_raises():
    a, b = socket.socketpair()
    try:
        frame = pack_frame({"type": "snapshot", "tally": mk_tally(0).to_obj()})
        a.sendall(frame[: len(frame) - 5])
        a.close()
        with pytest.raises(ProtocolError):
            recv_frame(b)
    finally:
        b.close()


def test_frame_oversize_announcement_raises():
    a, b = socket.socketpair()
    try:
        import struct

        a.sendall(struct.pack("!I", (64 << 20) + 1))
        with pytest.raises(ProtocolError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_parse_addr():
    assert parse_addr("10.0.0.1:9000") == ("10.0.0.1", 9000)
    assert parse_addr(":9000") == ("127.0.0.1", 9000)
    assert parse_addr(("h", 1)) == ("h", 1)


# ---------------------------------------------------------------------------
# Master: merge correctness against the offline batch path
# ---------------------------------------------------------------------------


def test_master_merge_matches_combine_aggregates(tmp_path):
    """Streamed snapshots and `iprof combine` over the same tallies must
    produce the same composite."""
    n = 8
    paths = []
    for r in range(n):
        p = str(tmp_path / f"rank{r}.tally")
        save_tally(mk_tally(r), p)
        paths.append(p)
    offline = combine_aggregates(paths)

    with MasterServer(port=0) as m:
        for r in range(n):
            s = SnapshotStreamer(m.addr, source=f"rank{r}")
            assert s.push(mk_tally(r))
            s.close()
        assert wait_until(lambda: m.stats()["sources"] == n)
        live, meta = query_composite(m.addr)

    assert meta["sources"] == n
    assert totals(live) == totals(offline)
    assert live.hostnames == offline.hostnames
    assert live.processes == offline.processes


def test_master_latest_snapshot_wins():
    """Snapshots are cumulative: a source's newer push replaces (never adds
    to) its older one, so re-pushes don't double-count."""
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0")
        assert s.push(mk_tally(0, calls=5))
        assert s.push(mk_tally(0, calls=9))
        s.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 2)
        t, _ = query_composite(m.addr)
    assert t.apis[("ust_repro", "train_step")].calls == 9


def test_master_ignores_stale_out_of_order_seq():
    m = MasterServer(port=0)
    m.submit("r0", mk_tally(0, calls=9), seq=5)
    m.submit("r0", mk_tally(0, calls=3), seq=2)  # stale duplicate
    assert m.composite().apis[("ust_repro", "train_step")].calls == 9


def test_master_composite_does_not_mutate_stored_tallies():
    m = MasterServer(port=0)
    for r in range(4):
        m.submit(f"r{r}", mk_tally(r))
    first = totals(m.composite())
    assert totals(m.composite()) == first  # idempotent across calls


def test_forward_tree_local_to_global():
    """rank → local master → global master: totals survive the hop."""
    with MasterServer(port=0) as g:
        with MasterServer(port=0, forward_to=g.addr, forward_period_s=0.05) as l:
            for r in range(4):
                s = SnapshotStreamer(l.addr, source=f"rank{r}")
                assert s.push(mk_tally(r))
                s.close()
            assert wait_until(lambda: l.stats()["sources"] == 4)
            expect = totals(l.composite())
            assert wait_until(
                lambda: g.stats()["sources"] == 1
                and totals(query_composite(g.addr)[0]) == expect
            )
            # local master shows up as ONE source at the global master
            _, meta = query_composite(g.addr)
            assert meta["sources"] == 1


def test_forward_survives_parent_outage():
    """A failed upstream push must re-arm the forward trigger: the composite
    reaches the parent once it comes back, even with no new rank traffic."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    parent_port = probe.getsockname()[1]
    probe.close()  # parent not up yet
    local = MasterServer(
        port=0, forward_to=f"127.0.0.1:{parent_port}", forward_period_s=0.05
    ).start()
    local._forwarder.retry_s = 0.01
    try:
        local.submit("r0", mk_tally(0))
        assert not local.flush()  # parent down: push fails, trigger survives
        with MasterServer(port=parent_port) as parent:
            assert wait_until(lambda: parent.stats()["sources"] == 1)
            t, _ = query_composite(parent.addr)
            assert t.apis[("ust_repro", "train_step")].calls == 10
    finally:
        local.stop()


def test_master_new_session_same_source_not_stale():
    """A new session from the same source restarts seq at 0; its hello must
    reset the stored seq so the fresh snapshots aren't dropped as stale."""
    with MasterServer(port=0) as m:
        s1 = SnapshotStreamer(m.addr, source="r0")
        for calls in (3, 5, 7):  # seqs 0,1,2
            assert s1.push(mk_tally(0, calls=calls))
        s1.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 3)
        s2 = SnapshotStreamer(m.addr, source="r0")  # seq restarts at 0
        assert s2.push(mk_tally(0, calls=9))
        s2.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 4)
        t, _ = query_composite(m.addr)
    assert t.apis[("ust_repro", "train_step")].calls == 9


def test_streamer_drops_without_master_then_recovers():
    """No master listening: pushes are dropped, tracing is never disturbed;
    once a master appears the next cumulative push lands in full."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()  # nothing listening here now
    s = SnapshotStreamer(f"127.0.0.1:{port}", source="r0", retry_s=0.01)
    assert not s.push(mk_tally(0))
    assert s.dropped == 1
    with MasterServer(port=port) as m:
        assert wait_until(lambda: s.push(mk_tally(0, calls=7)), timeout_s=2.0)
        assert wait_until(lambda: m.stats()["sources"] == 1)
        t, _ = query_composite(m.addr)
        assert t.apis[("ust_repro", "train_step")].calls == 7
    s.close()


# ---------------------------------------------------------------------------
# iprof top CLI against a live master
# ---------------------------------------------------------------------------


def test_iprof_top_renders_composite(capsys):
    from repro.core.iprof import main as iprof

    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        rc = iprof(["top", m.addr, "--iterations", "1", "--no-clear"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "train_step" in out and "1 sources" in out
    assert "-- device --" in out  # mk_tally has device rows


def test_iprof_top_unreachable_master(capsys):
    from repro.core.iprof import main as iprof

    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    rc = iprof(["top", f"127.0.0.1:{port}", "--iterations", "1", "--timeout", "0.2"])
    assert rc == 1


# ---------------------------------------------------------------------------
# Tracer-driven end-to-end
# ---------------------------------------------------------------------------


def test_tracer_streams_final_tally_matching_offline(tmp_path):
    """Single rank, in-process: the tracer's consumer thread pushes live
    snapshots; after stop the master composite equals tally_trace."""
    import jax.numpy as jnp

    from repro.core import TraceConfig, Tracer, traced_jit, train_step_span
    from repro.core.plugins.tally import tally_trace

    d = str(tmp_path / "t")
    with MasterServer(port=0) as m:
        f = traced_jit(lambda x: (x + 1).sum(), name="inc_sum")
        x = jnp.arange(64.0)
        cfg = TraceConfig(out_dir=d, mode="default", stream_to=m.addr, stream_period_s=0.05)
        assert cfg.online  # streaming implies the live tally
        with Tracer(cfg) as tr:
            for s_ in range(5):
                with train_step_span(s_, 2, 32) as sp:
                    sp.outs["loss"] = float(f(x))
                    sp.outs["grad_norm"] = 1.0
                time.sleep(0.03)
        assert tr.handle.streamed >= 1  # final push is unconditional
        live, _ = query_composite(m.addr)
    offline = tally_trace(d)
    assert totals(live) == totals(offline)
    assert live.hostnames == offline.hostnames


def test_tracer_serve_port_mid_run_attach(tmp_path):
    """serve_port runs an in-process master: a client can attach mid-run and
    see the live profile of the traced process."""
    import jax.numpy as jnp

    from repro.core import TraceConfig, Tracer, live_snapshot, traced_jit, train_step_span

    d = str(tmp_path / "t")
    cfg = TraceConfig(out_dir=d, mode="default", serve_port=0, stream_period_s=0.02)
    f = traced_jit(lambda x: (x * 2).sum(), name="dbl_sum")
    x = jnp.arange(64.0)
    with Tracer(cfg) as tr:
        key = ("ust_repro", "train_step")
        for s_ in range(4):
            with train_step_span(s_, 2, 32) as sp:
                sp.outs["loss"] = float(f(x))
                sp.outs["grad_norm"] = 1.0
        assert wait_until(
            lambda: query_composite(f"127.0.0.1:{tr.server.port}")[0].apis.get(key)
            is not None
        )
        assert live_snapshot() is not None  # serve-layer hook sees it too
    assert live_snapshot() is None  # session over


@pytest.mark.slow
def test_two_rank_live_example_end_to_end():
    """The acceptance scenario: examples/distributed_train.py --live runs two
    local ranks streaming through a local master to a global master, and the
    live composite must match `iprof combine` on the same run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO_ROOT, "examples", "distributed_train.py"),
            "--live",
            "--live-steps",
            "6",
        ],
        env=env,
        capture_output=True,
        text=True,
        timeout=180,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "live composite matches offline combine" in proc.stdout

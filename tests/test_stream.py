"""Streaming aggregation service (§3.7+§6): framing, the v2 delta protocol
(encode/decode, mis-based frames, resync-after-reconnect), master merge vs
the offline batch combine, the forwarding tree, and tracer-driven
end-to-end."""

import os
import socket
import subprocess
import sys
import time

import pytest

from repro.core.aggregate import combine_aggregates, save_tally
from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import (
    MasterServer,
    ProtocolError,
    SnapshotStreamer,
    StreamClient,
    pack_frame,
    parse_addr,
    recv_frame,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mk_tally(rank: int, calls: int = 10) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 1))
    st = ApiStat()
    for i in range(calls):
        st.add(1000 + rank + i)
    t.apis[("ust_repro", "train_step")] = st
    s2 = ApiStat()
    s2.add(50 * (rank + 1))
    t.device_apis[("ust_kernel", "k")] = s2
    return t


def totals(t: Tally):
    out = {}
    for label, table in (("host", t.apis), ("device", t.device_apis)):
        for key, st in table.items():
            out[(label,) + key] = (st.calls, st.total_ns)
    return out


def fetch_composite(addr, timeout_s=3.0):
    """One-shot composite read via the unified client."""
    with StreamClient(addr, timeout_s=timeout_s) as c:
        return c.composite()


def wait_until(pred, timeout_s=5.0, period_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period_s)
    return pred()


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        msgs = [
            {"type": "hello", "source": "r0"},
            {"type": "snapshot", "seq": 3, "tally": mk_tally(2).to_obj()},
            {"type": "query"},
        ]
        for m in msgs:
            a.sendall(pack_frame(m))
        got = [recv_frame(b) for _ in msgs]
        assert got == msgs
        back = Tally.from_obj(got[1]["tally"])
        assert back.to_obj() == mk_tally(2).to_obj()
        a.close()
        assert recv_frame(b) is None  # clean EOF
    finally:
        b.close()


def test_frame_torn_mid_body_raises():
    a, b = socket.socketpair()
    try:
        frame = pack_frame({"type": "snapshot", "tally": mk_tally(0).to_obj()})
        a.sendall(frame[: len(frame) - 5])
        a.close()
        with pytest.raises(ProtocolError):
            recv_frame(b)
    finally:
        b.close()


def test_frame_oversize_announcement_raises():
    a, b = socket.socketpair()
    try:
        import struct

        a.sendall(struct.pack("!I", (64 << 20) + 1))
        with pytest.raises(ProtocolError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_parse_addr():
    assert parse_addr("10.0.0.1:9000") == ("10.0.0.1", 9000)
    assert parse_addr(":9000") == ("127.0.0.1", 9000)
    assert parse_addr(("h", 1)) == ("h", 1)


# ---------------------------------------------------------------------------
# Delta encoding (protocol v2)
# ---------------------------------------------------------------------------


def grow(t: Tally, calls: int, extra_api: str = None) -> Tally:
    """Cumulatively grow a tally the way a live rank does."""
    for _ in range(calls):
        t.apis[("ust_repro", "train_step")].add(2000)
    if extra_api is not None:
        st = ApiStat()
        st.add(123)
        t.apis[("ust_repro", extra_api)] = st
    return t


def test_delta_roundtrip_through_msgpack():
    """delta_to → msgpack → apply_delta reproduces the newer cumulative
    state exactly, and the delta only carries the changed entries."""
    import msgpack

    base = mk_tally(0, calls=5)
    # a wide stable region the delta must NOT carry
    for i in range(50):
        st = ApiStat()
        st.add(10 + i)
        base.apis[("ust_jaxrt", f"cold_{i}")] = st
    older = Tally().merge(base)
    grow(base, calls=3, extra_api="optimizer_update")
    base.hostnames.add("node999")

    d = base.delta_to(older)
    assert len(d["apis"]) == 2  # only train_step + the new API changed
    assert d["hostnames"] == ["node999"]
    d = msgpack.unpackb(msgpack.packb(d, use_bin_type=True), raw=False)
    rebuilt = Tally().merge(older).apply_delta(d)
    assert rebuilt.to_obj() == base.to_obj()


def test_delta_refuses_removed_entries():
    """Cumulative tallies never shrink; a shrunk 'current' state must raise
    so the streamer falls back to a full snapshot."""
    prev = mk_tally(0)
    cur = Tally().merge(prev)
    del cur.apis[("ust_repro", "train_step")]
    with pytest.raises(ValueError):
        cur.delta_to(prev)
    cur2 = Tally().merge(prev)
    cur2.hostnames = set()
    with pytest.raises(ValueError):
        cur2.delta_to(prev)
    # removal masked by an equal-size addition must still be caught
    cur3 = Tally().merge(prev)
    del cur3.apis[("ust_repro", "train_step")]
    st = ApiStat()
    st.add(1)
    cur3.apis[("ust_repro", "replacement")] = st
    with pytest.raises(ValueError):
        cur3.delta_to(prev)


def test_master_delta_out_of_order_and_duplicate_rejected():
    """A delta applies only on exact base_seq match: duplicates (already
    superseded base) and out-of-order frames (future base) are rejected
    without corrupting the stored cumulative state."""
    m = MasterServer(port=0)
    t = mk_tally(0, calls=5)
    m.submit("r0", Tally().merge(t), seq=0)

    older = Tally().merge(t)
    grow(t, calls=4)
    d1 = t.delta_to(older)
    assert m.submit_delta("r0", d1, seq=1, base_seq=0)
    assert m.composite().apis[("ust_repro", "train_step")].calls == 9

    # duplicate redelivery of the same delta: stored seq is 1, base is 0
    assert not m.submit_delta("r0", d1, seq=1, base_seq=0)
    # out-of-order / gapped delta: base_seq 5 never existed
    assert not m.submit_delta("r0", d1, seq=6, base_seq=5)
    # unknown source (e.g. master restarted and lost state)
    assert not m.submit_delta("rX", d1, seq=1, base_seq=0)
    assert m.composite().apis[("ust_repro", "train_step")].calls == 9
    assert m.stats()["deltas"] == 1


def test_streamer_switches_to_deltas_after_hello_ack():
    """Steady state on one connection: first push is a full snapshot, later
    pushes are deltas (once hello_ack lands), and the master state tracks
    the sender's cumulative tally exactly."""
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0")
        t = mk_tally(0, calls=5)
        assert s.push(t)
        assert s.full_frames == 1
        assert wait_until(lambda: (s.poll_control() or True) and s.peer_version is not None)
        for i in range(4):
            grow(t, calls=1)
            assert s.push(t)
        assert s.delta_frames >= 3  # at most one more full before the ack
        assert wait_until(
            lambda: fetch_composite(m.addr)[0].apis[("ust_repro", "train_step")].calls == 9
        )
        assert m.deltas >= 3
        s.close()


def test_streamer_resync_every_forces_full_frames():
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0", resync_every=2)
        t = mk_tally(0, calls=1)
        assert s.push(t)
        assert wait_until(lambda: (s.poll_control() or True) and s.peer_version == 2)
        for _ in range(6):
            grow(t, calls=1)
            assert s.push(t)
        # pattern after the ack: delta, delta, full, delta, delta, full…
        assert s.full_frames >= 3
        assert s.delta_frames >= 4
        assert wait_until(
            lambda: fetch_composite(m.addr)[0].apis[("ust_repro", "train_step")].calls == 7
        )
        s.close()


def test_resync_after_master_restart():
    """Master restarts (losing all state) while the streamer holds delta
    state: the dead connection is detected, the reconnect re-hellos, and
    the first frame on the new connection is a full snapshot that rebuilds
    the master."""
    m1 = MasterServer(port=0).start()
    s = SnapshotStreamer(m1.addr, source="r0", retry_s=0.01)
    t = mk_tally(0, calls=3)
    assert s.push(t)
    assert wait_until(lambda: (s.poll_control() or True) and s.peer_version == 2)
    grow(t, calls=2)
    assert s.push(t)
    assert s.delta_frames >= 1  # delta base state exists on this connection
    m1.stop()

    grow(t, calls=1)
    # the EOF left by the dead master is seen before the next send: the push
    # fails, the connection (and its delta base state) is dropped
    assert not s.push(t)
    # "restarted" master: same role, empty state (fresh port sidesteps the
    # kernel's FIN_WAIT hold on the old one; the streamer state machine
    # can't tell the difference)
    with MasterServer(port=0) as m2:
        s.addr = parse_addr(m2.addr)
        assert wait_until(
            lambda: s.push(t)
            and m2.stats()["sources"] == 1
            and m2.composite().apis[("ust_repro", "train_step")].calls == 6,
            timeout_s=8.0,
        )
        assert m2.full_snapshots >= 1  # reconnect resynced with a full frame
    s.close()


def test_master_requests_resync_on_unknown_base():
    """A mis-based delta makes the master answer `resync`; the streamer's
    next push is then a full snapshot that heals the state."""
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0")
        t = mk_tally(0, calls=2)
        assert s.push(t)
        assert wait_until(lambda: (s.poll_control() or True) and s.peer_version == 2)
        grow(t, calls=1)
        assert s.push(t)
        assert s.delta_frames >= 1
        # simulate master-side state loss with the connection still up
        assert wait_until(lambda: m.stats()["sources"] == 1)
        m._latest.clear()
        grow(t, calls=1)
        assert s.push(t)  # delta lands on empty state → rejected → resync
        assert wait_until(lambda: (s.poll_control() or True) and s.resyncs >= 1)
        grow(t, calls=1)
        assert s.push(t)  # forced full
        assert wait_until(
            lambda: m.stats()["sources"] == 1
            and m.composite().apis[("ust_repro", "train_step")].calls == 5
        )
        assert m.resyncs_sent >= 1
        s.close()


def test_no_delta_mode_always_full():
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0", delta=False)
        t = mk_tally(0, calls=1)
        for _ in range(3):
            grow(t, calls=1)
            assert s.push(t)
        assert s.full_frames == 3 and s.delta_frames == 0
        s.close()


def test_subscribe_composites_pushes_updates():
    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        got = []
        with StreamClient(m.addr) as c:
            for t, meta in c.subscribe(period_s=0.05):
                got.append((t, meta))
                if len(got) >= 3:
                    break
        assert all(
            t.apis[("ust_repro", "train_step")].calls == 10 for t, _ in got
        )
        assert got[0][1]["sources"] == 1
        # idle master: only the first push serializes the composite, later
        # periods are tally-less heartbeats re-yielding the cached tally
        assert "unchanged" not in got[0][1]
        assert got[1][1].get("unchanged") and got[2][1].get("unchanged")


def test_forward_delta_disabled_sends_full_frames_upstream():
    """MasterServer(forward_delta=False) must honor the full-snapshot wire
    behavior on its upstream hop (TraceConfig.stream_delta plumbs here)."""
    with MasterServer(port=0) as g:
        with MasterServer(
            port=0, forward_to=g.addr, forward_period_s=0.02, forward_delta=False
        ) as l:
            for calls in (3, 5, 8):
                l.submit("r0", mk_tally(0, calls=calls))
                l.flush(force=True)
            fwd = l.forwarder
            assert fwd.delta is False
            assert fwd.full_frames >= 3 and fwd.delta_frames == 0
            assert wait_until(
                lambda: fetch_composite(g.addr)[0]
                .apis[("ust_repro", "train_step")]
                .calls
                == 8
            )


# ---------------------------------------------------------------------------
# Master: merge correctness against the offline batch path
# ---------------------------------------------------------------------------


def test_master_merge_matches_combine_aggregates(tmp_path):
    """Streamed snapshots and `iprof combine` over the same tallies must
    produce the same composite."""
    n = 8
    paths = []
    for r in range(n):
        p = str(tmp_path / f"rank{r}.tally")
        save_tally(mk_tally(r), p)
        paths.append(p)
    offline = combine_aggregates(paths)

    with MasterServer(port=0) as m:
        for r in range(n):
            s = SnapshotStreamer(m.addr, source=f"rank{r}")
            assert s.push(mk_tally(r))
            s.close()
        assert wait_until(lambda: m.stats()["sources"] == n)
        live, meta = fetch_composite(m.addr)

    assert meta["sources"] == n
    assert totals(live) == totals(offline)
    assert live.hostnames == offline.hostnames
    assert live.processes == offline.processes


def test_master_latest_snapshot_wins():
    """Snapshots are cumulative: a source's newer push replaces (never adds
    to) its older one, so re-pushes don't double-count."""
    with MasterServer(port=0) as m:
        s = SnapshotStreamer(m.addr, source="r0")
        assert s.push(mk_tally(0, calls=5))
        assert s.push(mk_tally(0, calls=9))
        s.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 2)
        t, _ = fetch_composite(m.addr)
    assert t.apis[("ust_repro", "train_step")].calls == 9


def test_master_ignores_stale_out_of_order_seq():
    m = MasterServer(port=0)
    m.submit("r0", mk_tally(0, calls=9), seq=5)
    m.submit("r0", mk_tally(0, calls=3), seq=2)  # stale duplicate
    assert m.composite().apis[("ust_repro", "train_step")].calls == 9


def test_master_composite_does_not_mutate_stored_tallies():
    m = MasterServer(port=0)
    for r in range(4):
        m.submit(f"r{r}", mk_tally(r))
    first = totals(m.composite())
    assert totals(m.composite()) == first  # idempotent across calls


def test_forward_tree_local_to_global():
    """rank → local master → global master: totals survive the hop, and (the
    forward_ranks default) every origin rank stays visible at the root."""
    with MasterServer(port=0) as g:
        with MasterServer(port=0, forward_to=g.addr, forward_period_s=0.05) as l:
            for r in range(4):
                s = SnapshotStreamer(l.addr, source=f"rank{r}")
                assert s.push(mk_tally(r))
                s.close()
            assert wait_until(lambda: l.stats()["sources"] == 4)
            expect = totals(l.composite())
            assert wait_until(
                lambda: g.stats()["sources"] == 4
                and totals(fetch_composite(g.addr)[0]) == expect
            )
            # per-rank identities pass through the hop
            _, meta = fetch_composite(g.addr)
            assert meta["sources"] == 4


def test_forward_tree_composite_mode_single_source():
    """forward_ranks=False restores the v2.0 behavior: the local master is
    one anonymous composite source at its parent."""
    with MasterServer(port=0) as g:
        with MasterServer(
            port=0, forward_to=g.addr, forward_period_s=0.05, forward_ranks=False
        ) as l:
            for r in range(4):
                s = SnapshotStreamer(l.addr, source=f"rank{r}")
                assert s.push(mk_tally(r))
                s.close()
            assert wait_until(lambda: l.stats()["sources"] == 4)
            expect = totals(l.composite())
            assert wait_until(
                lambda: g.stats()["sources"] == 1
                and totals(fetch_composite(g.addr)[0]) == expect
            )
            _, meta = fetch_composite(g.addr)
            assert meta["sources"] == 1


def test_forward_survives_parent_outage():
    """A failed upstream push must re-arm the forward trigger: the composite
    reaches the parent once it comes back, even with no new rank traffic."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    parent_port = probe.getsockname()[1]
    probe.close()  # parent not up yet
    local = MasterServer(
        port=0, forward_to=f"127.0.0.1:{parent_port}", forward_period_s=0.05
    ).start()
    local._forwarder.retry_s = 0.01
    try:
        local.submit("r0", mk_tally(0))
        assert not local.flush()  # parent down: push fails, trigger survives
        with MasterServer(port=parent_port) as parent:
            assert wait_until(lambda: parent.stats()["sources"] == 1)
            t, _ = fetch_composite(parent.addr)
            assert t.apis[("ust_repro", "train_step")].calls == 10
    finally:
        local.stop()


def test_master_new_session_same_source_not_stale():
    """A new session from the same source restarts seq at 0; its hello must
    reset the stored seq so the fresh snapshots aren't dropped as stale."""
    with MasterServer(port=0) as m:
        s1 = SnapshotStreamer(m.addr, source="r0")
        for calls in (3, 5, 7):  # seqs 0,1,2
            assert s1.push(mk_tally(0, calls=calls))
        s1.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 3)
        s2 = SnapshotStreamer(m.addr, source="r0")  # seq restarts at 0
        assert s2.push(mk_tally(0, calls=9))
        s2.close()
        assert wait_until(lambda: m.stats()["snapshots"] == 4)
        t, _ = fetch_composite(m.addr)
    assert t.apis[("ust_repro", "train_step")].calls == 9


def test_streamer_drops_without_master_then_recovers():
    """No master listening: pushes are dropped, tracing is never disturbed;
    once a master appears the next cumulative push lands in full."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()  # nothing listening here now
    s = SnapshotStreamer(f"127.0.0.1:{port}", source="r0", retry_s=0.01)
    assert not s.push(mk_tally(0))
    assert s.dropped == 1
    with MasterServer(port=port) as m:
        assert wait_until(lambda: s.push(mk_tally(0, calls=7)), timeout_s=2.0)
        assert wait_until(lambda: m.stats()["sources"] == 1)
        t, _ = fetch_composite(m.addr)
        assert t.apis[("ust_repro", "train_step")].calls == 7
    s.close()


# ---------------------------------------------------------------------------
# iprof top CLI against a live master
# ---------------------------------------------------------------------------


def test_iprof_top_renders_composite(capsys):
    from repro.core.iprof import main as iprof

    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        rc = iprof(["top", m.addr, "--iterations", "1", "--no-clear"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "train_step" in out and "1 sources" in out
    assert "-- device --" in out  # mk_tally has device rows


def test_iprof_top_live_subscribe_mode(capsys):
    from repro.core.iprof import main as iprof

    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        rc = iprof(
            ["top", m.addr, "--live", "--interval", "0.05", "--iterations", "2", "--no-clear"]
        )
    out = capsys.readouterr().out
    assert rc == 0
    assert out.count("[iprof top]") == 2 and "train_step" in out


def test_iprof_top_unreachable_master(capsys):
    from repro.core.iprof import main as iprof

    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    rc = iprof(["top", f"127.0.0.1:{port}", "--iterations", "1", "--timeout", "0.2"])
    assert rc == 1


# ---------------------------------------------------------------------------
# Tracer-driven end-to-end
# ---------------------------------------------------------------------------


def test_tracer_streams_final_tally_matching_offline(tmp_path):
    """Single rank, in-process: the tracer's consumer thread pushes live
    snapshots; after stop the master composite equals tally_trace."""
    import jax.numpy as jnp

    from repro.core import TraceConfig, Tracer, traced_jit, train_step_span
    from repro.core.plugins.tally import tally_trace

    d = str(tmp_path / "t")
    with MasterServer(port=0) as m:
        f = traced_jit(lambda x: (x + 1).sum(), name="inc_sum")
        x = jnp.arange(64.0)
        cfg = TraceConfig(out_dir=d, mode="default", stream_to=m.addr, stream_period_s=0.05)
        assert cfg.online  # streaming implies the live tally
        with Tracer(cfg) as tr:
            for s_ in range(5):
                with train_step_span(s_, 2, 32) as sp:
                    sp.outs["loss"] = float(f(x))
                    sp.outs["grad_norm"] = 1.0
                time.sleep(0.03)
        assert tr.handle.streamed >= 1  # final push is unconditional
        live, _ = fetch_composite(m.addr)
    offline = tally_trace(d)
    assert totals(live) == totals(offline)
    assert live.hostnames == offline.hostnames


def test_tracer_serve_port_mid_run_attach(tmp_path):
    """serve_port runs an in-process master: a client can attach mid-run and
    see the live profile of the traced process."""
    import jax.numpy as jnp

    from repro.core import TraceConfig, Tracer, live_snapshot, traced_jit, train_step_span

    d = str(tmp_path / "t")
    cfg = TraceConfig(out_dir=d, mode="default", serve_port=0, stream_period_s=0.02)
    f = traced_jit(lambda x: (x * 2).sum(), name="dbl_sum")
    x = jnp.arange(64.0)
    with Tracer(cfg) as tr:
        key = ("ust_repro", "train_step")
        for s_ in range(4):
            with train_step_span(s_, 2, 32) as sp:
                sp.outs["loss"] = float(f(x))
                sp.outs["grad_norm"] = 1.0
        assert wait_until(
            lambda: fetch_composite(f"127.0.0.1:{tr.server.port}")[0].apis.get(key)
            is not None
        )
        assert live_snapshot() is not None  # serve-layer hook sees it too
    assert live_snapshot() is None  # session over


@pytest.mark.slow
def test_two_rank_live_example_end_to_end():
    """The acceptance scenario: examples/distributed_train.py --live runs two
    local ranks streaming through a local master to a global master (one
    rank deliberately slowed); the live composite must match `iprof combine`
    on the same run, per-rank sums must equal the composite, and the
    cluster-scope StragglerRankPolicy must flag the slow rank (advisory
    recorded + trainer-layer watchdog fed)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO_ROOT, "examples", "distributed_train.py"),
            "--live",
            "--live-steps",
            "6",
            "--live-slow-rank",
            "1",
        ],
        env=env,
        capture_output=True,
        text=True,
        timeout=180,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "live composite matches offline combine" in proc.stdout
    assert "per-rank sums equal the merged composite" in proc.stdout
    assert "OK: straggler" in proc.stdout and "rank1 flagged" in proc.stdout

"""iprof CLI (§3.4 Fig 4) end-to-end: run → tally/pretty/timeline/validate
→ multi-rank combine."""

import json
import os

import numpy as np
import pytest

from repro.core.aggregate import save_tally
from repro.core.iprof import main as iprof
from repro.core.plugins.tally import tally_trace


def _traced_workload(tmp_path, rank=0, aggregate_only=False, columnar=False):
    """Run a tiny traced workload via the iprof 'run' subcommand."""
    out = str(tmp_path / f"trace_r{rank}")
    args = ["run", "-m", "default", "-o", out, "--rank", str(rank)]
    if aggregate_only:
        args.append("--aggregate-only")
    if columnar:
        args.append("--columnar")
    args.append("tests.iprof_target:main")
    rc = iprof(args)
    assert rc == 0
    return out


def test_run_and_tally(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["tally", out]) == 0
    text = capsys.readouterr().out
    assert "train_step" in text and "Time(%)" in text


def test_tally_jobs_matches_serial(tmp_path, capsys):
    """--jobs N renders the identical table (sharded fold, same tally)."""
    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["tally", out]) == 0
    serial = capsys.readouterr().out
    assert iprof(["tally", out, "--jobs", "3"]) == 0
    assert capsys.readouterr().out == serial
    assert iprof(["tally", out, "--jobs", "3", "--no-sidecar"]) == 0
    assert capsys.readouterr().out == serial


def test_tally_empty_trace_dir_warns(tmp_path, capsys):
    """Zero completed streams (metadata only): warn on stderr, exit 0 with
    an empty table — not a crash, not silence."""
    import shutil

    out = _traced_workload(tmp_path)
    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    shutil.copy(os.path.join(out, "metadata.json"), empty)
    capsys.readouterr()
    assert iprof(["tally", empty]) == 0
    cap = capsys.readouterr()
    assert "no completed streams" in cap.err
    assert "0 Processes" in cap.out


def test_index_then_tally_uses_sidecars(tmp_path, capsys):
    """iprof index builds .ctfcol sidecars; tally output is unchanged."""
    from repro.core.ctf import load_sidecar, stream_files

    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["tally", out]) == 0
    before = capsys.readouterr().out
    assert iprof(["index", out]) == 0
    assert "indexed" in capsys.readouterr().out
    for p in stream_files(out):
        assert load_sidecar(p) is not None
    assert iprof(["tally", out]) == 0
    assert capsys.readouterr().out == before


def test_run_columnar_writes_sidecars(tmp_path, capsys):
    """iprof run --columnar leaves valid sidecars next to the streams."""
    from repro.core.ctf import load_sidecar, stream_files

    out = _traced_workload(tmp_path, columnar=True)
    paths = stream_files(out)
    assert paths
    for p in paths:
        assert load_sidecar(p) is not None


def test_pretty(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["pretty", out, "-n", "5"]) == 0
    assert "vpid" in capsys.readouterr().out


def test_timeline(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    tl = str(tmp_path / "tl.json")
    assert iprof(["timeline", out, "-o", tl]) == 0
    doc = json.load(open(tl))
    assert len(doc["traceEvents"]) > 0


def test_validate(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    assert iprof(["validate", out]) == 0


def test_combine_ranks(tmp_path, capsys):
    """§3.7: aggregate-only rank traces → global master composite."""
    for r in range(4):
        _traced_workload(tmp_path / f"r{r}", rank=r, aggregate_only=True)
    capsys.readouterr()
    assert iprof(["combine", str(tmp_path)]) == 0
    text = capsys.readouterr().out
    assert "train_step" in text
    # composite counts = 4 ranks × 3 steps
    import re

    m = re.search(r"train_step.*?\|\s+(\d+)\s+\|", text)
    assert m and int(m.group(1)) == 12

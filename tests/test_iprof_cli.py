"""iprof CLI (§3.4 Fig 4) end-to-end: run → tally/pretty/timeline/validate
→ multi-rank combine."""

import json
import os

import numpy as np
import pytest

from repro.core.aggregate import save_tally
from repro.core.iprof import main as iprof
from repro.core.plugins.tally import tally_trace


def _traced_workload(tmp_path, rank=0, aggregate_only=False):
    """Run a tiny traced workload via the iprof 'run' subcommand."""
    out = str(tmp_path / f"trace_r{rank}")
    args = ["run", "-m", "default", "-o", out, "--rank", str(rank)]
    if aggregate_only:
        args.append("--aggregate-only")
    args.append("tests.iprof_target:main")
    rc = iprof(args)
    assert rc == 0
    return out


def test_run_and_tally(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["tally", out]) == 0
    text = capsys.readouterr().out
    assert "train_step" in text and "Time(%)" in text


def test_pretty(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    capsys.readouterr()
    assert iprof(["pretty", out, "-n", "5"]) == 0
    assert "vpid" in capsys.readouterr().out


def test_timeline(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    tl = str(tmp_path / "tl.json")
    assert iprof(["timeline", out, "-o", tl]) == 0
    doc = json.load(open(tl))
    assert len(doc["traceEvents"]) > 0


def test_validate(tmp_path, capsys):
    out = _traced_workload(tmp_path)
    assert iprof(["validate", out]) == 0


def test_combine_ranks(tmp_path, capsys):
    """§3.7: aggregate-only rank traces → global master composite."""
    for r in range(4):
        _traced_workload(tmp_path / f"r{r}", rank=r, aggregate_only=True)
    capsys.readouterr()
    assert iprof(["combine", str(tmp_path)]) == 0
    text = capsys.readouterr().out
    assert "train_step" in text
    # composite counts = 4 ranks × 3 steps
    import re

    m = re.search(r"train_step.*?\|\s+(\d+)\s+\|", text)
    assert m and int(m.group(1)) == 12

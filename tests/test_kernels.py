"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
always against the pure-jnp oracles in kernels/ref.py (interpret=True on CPU
— the kernel body itself executes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_optional import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd_scan import ssd_pallas

RNG = np.random.default_rng(0)


def randn(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,T,H,Kv,hd,window",
    [
        (1, 32, 32, 4, 4, 32, None),  # MHA
        (2, 64, 64, 8, 2, 16, None),  # GQA 4:1
        (2, 64, 64, 4, 1, 32, None),  # MQA
        (1, 48, 48, 2, 2, 64, 16),  # SWA
        (1, 16, 64, 4, 2, 32, None),  # decode-ish: q block shorter than kv
        (3, 128, 128, 2, 1, 8, 32),
    ],
)
def test_flash_attention_sweep(B, S, T, H, Kv, hd, window, dtype):
    q = randn(B, S, H, hd, dtype=dtype)
    k = randn(B, T, Kv, hd, dtype=dtype)
    v = randn(B, T, Kv, hd, dtype=dtype)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(
        q, k, v, causal=True, window=window, blk_q=16, blk_k=16, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_attention_noncausal():
    q, k, v = randn(2, 32, 4, 16), randn(2, 32, 2, 16), randn(2, 32, 2, 16)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    got = flash_attention_pallas(q, k, v, causal=False, blk_q=16, blk_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_softmax_definition():
    """Against the literal softmax(QKᵀ/√d)V definition, not just the ref."""
    q, k, v = randn(1, 16, 2, 8), randn(1, 16, 2, 8), randn(1, 16, 2, 8)
    s = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(8)
    mask = np.tril(np.ones((16, 16), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(s, -1), v)
    got = flash_attention_pallas(q, k, v, causal=True, blk_q=8, blk_k=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([8, 16, 32, 64]),
    bk=st.sampled_from([8, 16, 32, 64]),
    scale=st.floats(min_value=0.1, max_value=8.0),
)
def test_flash_attention_block_shape_invariance(bq, bk, scale):
    """Property: result is independent of BlockSpec tiling and input scale
    doesn't break the online softmax."""
    q = randn(1, 64, 2, 16, scale=scale)
    k = randn(1, 64, 2, 16, scale=scale)
    v = randn(1, 64, 2, 16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, blk_q=bq, blk_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,C,blk", [(1, 16, 32, 32), (2, 64, 128, 64), (3, 33, 96, 32)])
def test_rglru_sweep(B, S, C, blk, dtype):
    x, r, i = randn(B, S, C, dtype=dtype), randn(B, S, C, dtype=dtype), randn(B, S, C, dtype=dtype)
    lam = randn(C)
    want_y, want_h = ref.rglru_ref(x, r, i, lam)
    got_y, got_h = rglru_pallas(x, r, i, lam, blk_c=blk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), **TOL[dtype])


def test_rglru_with_initial_state():
    x, r, i = randn(2, 8, 16), randn(2, 8, 16), randn(2, 8, 16)
    lam, h0 = randn(16), randn(2, 16)
    want_y, want_h = ref.rglru_ref(x, r, i, lam, h0=h0)
    got_y, got_h = rglru_pallas(x, r, i, lam, h0=h0, interpret=True)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), rtol=2e-5, atol=2e-5)


def test_rglru_step_equals_scan():
    """Decode recurrence must continue the train-time scan exactly."""
    x, r, i = randn(2, 9, 16), randn(2, 9, 16), randn(2, 9, 16)
    lam = randn(16)
    want_y, want_h = ref.rglru_ref(x, r, i, lam)
    h = jnp.zeros((2, 16))
    for t in range(9):
        y_t, h = ref.rglru_step_ref(h, x[:, t], r[:, t], i[:, t], lam)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(want_y[:, t]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want_h), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(min_value=1, max_value=32), c=st.sampled_from([8, 16, 64]))
def test_rglru_stability_property(s, c):
    """Property: |a_t| < 1 ⇒ outputs bounded by running max of inputs (up to
    the √(1-a²) normalization) — no blowup for any gate values."""
    x, r, i = randn(1, s, c, scale=3.0), randn(1, s, c, scale=3.0), randn(1, s, c, scale=3.0)
    lam = randn(c, scale=2.0)
    y, _ = ref.rglru_ref(x, r, i, lam)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(y))) <= float(jnp.max(jnp.abs(x))) * (s + 1)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd_inputs(B, S, H, P, G, N, dtype=jnp.float32):
    return (
        randn(B, S, H, P, dtype=dtype),
        jnp.asarray(RNG.uniform(1e-3, 0.1, size=(B, S, H)), jnp.float32),
        jnp.asarray(RNG.uniform(0, 2, size=(H,)), jnp.float32),
        randn(B, S, G, N, dtype=dtype),
        randn(B, S, G, N, dtype=dtype),
        randn(H),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [(1, 32, 2, 16, 1, 8, 8), (2, 64, 4, 16, 2, 8, 16), (1, 128, 8, 32, 2, 16, 32)],
)
def test_ssd_sweep(B, S, H, P, G, N, chunk, dtype):
    x, dt, A_log, Bm, Cm, D = ssd_inputs(B, S, H, P, G, N, dtype)
    want_y, want_st = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=chunk)
    got_y, got_st = ssd_pallas(x, dt, A_log, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(got_st), np.asarray(want_st), rtol=1e-3, atol=1e-3)


def test_ssd_chunked_equals_sequential():
    """The chunked SSD must equal the token-by-token recurrence (the decode
    path) — the core state-space duality identity."""
    B, S, H, P, G, N = 2, 24, 2, 8, 1, 4
    x, dt, A_log, Bm, Cm, D = ssd_inputs(B, S, H, P, G, N)
    want_y, want_st = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=8)
    st_ = jnp.zeros((B, H, P, N))
    for t in range(S):
        y_t, st_ = ref.ssd_step_ref(st_, x[:, t], dt[:, t], A_log, Bm[:, t], Cm[:, t], D)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(want_y[:, t]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(want_st), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]))
def test_ssd_chunk_size_invariance(chunk):
    """Property: the result must not depend on the chunking."""
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 4
    x, dt, A_log, Bm, Cm, D = ssd_inputs(B, S, H, P, G, N)
    base, st0 = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=S)
    got, st1 = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st0), rtol=2e-4, atol=2e-4)


def test_ssd_state_continuation():
    """Splitting a sequence and carrying state must be exact."""
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 4
    x, dt, A_log, Bm, Cm, D = ssd_inputs(B, S, H, P, G, N)
    full, st_full = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=8)
    ya, sa = ref.ssd_ref(x[:, :16], dt[:, :16], A_log, Bm[:, :16], Cm[:, :16], D, chunk=8)
    yb, sb = ref.ssd_ref(x[:, 16:], dt[:, 16:], A_log, Bm[:, 16:], Cm[:, 16:], D, chunk=8, state0=sa)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([ya, yb], 1)), np.asarray(full), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(np.asarray(sb), np.asarray(st_full), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# causal conv1d
# ---------------------------------------------------------------------------


def test_causal_conv1d_state_continuation():
    x = randn(2, 12, 6)
    w = randn(4, 6)
    full, _ = ref.causal_conv1d_ref(x, w)
    ya, st = ref.causal_conv1d_ref(x[:, :7], w)
    yb, _ = ref.causal_conv1d_ref(x[:, 7:], w, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([ya, yb], 1)), np.asarray(full), rtol=1e-6, atol=1e-6
    )


def test_ops_dispatch_ref_on_cpu():
    from repro.kernels import ops

    q, k, v = randn(1, 16, 2, 8), randn(1, 16, 2, 8), randn(1, 16, 2, 8)
    out = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_ops_pallas_impl_selectable():
    from repro.kernels import ops

    q, k, v = randn(1, 16, 2, 8), randn(1, 16, 2, 8), randn(1, 16, 2, 8)
    out = ops.flash_attention(q, k, v, impl="pallas")
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)

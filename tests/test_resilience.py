"""Failure-path hardening: checkpoint damage, async-commit errors, daemon
survival, late masters, telemetry forwarding, and trainer drain."""

import json
import os
import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_checkpoint, list_checkpoints
from repro.configs import get_config
from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import MasterServer, SnapshotStreamer, StreamClient
from repro.core.telemetry import TelemetryDaemon
from repro.jaxcompat import make_mesh
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def mk_tally(ns=1000):
    t = Tally()
    st = ApiStat()
    st.add(ns)
    t.apis[("ust_repro", "train_step")] = st
    return t


# ---------------------------------------------------------------------------
# checkpoint damage tolerance
# ---------------------------------------------------------------------------


def _save_steps(root, steps):
    ck = Checkpointer(str(root), keep=10)
    tree = {"w": jnp.arange(8.0)}
    for s in steps:
        ck.save(s, tree, extra={"steps_done": s})
    return ck


def test_list_checkpoints_newest_first(tmp_path):
    _save_steps(tmp_path, [2, 10, 6])
    names = [os.path.basename(p) for p in list_checkpoints(str(tmp_path))]
    assert names == ["step_10", "step_6", "step_2"]
    assert latest_checkpoint(str(tmp_path)).endswith("step_10")
    assert list_checkpoints(str(tmp_path / "nowhere")) == []


def test_latest_checkpoint_skips_corrupt_manifest(tmp_path):
    _save_steps(tmp_path, [4, 8])
    with open(tmp_path / "step_8" / "manifest.json", "w") as f:
        f.write("{this is not json")
    assert latest_checkpoint(str(tmp_path)).endswith("step_4")


def test_latest_checkpoint_skips_truncated_leaf(tmp_path):
    _save_steps(tmp_path, [4, 8])
    man = json.load(open(tmp_path / "step_8" / "manifest.json"))
    leaf = tmp_path / "step_8" / man["leaves"][0]["file"]
    leaf.write_bytes(leaf.read_bytes()[:10])
    assert latest_checkpoint(str(tmp_path)).endswith("step_4")


def test_latest_checkpoint_skips_missing_leaf(tmp_path):
    _save_steps(tmp_path, [4, 8])
    man = json.load(open(tmp_path / "step_8" / "manifest.json"))
    os.remove(tmp_path / "step_8" / man["leaves"][0]["file"])
    assert latest_checkpoint(str(tmp_path)).endswith("step_4")
    # all checkpoints damaged → None, not an exception
    os.remove(tmp_path / "step_4" / "manifest.json")
    assert latest_checkpoint(str(tmp_path)) is None


def test_restore_still_validates_crc(tmp_path):
    """list_checkpoints is structural only — bit rot is caught by restore."""
    ck = _save_steps(tmp_path, [4])
    man = json.load(open(tmp_path / "step_4" / "manifest.json"))
    leaf = tmp_path / "step_4" / man["leaves"][0]["file"]
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF  # flip payload byte; size unchanged → structurally valid
    leaf.write_bytes(bytes(raw))
    path = latest_checkpoint(str(tmp_path))
    assert path.endswith("step_4")
    with pytest.raises(ValueError, match="integrity"):
        ck.restore(path, {"w": jnp.zeros(8)})


# ---------------------------------------------------------------------------
# async-commit error surfacing
# ---------------------------------------------------------------------------


def _broken_writer(ck, monkeypatch):
    def boom(step, host_leaves, extra):
        raise OSError("disk full")

    monkeypatch.setattr(ck, "_write", boom)


def test_save_async_error_surfaces_from_wait(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    _broken_writer(ck, monkeypatch)
    ck.save_async(1, {"a": np.zeros(4)})
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        ck.wait()
    ck.wait()  # error is consumed, not raised forever


def test_save_async_error_surfaces_from_next_save(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    _broken_writer(ck, monkeypatch)
    ck.save_async(1, {"a": np.zeros(4)})
    while ck._pending.is_alive():
        time.sleep(0.01)
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        ck.save(2, {"a": np.zeros(4)})
    # the checkpointer remains usable after surfacing the failure
    path = ck.save(3, {"a": np.zeros(4)})
    assert path.endswith("step_3")


def test_save_async_error_surfaces_from_next_save_async(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    _broken_writer(ck, monkeypatch)
    ck.save_async(1, {"a": np.zeros(4)})
    while ck._pending.is_alive():
        time.sleep(0.01)
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        ck.save_async(2, {"a": np.zeros(4)})


# ---------------------------------------------------------------------------
# telemetry daemon survives bad samples
# ---------------------------------------------------------------------------


def test_daemon_survives_failing_sample():
    calls = {"n": 0}

    def record(*a):
        calls["n"] += 1
        if calls["n"] <= 3:
            raise OSError("transient /proc failure")

    d = TelemetryDaemon(record, period_s=0.005)
    d.start()
    deadline = time.monotonic() + 5.0
    while (d.sample_errors < 3 or d.samples < 2) and time.monotonic() < deadline:
        time.sleep(0.01)
    d.stop()
    assert d.sample_errors >= 3  # failures counted...
    assert d.samples >= 2  # ...and the loop kept sampling afterwards
    assert d.last  # the good samples refreshed the snapshot


# ---------------------------------------------------------------------------
# streamer initial-connect retry
# ---------------------------------------------------------------------------


def _free_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_streamer_default_is_fail_fast():
    sr = SnapshotStreamer(("127.0.0.1", _free_port()), "r0", timeout_s=0.5)
    t0 = time.monotonic()
    assert sr.push(mk_tally()) is False
    assert time.monotonic() - t0 < 2.0
    assert sr.dropped == 1
    sr.close()


def test_streamer_retries_until_master_arrives():
    port = _free_port()
    box = {}

    def late_master():
        time.sleep(0.4)
        box["master"] = MasterServer(port=port).start()

    th = threading.Thread(target=late_master, daemon=True)
    th.start()
    sr = SnapshotStreamer(
        ("127.0.0.1", port), "r0", connect_retries=40, connect_backoff_s=0.05
    )
    try:
        assert sr.push(mk_tally()) is True  # blocked through the gap, then landed
        th.join()
        deadline = time.monotonic() + 5.0
        while "r0" not in box["master"].ranks() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert "r0" in box["master"].ranks()
    finally:
        sr.close()
        th.join()
        box["master"].stop()


def test_streamer_rejects_bad_retry_params():
    with pytest.raises(ValueError):
        SnapshotStreamer(("127.0.0.1", 1), "r0", connect_retries=-1)
    with pytest.raises(ValueError):
        SnapshotStreamer(("127.0.0.1", 1), "r0", connect_backoff_s=0.0)


# ---------------------------------------------------------------------------
# telemetry forwarding: submit → master.telemetry() → StreamClient meta
# ---------------------------------------------------------------------------


def test_telemetry_rides_frames_end_to_end():
    master = MasterServer(port=0).start()
    try:
        telem = {"mem_in_use": 9, "mem_limit": 100, "host_rss": 1234}
        master.submit("rank0", mk_tally(), telemetry=telem)
        master.submit("rank1", mk_tally())
        assert master.telemetry() == {"rank0": telem}
        ranks, meta = StreamClient(master.addr).ranks()
        assert set(ranks) == {"rank0", "rank1"}
        assert meta["telemetry"]["rank0"]["host_rss"] == 1234
        assert "rank1" not in meta["telemetry"]
    finally:
        master.stop()


def test_telemetry_push_is_never_elided():
    master = MasterServer(port=0).start()
    sr = SnapshotStreamer(master.addr, "r0")
    try:
        t = mk_tally()
        assert sr.push(t, skip_unchanged=True)
        deadline = time.monotonic() + 5.0
        while sr.peer_version is None and time.monotonic() < deadline:
            sr.poll_control()  # deltas (and elision) start after hello_ack
            time.sleep(0.02)
        assert sr.push(t, skip_unchanged=True)  # unchanged → elided
        assert sr.skipped == 1
        assert sr.push(t, skip_unchanged=True, telemetry={"host_rss": 7})
        assert sr.skipped == 1  # telemetry forces the frame out
        deadline = time.monotonic() + 5.0
        while not master.telemetry() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert master.telemetry().get("r0") == {"host_rss": 7}
    finally:
        sr.close()
        master.stop()


# ---------------------------------------------------------------------------
# trainer: checkpoint-and-drain + damaged-checkpoint restore fallback
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def smoke_model(mesh):
    return Model(get_config("stablelm-3b").smoke(), mesh)


SHAPE = ShapeSpec("t", "train", 32, 4)


def mk_trainer(smoke_model, mesh, tmp, steps=8, **kw):
    return Trainer(
        smoke_model,
        SHAPE,
        Partitioner(mesh),
        TrainConfig(peak_lr=5e-3, warmup=2, total_steps=100),
        TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=str(tmp), **kw),
    )


def test_drain_midrun_checkpoints_and_stops(smoke_model, mesh, tmp_path):
    t = mk_trainer(smoke_model, mesh, tmp_path / "d", steps=20)
    drained_at = []
    t.on_drain.append(lambda: drained_at.append(t.step))
    orig = t.step_fn

    class DrainAt3:
        def __call__(self, state, batch):
            if t.step == 3:
                t.request_drain()
            return orig(state, batch)

    t.step_fn = DrainAt3()
    res = t.run()
    assert res["drained"] is True
    assert res["steps_run"] < 20  # stopped early
    path = latest_checkpoint(str(tmp_path / "d"))
    assert path is not None and path.endswith(f"step_{t.step}")
    assert drained_at == [t.step]  # on_drain fired exactly once, at the drain step
    # a successor picks up exactly where the drain left off
    t2 = mk_trainer(smoke_model, mesh, tmp_path / "d", steps=t.step + 2)
    res2 = t2.run()
    assert res2["steps_run"] == 2 and res2["drained"] is False


def test_restore_falls_back_over_damaged_checkpoint(smoke_model, mesh, tmp_path):
    mk_trainer(smoke_model, mesh, tmp_path / "r", steps=8).run()  # step_4, step_8
    with open(tmp_path / "r" / "step_8" / "manifest.json", "w") as f:
        f.write("garbage")
    t = mk_trainer(smoke_model, mesh, tmp_path / "r", steps=8)
    t.run()
    assert t.step == 8  # resumed from step_4 and re-ran 4..8

"""Hardened serving tier (docs/streaming.md v3): token auth, TLS, per-tenant
namespaces + quotas, the shared broadcast hub (encode-once fanout,
slow-subscriber eviction), the unified StreamClient, and `iprof top --live`
reconnect across a master restart."""

import os
import select
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.core.iprof import main as iprof_main
from repro.core.plugins.tally import ApiStat, Tally
from repro.core.stream import (
    MasterServer,
    PROTOCOL_VERSION,
    ProtocolError,
    ServeOptions,
    ServerRejected,
    SnapshotStreamer,
    StreamClient,
    client_ssl_context,
    pack_frame,
    parse_addr,
    recv_frame,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mk_tally(rank: int, calls: int = 10, ns: int = 1000) -> Tally:
    t = Tally()
    t.hostnames.add(f"node{rank // 8:03d}")
    t.processes.add(rank)
    t.threads.add((rank, 1))
    st = ApiStat()
    for _ in range(calls):
        st.add(ns)
    t.apis[("ust_repro", "train_step")] = st
    return t


def mk_wide_tally(rows: int, calls: int = 1) -> Tally:
    """A tally with many distinct API rows — frames big enough to clog a
    deliberately tiny receive window (the slow-subscriber test)."""
    t = Tally()
    t.processes.add(0)
    for i in range(rows):
        st = ApiStat()
        for _ in range(calls):
            st.add(1000 + i)
        t.apis[("ust_repro", f"api_{i:05d}")] = st
    return t


def wait_until(pred, timeout_s=5.0, period_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period_s)
    return pred()


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def restart_master(port: int, timeout_s: float = 10.0, **kw) -> MasterServer:
    """Start a master on a just-released port, riding out the rebind race."""
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            return MasterServer(port=port, **kw).start()
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.1)


# ---------------------------------------------------------------------------
# TLS material (self-signed, generated once per session via the openssl CLI)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def tls_pair(tmp_path_factory):
    import shutil

    if shutil.which("openssl") is None:
        pytest.skip("openssl CLI not available to mint a test certificate")
    d = tmp_path_factory.mktemp("tls")
    cert, key = str(d / "cert.pem"), str(d / "key.pem")
    subprocess.run(
        [
            "openssl", "req", "-x509", "-newkey", "rsa:2048",
            "-keyout", key, "-out", cert, "-days", "2", "-nodes",
            "-subj", "/CN=localhost",
        ],
        check=True,
        capture_output=True,
    )
    return cert, key


# ---------------------------------------------------------------------------
# ServeOptions
# ---------------------------------------------------------------------------


def test_serve_options_validation():
    with pytest.raises(ValueError):
        ServeOptions(tls_key="k.pem")  # key without cert
    with pytest.raises(ValueError):
        ServeOptions(tls_ca="ca.pem")  # client-cert CA without cert
    with pytest.raises(ValueError):
        ServeOptions(max_sources=-1)
    with pytest.raises(ValueError):
        ServeOptions(hub_queue_frames=0)
    assert not ServeOptions().auth_required


def test_tenant_for_constant_time_mapping():
    o = ServeOptions(auth_tokens={"ta": "alpha", "tb": "", "tc": "default"})
    assert o.auth_required
    assert o.tenant_for("ta") == "alpha"
    assert o.tenant_for("tb") == "default"  # empty tenant → default
    assert o.tenant_for("tc") == "default"
    assert o.tenant_for("nope") is None
    assert o.tenant_for(None) is None
    assert o.tenant_for(b"ta") == "alpha"  # bytes token from the wire
    assert ServeOptions().tenant_for(None) == "default"  # auth off


# ---------------------------------------------------------------------------
# Token auth
# ---------------------------------------------------------------------------


def test_bad_token_rejected():
    with MasterServer(port=0, options=ServeOptions(auth_tokens={"s3cret": ""})) as m:
        with pytest.raises(ServerRejected) as ei:
            StreamClient(m.addr, timeout_s=3.0, token="wrong").connect()
        assert ei.value.code == "auth"
        assert wait_until(lambda: m.auth_failures >= 1)


def test_missing_token_rejected():
    with MasterServer(port=0, options=ServeOptions(auth_tokens={"s3cret": ""})) as m:
        with pytest.raises(ServerRejected):
            with StreamClient(m.addr, timeout_s=3.0) as c:  # no token at all
                c.ping()
        assert m.auth_failures >= 1
        # the composite is not readable without auth either
        with pytest.raises(ServerRejected):
            StreamClient(m.addr, timeout_s=3.0, token="").connect()


def test_good_token_binds_tenant():
    opts = ServeOptions(auth_tokens={"ta": "alpha", "td": ""})
    with MasterServer(port=0, options=opts) as m:
        with StreamClient(m.addr, token="ta") as c:
            assert c.tenant == "alpha"
            assert c.server_version == PROTOCOL_VERSION
            assert c.ping()
        with StreamClient(m.addr, token="td") as c:
            assert c.tenant == "default"
        assert m.auth_failures == 0


def test_frames_before_hello_rejected_when_auth_required():
    """A client that skips hello entirely must not reach any handler."""
    with MasterServer(port=0, options=ServeOptions(auth_tokens={"t": ""})) as m:
        s = socket.create_connection(parse_addr(m.addr), timeout=3.0)
        try:
            s.sendall(pack_frame({"type": "query", "v": PROTOCOL_VERSION}))
            reply = recv_frame(s)
            assert reply is not None and reply["type"] == "error"
            assert reply["error"] == "auth"
        finally:
            s.close()
        assert wait_until(lambda: m.auth_failures >= 1)
        assert m.queries == 0


def test_streamer_rejected_on_bad_token_counts_and_drops():
    with MasterServer(port=0, options=ServeOptions(auth_tokens={"good": ""})) as m:
        s = SnapshotStreamer(m.addr, source="r0", token="bad", retry_s=0.05)
        t = mk_tally(0)
        for _ in range(30):
            s.push(t)
            s.poll_control()
            if s.rejected:
                break
            time.sleep(0.05)
        assert s.rejected >= 1
        assert len(m.ranks()) == 0  # nothing ingested
        s.close()


# ---------------------------------------------------------------------------
# Tenant isolation + quotas
# ---------------------------------------------------------------------------


def test_tenant_a_cannot_read_tenant_b():
    opts = ServeOptions(auth_tokens={"ta": "alpha", "tb": "beta"})
    with MasterServer(port=0, options=opts) as m:
        sa = SnapshotStreamer(m.addr, source="rank0", token="ta")
        sb = SnapshotStreamer(m.addr, source="rank0", token="tb")  # same id!
        ta, tb = mk_tally(0, calls=3), mk_tally(1, calls=7)
        assert sa.push(ta) and sb.push(tb)
        assert wait_until(
            lambda: len(m.ranks(tenant="alpha")) == 1
            and len(m.ranks(tenant="beta")) == 1
        )
        with StreamClient(m.addr, token="ta") as ca:
            tal, meta = ca.composite()
            assert tal.to_obj() == ta.to_obj()  # alpha sees alpha, exactly
            assert meta["sources"] == 1
            ranks, _ = ca.ranks()
            assert ranks["rank0"].to_obj() == ta.to_obj()
        with StreamClient(m.addr, token="tb") as cb:
            tal, _ = cb.composite()
            assert tal.to_obj() == tb.to_obj()  # same source id, other state
        st = m.stats()
        assert set(st["per_tenant"]) >= {"alpha", "beta"}
        assert st["per_tenant"]["alpha"]["sources"] == 1
        sa.close()
        sb.close()


def test_subscription_is_tenant_scoped():
    opts = ServeOptions(auth_tokens={"ta": "alpha", "tb": "beta"})
    with MasterServer(port=0, options=opts) as m:
        assert m.submit("r0", mk_tally(0, calls=3), tenant="alpha")
        assert m.submit("r0", mk_tally(1, calls=9), tenant="beta")
        with StreamClient(m.addr, token="ta") as c:
            tal, meta = next(iter(c.subscribe(period_s=0.05)))
            key = ("ust_repro", "train_step")
            assert tal.apis[key].calls == 3  # alpha's tally, not beta's


def test_source_quota_rejects_and_counts():
    with MasterServer(port=0, options=ServeOptions(max_sources=2)) as m:
        assert m.submit("r0", mk_tally(0))
        assert m.submit("r1", mk_tally(1))
        assert not m.submit("r2", mk_tally(2))  # over quota
        assert m.submit("r0", mk_tally(0, calls=20))  # updates still fine
        assert m.quota_src_rejects == 1
        assert len(m.ranks()) == 2
        assert m.stats()["quota_src_rejects"] == 1


def test_row_quota_rejects_full_and_delta():
    with MasterServer(port=0, options=ServeOptions(max_tally_rows=8)) as m:
        assert m.submit("r0", mk_wide_tally(4))
        assert not m.submit("r0", mk_wide_tally(50))  # grown past the cap
        assert m.quota_row_rejects == 1
        # the last admitted state is retained untouched
        assert len(m.ranks()["r0"].apis) == 4


def test_subscriber_quota_rejects_with_error_frame():
    with MasterServer(port=0, options=ServeOptions(max_subscribers=1)) as m:
        m.submit("r0", mk_tally(0))
        c1 = StreamClient(m.addr)
        gen1 = c1.subscribe(period_s=0.05)
        next(gen1)  # first subscriber admitted and served
        assert wait_until(lambda: m.stats()["subscribers"] == 1)
        c2 = StreamClient(m.addr)
        with pytest.raises(ServerRejected) as ei:
            next(c2.subscribe(period_s=0.05))
        assert ei.value.code == "quota"
        assert m.quota_sub_rejects == 1
        gen1.close()
        c1.close()
        c2.close()
        assert wait_until(lambda: m.stats()["subscribers"] == 0)


# ---------------------------------------------------------------------------
# Broadcast hub: encode-once fanout + slow-consumer eviction
# ---------------------------------------------------------------------------


def _raw_subscribe(addr, period_s, rcvbuf=None):
    """Hand-rolled subscriber socket (so tests control draining exactly)."""
    s = socket.socket()
    if rcvbuf:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, rcvbuf)
    s.connect(parse_addr(addr))
    s.settimeout(5.0)
    s.sendall(pack_frame({"type": "hello", "v": PROTOCOL_VERSION, "source": "sub"}))
    ack = recv_frame(s)
    assert ack and ack["type"] == "hello_ack"
    s.sendall(
        pack_frame(
            {"type": "subscribe", "v": PROTOCOL_VERSION, "period_s": period_s}
        )
    )
    return s


def test_fanout_encodes_once_per_update():
    """The hub invariant: N subscribers share one serialization per update —
    ``sub_encodes`` tracks updates, not subscriber count."""
    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        subs = [_raw_subscribe(m.addr, 0.02) for _ in range(8)]
        try:
            # each subscriber gets its snapshot-on-join full frame
            for s in subs:
                msg = recv_frame(s)
                assert msg["type"] == "composite" and "tally" in msg
            base = m.sub_encodes
            n_updates = 5
            got_full = [1] * len(subs)
            for u in range(n_updates):
                m.submit("r0", mk_tally(0, calls=20 + u))
                deadline = time.monotonic() + 5.0
                # every subscriber sees this update before the next lands
                for i, s in enumerate(subs):
                    while time.monotonic() < deadline:
                        msg = recv_frame(s)
                        if msg["type"] == "composite" and "tally" in msg:
                            got_full[i] += 1
                            break
            assert all(n >= n_updates for n in got_full)
            # 8 subscribers, 5 updates: a per-subscriber encode would be ≥40
            assert m.sub_encodes - base <= n_updates + 2
            assert m.sub_frames >= 8 * n_updates
        finally:
            for s in subs:
                s.close()


def test_slow_subscriber_evicted_without_stalling_hub():
    """A subscriber that never drains gets evicted on queue overflow; the
    healthy subscriber next to it keeps receiving throughout."""
    opts = ServeOptions(hub_queue_frames=2)
    with MasterServer(port=0, options=opts) as m:
        wide = mk_wide_tally(1500)  # ~100 KB frames: clogs a 4 KB window fast
        m.submit("r0", wide)
        slow = _raw_subscribe(m.addr, 0.01, rcvbuf=4096)
        healthy = _raw_subscribe(m.addr, 0.01)
        try:
            assert recv_frame(healthy)["type"] == "composite"
            healthy_frames = 0
            for i in range(200):
                wide.apis[("ust_repro", "api_00000")].add(1000 + i)
                m.submit("r0", wide)
                r, _, _ = select.select([healthy], [], [], 0.05)
                if r:
                    recv_frame(healthy)
                    healthy_frames += 1
                if m.sub_evictions >= 1:
                    break
            assert m.sub_evictions >= 1, "slow subscriber was never evicted"
            # hub still alive for the healthy subscriber after the eviction
            m.submit("r0", mk_wide_tally(1500, calls=3))
            assert wait_until(
                lambda: select.select([healthy], [], [], 0.1)[0] != []
            )
            assert recv_frame(healthy)["type"] == "composite"
            assert healthy_frames >= 1
        finally:
            slow.close()
            healthy.close()
        assert wait_until(lambda: m.stats()["subscribers"] == 0)


# ---------------------------------------------------------------------------
# TLS
# ---------------------------------------------------------------------------


def test_tls_end_to_end_streamer_and_client(tls_pair):
    cert, key = tls_pair
    opts = ServeOptions(tls_cert=cert, tls_key=key, auth_tokens={"tok": ""})
    with MasterServer(port=0, options=opts) as m:
        s = SnapshotStreamer(
            m.addr,
            source="r0",
            token="tok",
            ssl_context=client_ssl_context(cafile=cert),
        )
        t = mk_tally(0, calls=4)
        assert s.push(t)
        assert wait_until(lambda: len(m.ranks()) == 1)
        with StreamClient(m.addr, token="tok", tls_ca=cert) as c:
            tal, meta = c.composite()
            assert tal.to_obj() == t.to_obj()
            assert m.stats()["tls"] is True
        s.close()
        assert m.tls_failures == 0


def test_tls_client_against_plaintext_server_fails_cleanly():
    """A TLS client hitting a plaintext master must get a prompt, clean
    error (the ClientHello reads as an oversized frame server-side), never
    a hang."""
    with MasterServer(port=0) as m:
        t0 = time.monotonic()
        with pytest.raises((OSError, ProtocolError)):
            StreamClient(m.addr, timeout_s=3.0, tls_ca=__file__).connect()
        assert time.monotonic() - t0 < 5.0


def test_plaintext_client_against_tls_server_fails_cleanly(tls_pair):
    cert, key = tls_pair
    with MasterServer(port=0, options=ServeOptions(tls_cert=cert, tls_key=key)) as m:
        t0 = time.monotonic()
        with pytest.raises((OSError, ProtocolError)):
            StreamClient(m.addr, timeout_s=3.0).connect()  # no TLS
        assert time.monotonic() - t0 < 10.0
        assert wait_until(lambda: m.tls_failures >= 1)


# ---------------------------------------------------------------------------
# StreamClient ergonomics + deprecated shims
# ---------------------------------------------------------------------------


def test_stream_client_reuses_one_connection():
    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0))
        with StreamClient(m.addr) as c:
            for _ in range(5):
                c.composite()
                c.ranks()
                c.groups()
            c.ping()
        assert m.queries >= 15  # 16 requests over one pooled connection


def test_stream_client_transparent_reconnect_after_restart():
    """A pooled connection that died (master restart) is retried once."""
    port = free_port()
    m1 = MasterServer(port=port).start()
    m1.submit("r0", mk_tally(0))
    c = StreamClient(f"127.0.0.1:{port}")
    tal, _ = c.composite()
    assert tal.apis
    m1.stop()
    m2 = restart_master(port)
    try:
        m2.submit("r0", mk_tally(0, calls=2))
        tal, _ = c.composite()  # pooled conn is dead: reconnects, succeeds
        assert tal.apis[("ust_repro", "train_step")].calls == 2
    finally:
        c.close()
        m2.stop()


def test_deprecated_query_helpers_still_work_and_warn():
    from repro.core.stream import query_composite, query_ranks

    with MasterServer(port=0) as m:
        m.submit("r0", mk_tally(0, calls=6))
        with pytest.warns(DeprecationWarning):
            t, meta = query_composite(m.addr)
        assert t.apis[("ust_repro", "train_step")].calls == 6
        with pytest.warns(DeprecationWarning):
            ranks, _ = query_ranks(m.addr)
        assert set(ranks) == {"r0"}


# ---------------------------------------------------------------------------
# iprof top --live reconnect
# ---------------------------------------------------------------------------


def test_top_live_reconnects_across_master_restart(capsys):
    port = free_port()
    m1 = MasterServer(port=port).start()
    m1.submit("r0", mk_tally(0))
    rc = {}

    def run_top():
        rc["rc"] = iprof_main(
            [
                "top",
                f"127.0.0.1:{port}",
                "--live",
                "--iterations",
                "6",
                "--interval",
                "0.2",
                "--no-clear",
            ]
        )

    th = threading.Thread(target=run_top, daemon=True)
    th.start()
    assert wait_until(lambda: m1.stats()["subscribers"] == 1)
    m1.submit("r0", mk_tally(0, calls=20))
    time.sleep(0.3)  # let a couple of frames render
    m1.stop()  # master restart: the old loop would die here with rc 1
    m2 = restart_master(port)
    try:
        assert wait_until(lambda: m2.stats()["subscribers"] == 1, timeout_s=15.0)
        for i in range(10):
            m2.submit("r0", mk_tally(0, calls=30 + i))
            time.sleep(0.1)
            if not th.is_alive():
                break
        th.join(timeout=30.0)
        assert not th.is_alive()
        assert rc.get("rc") == 0
    finally:
        m2.stop()


def test_top_unreachable_master_still_rc1(capsys):
    rc = iprof_main(
        ["top", f"127.0.0.1:{free_port()}", "--live", "--iterations", "1"]
    )
    assert rc == 1
    assert "unreachable" in capsys.readouterr().err


def test_top_bad_token_rc1_no_retry_loop(capsys):
    with MasterServer(port=0, options=ServeOptions(auth_tokens={"t": ""})) as m:
        t0 = time.monotonic()
        rc = iprof_main(
            [
                "top",
                f"127.0.0.1:{m.port}",
                "--live",
                "--iterations",
                "1",
                "--token",
                "wrong",
            ]
        )
        assert rc == 1
        assert time.monotonic() - t0 < 5.0  # rejected, not retried forever
        assert "rejected" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# Full CLI over TLS (serve → run --stream-to → top), subprocess e2e
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_iprof_cli_tls_auth_end_to_end(tmp_path, tls_pair):
    cert, key = tls_pair
    port = free_port()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    iprof = [sys.executable, "-m", "repro.core.iprof"]
    serve = subprocess.Popen(
        iprof
        + [
            "serve",
            "--port",
            str(port),
            "--tls-cert",
            cert,
            "--tls-key",
            key,
            "--token",
            "s3cret",
            "--duration",
            "120",
        ],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    try:
        assert wait_until(
            lambda: serve.poll() is None
            and socket.socket().connect_ex(("127.0.0.1", port)) == 0,
            timeout_s=30.0,
        )
        run = subprocess.run(
            iprof
            + [
                "run",
                "-o",
                str(tmp_path / "t"),
                "--stream-to",
                f"127.0.0.1:{port}",
                "--stream-period",
                "0.1",
                "--token",
                "s3cret",
                "--tls-ca",
                cert,
                "tests.iprof_target:main",
            ],
            env=env,
            capture_output=True,
            text=True,
            timeout=180,
            cwd=REPO_ROOT,
        )
        assert run.returncode == 0, run.stdout + run.stderr
        assert "streamed=" in run.stdout
        top = subprocess.run(
            iprof
            + [
                "top",
                f"127.0.0.1:{port}",
                "--iterations",
                "1",
                "--no-clear",
                "--token",
                "s3cret",
                "--tls-ca",
                cert,
            ],
            env=env,
            capture_output=True,
            text=True,
            timeout=60,
        )
        assert top.returncode == 0, top.stdout + top.stderr
        assert "train_step" in top.stdout
        # and without credentials the same master turns the client away
        bad = subprocess.run(
            iprof + ["top", f"127.0.0.1:{port}", "--iterations", "1"],
            env=env,
            capture_output=True,
            text=True,
            timeout=60,
        )
        assert bad.returncode == 1
    finally:
        serve.terminate()
        serve.wait(timeout=30)

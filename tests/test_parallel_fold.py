"""Parallel sharded fold: ``fold_trace(jobs=N)`` ≡ ``fold_trace(jobs=1)``.

The contract under test: sharding the per-stream fold across a process
pool changes wall-clock only, never the tally — for any trace (compressed
streams, torn tails, unmatched entries/exits, discard records) and any
job count.  The correctness unit is the ``(pid, tid)`` stream *group*:
pairing stacks are (pid, tid)-local, so groups may land on any worker in
any order, but multi-file groups (rank-prefixed dirs) must stay together
in file order.  Property-based when hypothesis is installed, seeded-loop
fallback otherwise; plus a poisoned-shard test (a corrupt stream must
surface a clear error, never a silent partial tally) and a slow-marked
1M-event smoke reusing the benchmark's trace builder.
"""

import os

import pytest

from repro.core.ctf import StreamWriter, build_sidecars, write_metadata
from repro.core.clock import ClockInfo
from repro.core.fold import _partition_groups, fold_trace, stream_groups
from repro.core.ringbuffer import RECORD_HEADER
from tests.hypothesis_optional import given, settings, st
from tests.test_fold import (
    _BYNAME,
    _MODEL,
    _U32,
    _build_trace,
    _gen_stream,
    _rec,
    canon,
)

JOB_COUNTS = (2, 4, 7)


def _assert_jobs_agree(trace_dir: str) -> None:
    ref = canon(fold_trace(trace_dir, jobs=1))
    for n in JOB_COUNTS:
        assert canon(fold_trace(trace_dir, jobs=n)) == ref, f"jobs={n} diverged"


# ---------------------------------------------------------------------------
# Identity: property-based + seeded fallback
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_parallel_fold_identity_property(seed):
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        _build_trace(seed, d)
        _assert_jobs_agree(d)


def test_parallel_fold_identity_seeded(tmp_path):
    """Seeded corpus (runs everywhere, hypothesis or not): traces spanning
    compression, torn tails, unmatched pairs, discards — every job count."""
    for seed in range(8):
        d = str(tmp_path / f"t{seed}")
        _build_trace(seed, d)
        _assert_jobs_agree(d)


def test_parallel_fold_jobs_exceeding_groups(tmp_path):
    """jobs > group count clamps (no empty workers, same result)."""
    d = str(tmp_path / "t")
    _build_trace(3, d)
    assert canon(fold_trace(d, jobs=64)) == canon(fold_trace(d, jobs=1))


def test_parallel_fold_sidecar_consistent(tmp_path):
    """Workers take the sidecar fast path per stream; result unchanged."""
    d = str(tmp_path / "t")
    _build_trace(11, d)
    ref = canon(fold_trace(d, jobs=1, use_sidecar=False))
    build_sidecars(d)
    for n in (1,) + JOB_COUNTS:
        assert canon(fold_trace(d, jobs=n, use_sidecar=True)) == ref


# ---------------------------------------------------------------------------
# Sharding unit: (pid, tid) groups
# ---------------------------------------------------------------------------


def _write_split_pair_trace(d: str) -> None:
    """Two rank-prefixed files carrying the SAME (pid, tid): an entry left
    open at the end of the first file pairs with its exit at the start of
    the second — only whole-group sharding folds it as one call."""
    import random

    os.makedirs(d, exist_ok=True)
    ev_in = _BYNAME["ust_a:alpha_entry"]
    ev_out = _BYNAME["ust_a:alpha_exit"]
    w = StreamWriter(os.path.join(d, "rank0_stream_5_6.ctf"), 5, 6)
    w.append(_gen_stream(random.Random(1), 5, 6))
    w.append(_rec(ev_in.eid, 10_000, _U32.pack(1)))  # left open here
    w.close()
    w = StreamWriter(os.path.join(d, "rank1_stream_5_6.ctf"), 5, 6)
    w.append(_rec(ev_out.eid, 10_250, _U32.pack(0)))  # …closed here: dur 250
    w.append(_gen_stream(random.Random(2), 5, 6))
    w.close()
    # a second, independent group so jobs=2 really forks two shards
    w = StreamWriter(os.path.join(d, "stream_7_8.ctf"), 7, 8)
    w.append(_gen_stream(random.Random(3), 7, 8))
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={"hostname": "split"})


def test_same_pid_tid_files_stay_one_group(tmp_path):
    d = str(tmp_path / "t")
    _write_split_pair_trace(d)
    from repro.core.ctf import stream_files

    groups = stream_groups(stream_files(d))
    assert len(groups) == 2
    split = next(g for g in groups if len(g) == 2)
    # sorted file order within the group: rank0 before rank1
    assert [os.path.basename(p) for p in split] == [
        "rank0_stream_5_6.ctf",
        "rank1_stream_5_6.ctf",
    ]
    # every partition keeps each group whole on one shard, whatever the count
    whole = {tuple(g) for g in groups}
    for shards in (2, 3, 8):
        parts = _partition_groups(groups, shards)
        assert sum(len(s) for s in parts) == len(groups)
        for shard in parts:
            for g in shard:
                assert tuple(g) in whole


def test_split_pair_folds_identically_parallel(tmp_path):
    """The cross-file pair must tally as ONE 250ns call under every job
    count — the observable proof groups never split across workers."""
    d = str(tmp_path / "t")
    _write_split_pair_trace(d)
    ref = fold_trace(d, jobs=1)
    assert ref.apis[("ust_a", "alpha")].max_ns >= 250
    _assert_jobs_agree(d)


# ---------------------------------------------------------------------------
# Failure surface: a poisoned shard must raise, never truncate the tally
# ---------------------------------------------------------------------------


def _poison(path: str) -> None:
    """Corrupt a stream into an unreadable container: zstd frame magic with
    garbage body — decompression in the worker raises."""
    with open(path, "wb") as f:
        f.write(b"\x28\xb5\x2f\xfd" + b"\x00garbage\xff" * 4)


def test_poisoned_shard_surfaces_error(tmp_path):
    d = str(tmp_path / "t")
    os.makedirs(d)
    for i in range(4):
        w = StreamWriter(os.path.join(d, f"stream_{50 + i}_{9}.ctf"), 50 + i, 9)
        import random

        w.append(_gen_stream(random.Random(i), 50 + i, 9))
        w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    _poison(os.path.join(d, "stream_52_9.ctf"))
    with pytest.raises(RuntimeError, match="parallel fold .* no partial tally"):
        fold_trace(d, jobs=2)
    # serial path fails too (same poison), so parallel hides nothing extra
    with pytest.raises(Exception):
        fold_trace(d, jobs=1)


def test_truncated_header_is_benign_not_poison(tmp_path):
    """A torn record tail is NOT an error (crash-mid-write is a normal
    trace state): both serial and parallel folds stop cleanly at it."""
    d = str(tmp_path / "t")
    os.makedirs(d)
    w = StreamWriter(os.path.join(d, "stream_1_2.ctf"), 1, 2)
    w.append(_rec(_BYNAME["ust_a:alpha_entry"].eid, 5, _U32.pack(1)))
    w.append(RECORD_HEADER.pack(999, 1, 7)[:9])  # torn mid-header
    w.close()
    w = StreamWriter(os.path.join(d, "stream_3_4.ctf"), 3, 4)
    w.append(_rec(_BYNAME["ust_a:alpha_entry"].eid, 6, _U32.pack(1)))
    w.close()
    write_metadata(d, _MODEL, ClockInfo.capture(), env={})
    assert canon(fold_trace(d, jobs=2)) == canon(fold_trace(d, jobs=1))


# ---------------------------------------------------------------------------
# Scale smoke (CI bench job: pytest -m slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_parallel_fold_1m_event_smoke(tmp_path):
    """1M events through the real recorder→ring→StreamWriter pipeline:
    jobs=4 and the sidecar fast path both reproduce the jobs=1 tally."""
    from benchmarks.analysis_speed import build_trace

    d = str(tmp_path / "t")
    os.makedirs(d)
    n = build_trace(d, 1_000_000, streams=4)
    assert n >= 950_000  # builder floors to whole record blocks
    ref = canon(fold_trace(d, jobs=1, use_sidecar=False))
    assert canon(fold_trace(d, jobs=4, use_sidecar=False)) == ref
    assert build_sidecars(d) == 4
    assert canon(fold_trace(d, jobs=4, use_sidecar=True)) == ref

"""Beyond-paper extensions: zstd-compressed CTF streams + online analysis
(the paper's §6 future work, implemented)."""

import time

import jax.numpy as jnp
import pytest

from repro.core import TraceConfig, Tracer, collective_span, traced_jit, train_step_span
from repro.core.plugins.tally import tally_trace


def workload(steps=4):
    f = traced_jit(lambda x: (x + 1).sum(), name="inc_sum")
    x = jnp.arange(256.0)
    for s in range(steps):
        with train_step_span(s, 2, 64) as sp:
            sp.outs["loss"] = float(f(x))
            sp.outs["grad_norm"] = 1.0
        with collective_span("all_reduce", 128, "data", 4):
            pass


def test_compressed_stream_roundtrip(tmp_path):
    plain, comp = str(tmp_path / "plain"), str(tmp_path / "comp")
    with Tracer(TraceConfig(out_dir=plain, mode="default")) as t1:
        workload()
    with Tracer(TraceConfig(out_dir=comp, mode="default", compress=True)) as t2:
        workload()
    tp, tc = tally_trace(plain), tally_trace(comp)
    key = ("ust_repro", "train_step")
    assert tc.apis[key].calls == tp.apis[key].calls == 4
    # compression must actually shrink the on-disk trace
    assert t2.handle.size_bytes < t1.handle.size_bytes


def test_online_tally_matches_offline(tmp_path):
    d = str(tmp_path / "online")
    with Tracer(TraceConfig(out_dir=d, mode="default", online=True)) as tr:
        workload(steps=6)
        time.sleep(0.15)  # let the consumer drain
        live = tr.online.snapshot()
        # live tally is already populated mid-session
        assert live.apis.get(("ust_repro", "train_step")) is not None
    offline = tally_trace(d)
    final = tr.online.snapshot()
    key = ("ust_repro", "train_step")
    assert final.apis[key].calls == offline.apis[key].calls == 6
    assert final.apis[key].total_ns == offline.apis[key].total_ns
    kkey = ("ust_kernel", "inc_sum")
    assert final.device_apis[kkey].calls == offline.device_apis[kkey].calls


def test_online_busy_fraction(tmp_path):
    d = str(tmp_path / "busy")
    with Tracer(TraceConfig(out_dir=d, mode="default", online=True)) as tr:
        t0 = time.monotonic_ns()
        workload(steps=3)
        time.sleep(0.12)
        frac = tr.online.busy_fraction("ust_repro", "train_step", time.monotonic_ns() - t0)
    assert 0.0 <= frac <= 1.0

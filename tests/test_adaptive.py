"""Adaptive-optimization consumer (§6): windowed metrics, policy knob turns
mid-run, advisory events in the trace, and the serve-engine hook."""

import time

from repro.core.adaptive import (
    AdaptiveContext,
    AdaptiveController,
    AdaptivePolicy,
    RingPressurePolicy,
    StreamCadencePolicy,
    ThresholdAdvisoryPolicy,
    WidenSamplingPolicy,
    build_controller,
)
from repro.core.plugins.tally import ApiStat, Tally


def tally_with(calls: int, total_ns: int) -> Tally:
    t = Tally()
    st = ApiStat()
    for _ in range(calls):
        st.add(total_ns // calls)
    t.apis[("ust_repro", "train_step")] = st
    return t


def mk_ctx(prev: Tally, cur: Tally, window_s: float = 1.0) -> AdaptiveContext:
    ctrl = AdaptiveController([], period_s=0.01)
    return AdaptiveContext(ctrl, prev, cur, window_s)


# ---------------------------------------------------------------------------
# Windowed metrics
# ---------------------------------------------------------------------------


def test_windowed_busy_fraction_uses_deltas_not_cumulative():
    prev = tally_with(calls=10, total_ns=900_000_000)  # busy history...
    cur = Tally().merge(prev)
    cur.apis[("ust_repro", "train_step")].add(100_000_000)  # ...quiet window
    ctx = mk_ctx(prev, cur, window_s=1.0)
    assert abs(ctx.busy_fraction("ust_repro", "train_step") - 0.1) < 1e-9
    assert ctx.window_calls("ust_repro", "train_step") == 1
    assert ctx.window_latency_ns("ust_repro", "train_step") == 100_000_000


def test_windowed_metrics_for_new_and_absent_apis():
    prev = Tally()
    cur = tally_with(calls=4, total_ns=200_000_000)
    ctx = mk_ctx(prev, cur, window_s=2.0)
    assert abs(ctx.busy_fraction("ust_repro", "train_step") - 0.1) < 1e-9
    assert ctx.busy_fraction("ust_repro", "never_called") == 0.0
    assert ctx.window_latency_ns("ust_repro", "never_called") == 0.0


# ---------------------------------------------------------------------------
# Policies against a live tracing session
# ---------------------------------------------------------------------------


def run_traced_steps(tmp_path, policies, steps=6, step_sleep=0.01, **cfg_kw):
    """Drive a real online session with train_step spans and the policies."""
    from repro.core import TraceConfig, Tracer, train_step_span

    cfg = TraceConfig(
        out_dir=str(tmp_path / "t"),
        mode="default",
        adaptive=policies,
        adaptive_period_s=0.02,
        flush_period_s=0.01,
        **cfg_kw,
    )
    assert cfg.online  # adaptive implies the live tally
    with Tracer(cfg) as tr:
        for s in range(steps):
            with train_step_span(s, 2, 32) as sp:
                time.sleep(step_sleep)
                sp.outs["loss"] = 0.5
                sp.outs["grad_norm"] = 1.0
        deadline = time.monotonic() + 5.0
        while not tr.adaptive.actions and time.monotonic() < deadline:
            time.sleep(0.02)
    return tr


def test_widen_sampling_policy_turns_event_knob_mid_run(tmp_path):
    """The acceptance behavior: busy_fraction over a live window flips a
    tracepoint enable bit while the session is still running."""
    from repro.core import TraceConfig, Tracer, train_step_span

    pol = WidenSamplingPolicy(
        "ust_repro",
        "train_step",
        widen_events=["ust_repro:poll_ready_entry"],
        high=0.05,  # sleeping inside the span guarantees crossing this
        low=1.1,  # never re-narrow during the test
    )
    cfg = TraceConfig(
        out_dir=str(tmp_path / "t"),
        mode="default",
        adaptive=[pol],
        adaptive_period_s=0.02,
        flush_period_s=0.01,
    )
    with Tracer(cfg) as tr:
        ev = tr.model.by_name()["ust_repro:poll_ready_entry"]
        assert tr.tp.enabled[ev.eid] == 0  # excluded by the default mode
        deadline = time.monotonic() + 5.0
        s = 0
        while not pol.widened and time.monotonic() < deadline:
            with train_step_span(s, 2, 32) as sp:
                time.sleep(0.02)
                sp.outs["loss"] = 0.5
                sp.outs["grad_norm"] = 1.0
            s += 1
        # the knob really turned, while the session was still live
        assert pol.widened and tr.tp.enabled[ev.eid] == 1
    acts = [a for a in tr.adaptive.actions if a.knob == "event:ust_repro:poll_ready_entry"]
    assert acts and acts[0].value == "on"
    assert "busy_fraction" in acts[0].reason


def test_stream_cadence_policy_retunes_stream_period(tmp_path):
    pol = StreamCadencePolicy(
        "ust_repro", "train_step", high=0.05, low=0.0, fast_s=0.03, slow_s=2.0
    )
    tr = run_traced_steps(tmp_path, [pol], step_sleep=0.02, stream_period_s=0.5)
    assert tr.cfg.stream_period_s == 0.03  # changed mid-run from busy_fraction
    assert any(a.knob == "stream_period_s" for a in tr.adaptive.actions)


def test_advisory_event_lands_in_the_trace(tmp_path):
    from repro.core.babeltrace import CTFSource

    pol = ThresholdAdvisoryPolicy("ust_repro", "train_step", high=0.05, low=0.0)
    tr = run_traced_steps(tmp_path, [pol], step_sleep=0.02)
    assert any(a.knob.startswith("busy:") for a in tr.adaptive.actions)
    advisories = [
        ev for ev in CTFSource(tr.handle.trace_dir) if ev.name == "ust_repro:advisory"
    ]
    assert advisories, "advisory events must be recorded into the trace"
    policy_name, knob, detail = advisories[0].fields[:3]
    assert policy_name == "threshold-advisory"
    assert knob.startswith("busy:ust_repro:train_step")
    assert "busy_fraction" in detail


def test_ring_pressure_policy_grows_capacity():
    """Duck-typed tracer: the policy doubles future-ring capacity when the
    window shows drops, and only advises once the cap is hit."""

    class FakeRegistry:
        def __init__(self):
            self._capacity = 1 << 12
            self.total_dropped = 0

        @property
        def capacity(self):
            return self._capacity

        def set_capacity(self, n):
            self._capacity = n

    class FakeOnline:
        def snapshot(self):
            return Tally()

    class FakeTracer:
        online = FakeOnline()
        registry = FakeRegistry()
        tp = None
        cfg = None

    ctrl = AdaptiveController(
        [RingPressurePolicy(factor=2.0, max_bytes=1 << 13)], period_s=0.0
    )
    ctrl.attach(FakeTracer())
    assert not ctrl.tick(force=True)  # baseline window
    FakeTracer.registry.total_dropped = 7
    assert ctrl.tick(force=True)
    assert FakeTracer.registry.capacity == 1 << 13
    assert any(a.knob == "ring_bytes" for a in ctrl.actions)
    # at the cap: advisory only, capacity stays
    FakeTracer.registry.total_dropped = 20
    ctrl.tick(force=True)
    assert FakeTracer.registry.capacity == 1 << 13


def test_policy_exception_does_not_stop_other_policies(tmp_path):
    class Exploding(AdaptivePolicy):
        name = "exploding"

        def tick(self, ctx):
            raise RuntimeError("boom")

    survivor = ThresholdAdvisoryPolicy("ust_repro", "train_step", high=0.05, low=0.0)
    tr = run_traced_steps(tmp_path, [Exploding(), survivor], step_sleep=0.02)
    assert any(a.policy == "threshold-advisory" for a in tr.adaptive.actions)


def test_build_controller_normalization():
    ctrl = AdaptiveController([], period_s=0.1)
    assert build_controller(ctrl) is ctrl
    assert build_controller(None) is None
    built = build_controller([ThresholdAdvisoryPolicy("p", "a")], period_s=0.3)
    assert isinstance(built, AdaptiveController) and built.period_s == 0.3


def test_on_action_callback_observes_actions(tmp_path):
    seen = []
    ctrl = AdaptiveController(
        [ThresholdAdvisoryPolicy("ust_repro", "train_step", high=0.05, low=0.0)],
        period_s=0.02,
        on_action=seen.append,
    )
    tr = run_traced_steps(tmp_path, ctrl, step_sleep=0.02)
    assert tr.adaptive is ctrl
    assert seen and seen[0].policy == "threshold-advisory"
    assert "busy_fraction" in str(seen[0])

#!/usr/bin/env python
"""Docs link check: every relative markdown link must resolve to a file.

Scans *.md at the repo root and under docs/ for [text](target) links, skips
absolute URLs and mailto:, strips #anchors, and fails (exit 1) listing any
target that does not exist on disk.  No network access — external links are
out of scope by design so CI stays hermetic.
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
INLINE_CODE_RE = re.compile(r"`[^`]*`")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(root: str):
    for name in sorted(os.listdir(root)):
        if name.endswith(".md"):
            yield os.path.join(root, name)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    broken = []
    checked = 0
    for path in md_files(root):
        base = os.path.dirname(path)
        text = open(path, encoding="utf-8").read()
        # code spans/blocks legitimately contain []()-shaped text, not links
        text = INLINE_CODE_RE.sub("", FENCE_RE.sub("", text))
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            checked += 1
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                broken.append(f"{os.path.relpath(path, root)}: {m.group(1)}")
    if broken:
        print("broken relative links:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"docs link check OK ({checked} relative links resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Docs consistency check: links, file paths, and code references.

Three passes over *.md at the repo root and under docs/, all hermetic (no
network, no imports of the package):

1. **Relative links** — every [text](target) markdown link must resolve to a
   file on disk (absolute URLs / mailto: / #anchors skipped).
2. **Path references** — inline-code / fenced-code mentions of repo paths
   (`src/...`, `docs/...`, `examples/...`, ...) must exist, so docs can't
   point at renamed or deleted files.
3. **Module & class references** — dotted module mentions (`repro.core.stream`,
   `repro.core.stream.MasterServer`) must resolve to real modules/packages
   under src/ (trailing attribute names must appear in the module source),
   and CamelCase identifiers mentioned in code spans (`MasterServer`,
   `TraceConfig`) must occur somewhere in the source tree — so renaming a
   class without updating the docs fails CI.

Exit 1 listing every broken reference. Runs in the docs CI job.
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
INLINE_CODE_RE = re.compile(r"`([^`]*)`")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

# repo paths mentioned in code spans, e.g. `src/repro/core/stream.py`
PATH_RE = re.compile(r"\b(?:src|docs|tools|tests|examples|benchmarks)/[\w./-]+\b")
# dotted module (optionally .Class/.attr) references, e.g. repro.core.stream
DOTTED_RE = re.compile(r"\brepro(?:\.\w+)+")
# CamelCase identifiers (must mix cases: skips ALLCAPS consts and lowercase)
CAMEL_RE = re.compile(r"\b[A-Z][A-Za-z0-9]*[a-z][A-Za-z0-9]*\b")

#: CamelCase words legitimately used in code spans without being identifiers
CAMEL_ALLOWLIST = {
    "Name", "Time", "Calls", "Average", "Min", "Max",  # tally table headers
    "Hostnames", "Processes", "Threads",  # tally banner fields
}

SOURCE_DIRS = ("src", "tools", "tests", "examples", "benchmarks")


def md_files(root: str):
    for name in sorted(os.listdir(root)):
        if name.endswith(".md"):
            yield os.path.join(root, name)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def source_blob(root: str) -> str:
    """Every .py under the source dirs, concatenated, for identifier lookup."""
    parts = []
    for d in SOURCE_DIRS:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, files in os.walk(top):
            dirnames[:] = [n for n in dirnames if n != "__pycache__"]
            for name in files:
                if name.endswith(".py"):
                    with open(os.path.join(dirpath, name), encoding="utf-8") as f:
                        parts.append(f.read())
    return "\n".join(parts)


def code_spans(text: str):
    """Every inline-code span and fenced-code block body in a document."""
    for m in FENCE_RE.finditer(text):
        yield m.group(0).strip("`")
    for m in INLINE_CODE_RE.finditer(FENCE_RE.sub("", text)):
        yield m.group(1)


def resolve_dotted(root: str, ref: str) -> bool:
    """`repro.a.b[.Attr…]` → does the module exist (and mention the attr)?"""
    parts = ref.split(".")
    cur = os.path.join(root, "src")
    for i, part in enumerate(parts):
        pkg = os.path.join(cur, part)
        mod = pkg + ".py"
        if os.path.isdir(pkg):
            cur = pkg
            continue
        if os.path.isfile(mod):
            attrs = parts[i + 1 :]
            if not attrs:
                return True
            with open(mod, encoding="utf-8") as f:
                src = f.read()
            return re.search(rf"\b{re.escape(attrs[0])}\b", src) is not None
        return False
    # pure package reference (repro, repro.core, ...)
    return os.path.isfile(os.path.join(cur, "__init__.py"))


def check_links(root: str, path: str, text: str, broken: list) -> int:
    checked = 0
    base = os.path.dirname(path)
    # code spans/blocks legitimately contain []()-shaped text, not links
    stripped = INLINE_CODE_RE.sub("", FENCE_RE.sub("", text))
    for m in LINK_RE.finditer(stripped):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        checked += 1
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            broken.append(f"{os.path.relpath(path, root)}: link {m.group(1)}")
    return checked


def check_code_refs(root: str, path: str, text: str, blob: str, broken: list) -> int:
    checked = 0
    rel = os.path.relpath(path, root)
    seen = set()
    for span in code_spans(text):
        for m in PATH_RE.finditer(span):
            ref = m.group(0).rstrip(".")
            if ref in seen:
                continue
            seen.add(ref)
            checked += 1
            if not os.path.exists(os.path.join(root, ref)):
                broken.append(f"{rel}: path `{ref}`")
        for m in DOTTED_RE.finditer(span):
            ref = m.group(0)
            if ref in seen:
                continue
            seen.add(ref)
            checked += 1
            if not resolve_dotted(root, ref):
                broken.append(f"{rel}: module `{ref}`")
        for m in CAMEL_RE.finditer(span):
            name = m.group(0)
            if name in seen or name in CAMEL_ALLOWLIST:
                continue
            seen.add(name)
            checked += 1
            if not re.search(rf"\b{re.escape(name)}\b", blob):
                broken.append(f"{rel}: identifier `{name}`")
    return checked


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    blob = source_blob(root)
    broken: list = []
    links = refs = 0
    for path in md_files(root):
        text = open(path, encoding="utf-8").read()
        links += check_links(root, path, text, broken)
        # code-reference pass covers the docs we author about *this* tree;
        # exhibit files (SNIPPETS.md quotes other repos' code verbatim,
        # PAPERS.md quotes abstracts) legitimately mention foreign names
        name = os.path.basename(path)
        if name == "README.md" or os.path.basename(os.path.dirname(path)) == "docs":
            refs += check_code_refs(root, path, text, blob, broken)
    if broken:
        print("broken documentation references:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(
        f"docs check OK ({links} relative links, {refs} code references resolve)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

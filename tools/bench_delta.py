#!/usr/bin/env python
"""Benchmark regression delta: compare this run's BENCH_*.json against a
previous run's artifacts and print a delta table.

Warn-only by design (exit 0 always): CI runners are noisy shared machines,
so the table is a trend signal for the reviewer, not a gate.  Metrics where
*lower* is better (tracepoint costs, wall times) and where *higher* is
better (events/s, reduction ratios) are annotated accordingly; deltas past
``--warn-pct`` get a ``!!`` marker.

    python tools/bench_delta.py --prev prev-bench/ --cur .
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: (json file, dotted key path, label, higher_is_better)
METRICS = [
    ("BENCH_smoke.json", "tracepoint_cost.disabled_ns", "tracepoint disabled ns", False),
    ("BENCH_smoke.json", "tracepoint_cost.enabled_ns", "tracepoint enabled ns", False),
    ("BENCH_smoke.json", "tracepoint_cost.drop_ns", "tracepoint drop ns", False),
    ("BENCH_smoke.json", "aggregate_scale.merge_wall_s", "aggregate merge wall s", False),
    ("BENCH_smoke.json", "analysis_speed.tally.fast_events_per_s", "tally fold ev/s", True),
    ("BENCH_smoke.json", "analysis_speed.tally.speedup", "tally fold speedup x", True),
    (
        "BENCH_smoke.json",
        "analysis_speed.composite.row_ops_ratio",
        "composite row-ops ratio x",
        True,
    ),
    (
        "BENCH_smoke.json",
        "analysis_speed.parallel.speedup_max",
        "parallel fold jobs-sweep max x",
        True,
    ),
    (
        "BENCH_smoke.json",
        "analysis_speed.parallel.sidecar_speedup",
        "columnar sidecar fold speedup x",
        True,
    ),
    (
        "BENCH_smoke.json",
        "analysis_speed.parallel.combined_speedup",
        "parallel+sidecar combined x",
        True,
    ),
    ("BENCH_analysis.json", "tally.fast_events_per_s", "analysis fold ev/s", True),
    ("BENCH_analysis.json", "parallel.speedup_max", "analysis jobs-sweep max x", True),
    ("BENCH_analysis.json", "parallel.sidecar_speedup", "analysis sidecar x", True),
    ("BENCH_smoke.json", "stream_bw.ratio", "stream delta reduction x", True),
    # BENCH_stream.json superseded BENCH_stream_bw.json when the fanout
    # sweep landed; the old name is kept one transition cycle so the first
    # run after the rename still prints a delta against prior artifacts.
    ("BENCH_stream_bw.json", "ratio", "stream_bw standalone x", True),
    ("BENCH_stream.json", "ratio", "stream_bw standalone x", True),
    (
        "BENCH_stream.json",
        "fanout.encode_flatness",
        "hub fanout encode flatness (≈1)",
        False,
    ),
    (
        "BENCH_stream.json",
        "fanout.bytes_per_delta_per_sub",
        "hub fanout B/delta/sub",
        False,
    ),
    ("BENCH_collection.json", "enabled_net_ns", "collection enabled net ns", False),
    ("BENCH_collection.json", "pair_net_ns_per_event", "collection pair net ns/ev", False),
    ("BENCH_collection.json", "speedup_pair", "collection pair speedup x", True),
    ("BENCH_collection.json", "speedup_single", "collection single speedup x", True),
    (
        "BENCH_collection.json",
        "throughput_events_per_s",
        "collection throughput ev/s",
        True,
    ),
    ("BENCH_collection.json", "modes.full_ns_per_event", "fidelity full ns/ev", False),
    ("BENCH_collection.json", "modes.sampled_ns_per_event", "fidelity sampled ns/ev", False),
    (
        "BENCH_collection.json",
        "modes.tally_only_ns_per_event",
        "fidelity tally-only ns/ev",
        False,
    ),
    ("BENCH_collection.json", "modes.off_ns_per_event", "fidelity off ns/ev", False),
]


def _dig(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def _load(root: str, exclude: str = None) -> dict:
    """filename → parsed JSON, for every BENCH_*.json under root (any depth —
    artifact downloads sometimes nest).  ``exclude`` drops files under that
    directory, so scanning the repo root for *current* results never sweeps
    up the downloaded previous-run artifacts."""
    out = {}
    exclude_abs = os.path.abspath(exclude) + os.sep if exclude else None
    for path in glob.glob(os.path.join(root, "**", "BENCH_*.json"), recursive=True):
        if exclude_abs and os.path.abspath(path).startswith(exclude_abs):
            continue
        try:
            with open(path) as f:
                out.setdefault(os.path.basename(path), json.load(f))
        except (OSError, json.JSONDecodeError):
            pass
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True, help="directory of the previous run's artifacts")
    ap.add_argument("--cur", default=".", help="directory of this run's BENCH_*.json")
    ap.add_argument("--warn-pct", type=float, default=20.0, help="flag deltas past this %%")
    args = ap.parse_args()

    prev, cur = _load(args.prev), _load(args.cur, exclude=args.prev)
    if not prev:
        print(f"[bench-delta] no previous BENCH_*.json under {args.prev!r} — first run?")
        return 0
    if not cur:
        print(f"[bench-delta] no current BENCH_*.json under {args.cur!r}")
        return 0

    rows = []
    warned = 0
    for fname, keypath, label, higher_better in METRICS:
        p = _dig(prev.get(fname, {}), keypath)
        c = _dig(cur.get(fname, {}), keypath)
        if p is None or c is None or p == 0:
            continue
        pct = 100.0 * (c - p) / abs(p)
        regressed = (pct < 0) if higher_better else (pct > 0)
        flag = "!!" if (regressed and abs(pct) >= args.warn_pct) else "  "
        warned += flag == "!!"
        arrow = "higher=better" if higher_better else "lower=better"
        rows.append((label, p, c, pct, flag, arrow))

    if not rows:
        print("[bench-delta] no overlapping metrics between runs")
        return 0
    w = max(len(r[0]) for r in rows)
    print(f"{'metric'.ljust(w)} | {'prev':>12} | {'cur':>12} | {'delta':>8} |")
    print("-" * (w + 44))
    for label, p, c, pct, flag, arrow in rows:
        print(f"{label.ljust(w)} | {p:12.4g} | {c:12.4g} | {pct:+7.1f}% | {flag} ({arrow})")
    if warned:
        print(
            f"[bench-delta] {warned} metric(s) moved past the {args.warn_pct:.0f}% "
            "warn threshold (warn-only: not failing the job)"
        )
    return 0  # warn-only gate: never fail CI on shared-runner noise


if __name__ == "__main__":
    sys.exit(main())

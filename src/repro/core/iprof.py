"""iprof — THAPI's launcher/analyzer CLI (§3.4, Fig 4).

    "Tracing begins by launching the application using the iprof launcher…
     iprof allows filtering events, choosing tracing modes, turning on or off
     features such as hardware telemetry, and specifying parsing and analysis
     types for the collected traces."

Usage:
    python -m repro.core.iprof run  -m default --sample -o /tmp/t -- pkg.module:main arg1 ...
    python -m repro.core.iprof tally    /tmp/t [--device] [--top N] [--jobs N]
    python -m repro.core.iprof index    /tmp/t              # build .ctfcol sidecars
    python -m repro.core.iprof pretty   /tmp/t [-n N] [--filter memcpy]
    python -m repro.core.iprof timeline /tmp/t -o timeline.json
    python -m repro.core.iprof validate /tmp/t
    python -m repro.core.iprof combine  /tmp/agg_root   # §3.7 batch global master
    python -m repro.core.iprof serve --port 9000        # streaming master (§3.7+§6)
    python -m repro.core.iprof top   127.0.0.1:9000 [--live] [--by-rank]  # live composite view
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
from typing import List, Optional

from .aggregate import combine_aggregates, find_aggregates
from .plugins import pretty as pretty_plugin
from .plugins import tally as tally_plugin
from .plugins import timeline as timeline_plugin
from .plugins import validate as validate_plugin
from .tracepoints import FIDELITY_MODES
from .tracer import MODES, TraceConfig, Tracer


def _run(args) -> int:
    target = args.entry
    mod_name, _, fn_name = target.partition(":")
    mod = importlib.import_module(mod_name)
    fn = getattr(mod, fn_name or "main")
    cfg = TraceConfig(
        out_dir=args.out,
        mode=args.mode,
        sample=args.sample,
        sample_period_s=args.sample_period,
        aggregate_only=args.aggregate_only,
        rank=args.rank,
        ranks=None if args.ranks is None else [int(r) for r in args.ranks.split(",")],
        online=args.online,
        stream_to=args.stream_to,
        stream_period_s=args.stream_period,
        stream_delta=not args.no_stream_delta,
        stream_resync_every=args.stream_resync_every,
        serve_port=args.serve_port,
        legacy_graph=args.legacy_graph,
        ring_reserve=not args.no_ring_reserve,
        columnar=args.columnar,
        fidelity=args.fidelity,
        sampling_interval=args.sampling_interval,
        sampling_seed=args.sampling_seed,
        stream_token=args.token,
        stream_tls_ca=args.tls_ca,
    )
    old_argv = sys.argv
    sys.argv = [target] + list(args.args)
    try:
        with Tracer(cfg) as tr:
            fn()
    finally:
        sys.argv = old_argv
    h = tr.handle
    line = (
        f"[iprof] trace: {h.trace_dir} mode={h.mode} events={h.events} "
        f"dropped={h.dropped} bytes={h.size_bytes}"
    )
    if h.fidelity != "full":
        line += f" fidelity={h.fidelity}"
        if h.fidelity == "sampled":
            line += f" (1/{cfg.sampling_interval} systematic, tallies estimated)"
    if args.stream_to:
        line += f" streamed={h.streamed} stream_dropped={h.stream_dropped}"
    print(line)
    return 0


def _tally(args) -> int:
    from .ctf import stream_files

    if not stream_files(args.trace_dir):
        # zero completed streams: a valid (if sad) state — the workload
        # crashed before the first drain, traced nothing, or ran
        # --aggregate-only (use `iprof combine` there).  Render the empty
        # tally rather than erroring, but say why it is empty.
        print(
            f"[iprof] warning: no completed streams in {args.trace_dir} "
            "(empty trace, crashed workload, or aggregate-only run — "
            "see `iprof combine`); tally is empty",
            file=sys.stderr,
        )
    t = tally_plugin.tally_trace(
        args.trace_dir,
        legacy_graph=args.legacy_graph,
        jobs=args.jobs if args.jobs > 0 else None,  # 0 → one per CPU
        use_sidecar=not args.no_sidecar,
    )
    print(tally_plugin.render(t, top=args.top, device=False))
    if args.device or t.device_apis:
        print("\n-- device --")
        print(tally_plugin.render(t, top=args.top, device=True))
    return 0


def _index(args) -> int:
    from .ctf import build_sidecars

    n = build_sidecars(args.trace_dir)
    print(f"[iprof] indexed {n} stream(s): columnar sidecars written")
    return 0


def _pretty(args) -> int:
    pretty_plugin.pretty_print(args.trace_dir, limit=args.n, name_filter=args.filter)
    return 0


def _timeline(args) -> int:
    n = timeline_plugin.write_timeline(args.trace_dir, args.out)
    print(f"[iprof] wrote {n} timeline events to {args.out} (open in ui.perfetto.dev)")
    return 0


def _validate(args) -> int:
    findings = validate_plugin.validate_trace(args.trace_dir)
    print(validate_plugin.render(findings))
    return 0 if not any(f.severity == "error" for f in findings) else 2


def _parse_tokens(specs) -> Optional[dict]:
    """``--token TOK[=TENANT]`` (repeatable) → {token: tenant} or None."""
    if not specs:
        return None
    tokens = {}
    for spec in specs:
        tok, sep, tenant = spec.partition("=")
        if not tok:
            raise ValueError(f"bad --token {spec!r}: empty token")
        tokens[tok] = tenant if sep and tenant else "default"
    return tokens


def _serve(args) -> int:
    """Run a streaming master (local when --forward-to, else global)."""
    from .stream import MasterServer, ServeOptions

    rollup = args.rollup_groups
    if rollup is not None:
        if rollup.isdigit() and int(rollup) > 0:
            rollup = int(rollup)
        elif rollup != "host":
            print(
                f"[iprof] bad --rollup-groups {rollup!r}: want 'host' or a "
                "positive integer bucket size",
                file=sys.stderr,
            )
            return 2
    try:
        opts = ServeOptions(
            fanout=args.fanout,
            forward_ranks=not args.no_forward_ranks,
            rollup_groups=rollup,
            tls_cert=args.tls_cert,
            tls_key=args.tls_key,
            tls_ca=args.tls_ca,
            auth_tokens=_parse_tokens(args.token),
            max_sources=args.max_sources,
            max_tally_rows=args.max_tally_rows,
            max_subscribers=args.max_subscribers,
            forward_token=args.forward_token,
            forward_tls_ca=args.forward_tls_ca,
            source_ttl_s=args.source_ttl,
        )
    except ValueError as e:
        print(f"[iprof] bad serving options: {e}", file=sys.stderr)
        return 2
    try:
        m = MasterServer(
            port=args.port,
            host=args.bind,
            forward_to=args.forward_to,
            forward_period_s=args.forward_period,
            options=opts,
        ).start()
    except OSError as e:
        # covers bind errors and ssl.SSLError loading a bad cert/key pair
        print(f"[iprof] cannot start master on {args.bind}:{args.port}: {e}", file=sys.stderr)
        return 1
    role = f"local master → {args.forward_to}" if args.forward_to else "global master"
    hardened = []
    if opts.tls_cert:
        hardened.append("tls")
    if opts.auth_required:
        hardened.append(f"auth[{len(opts.auth_tokens)} token(s)]")
    suffix = f" ({', '.join(hardened)})" if hardened else ""
    print(f"[iprof] {role} listening on {m.addr}{suffix}", flush=True)
    try:
        if args.duration is not None:
            time.sleep(args.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        m.stop()
        st = m.stats()
        line = (
            f"[iprof] master stopped: {st['sources']} sources, "
            f"{st['snapshots']} snapshots ({st['deltas']} deltas, "
            f"{st['resyncs']} resyncs), {st['queries']} queries"
        )
        rejects = (
            st["auth_failures"]
            + st["tls_failures"]
            + st["quota_src_rejects"]
            + st["quota_row_rejects"]
            + st["quota_sub_rejects"]
        )
        if rejects:
            line += (
                f"; rejects: {st['auth_failures']} auth, {st['tls_failures']} tls, "
                f"{st['quota_src_rejects']}/{st['quota_row_rejects']}/"
                f"{st['quota_sub_rejects']} quota(src/row/sub), "
                f"{st['sub_evictions']} slow-subscriber evictions"
            )
        if st.get("fence_rejects") or st.get("source_gc"):
            line += (
                f"; elastic: {st.get('fence_rejects', 0)} fenced frames, "
                f"{st.get('source_gc', 0)} sources GC'd"
            )
        print(line)
    return 0


def _render_composite(args, t, meta, ranks=None, groups=None) -> None:
    """One `iprof top` refresh: header line + tally table(s)."""
    if not args.no_clear:
        print("\x1b[2J\x1b[H", end="")
    age = max(0.0, time.time() - meta["updated"]) if meta.get("updated") else 0.0
    print(
        f"[iprof top] {args.addr} | {meta.get('sources', 0)} sources | "
        f"{meta.get('snapshots', 0)} snapshots | updated {age:.1f}s ago"
    )
    print(tally_plugin.render(t, top=args.top, device=False))
    if args.device or t.device_apis:
        print("\n-- device --")
        print(tally_plugin.render(t, top=args.top, device=True))
    if ranks is not None:
        print("\n-- ranks --")
        print(
            tally_plugin.render_by_rank(
                ranks,
                top=args.top,
                device=args.device,
                incarnations=meta.get("incarnations"),
                retired=meta.get("retired"),
            )
        )
    if groups is not None:
        print("\n-- groups --")
        print(
            tally_plugin.render_by_rank(
                groups, top=args.top, device=args.device, label="Group"
            )
        )


def _top_live(args, client_kw) -> int:
    """``--live``: hold a subscription open, rendering pushed composites.

    Survives master restarts: on disconnect after a successful attach the
    loop reconnects with capped exponential backoff (starting at
    min(1s, --interval), doubling to --reconnect-max-wait) and re-subscribes
    on the fresh connection.  ``--no-reconnect`` restores one-shot semantics;
    a first connect that never succeeds is still rc-1 "unreachable".
    """
    from .stream import ProtocolError, ServerRejected, StreamClient

    shown = 0
    ever_connected = False
    wait = min(1.0, max(args.interval, 0.05))
    while True:
        try:
            with StreamClient(args.addr, timeout_s=args.timeout, **client_kw) as c:
                ever_connected = True
                for t, meta in c.subscribe(period_s=args.interval, by_rank=args.by_rank):
                    wait = min(1.0, max(args.interval, 0.05))  # healthy: reset backoff
                    _render_composite(args, t, meta, ranks=meta.get("ranks"))
                    shown += 1
                    if args.iterations is not None and shown >= args.iterations:
                        return 0
            # generator exhausted: master closed the stream cleanly
        except ServerRejected as e:
            print(f"[iprof] master at {args.addr} rejected us: {e}", file=sys.stderr)
            return 1
        except (OSError, ProtocolError) as e:
            if not ever_connected:
                print(f"[iprof] master at {args.addr} unreachable: {e}", file=sys.stderr)
                return 1
            if args.no_reconnect:
                print(f"[iprof] master at {args.addr} lost: {e}", file=sys.stderr)
                return 1
        if args.no_reconnect:
            return 0
        print(
            f"[iprof] lost master at {args.addr}; retrying in {wait:.1f}s",
            file=sys.stderr,
        )
        time.sleep(wait)
        wait = min(wait * 2, args.reconnect_max_wait)


def _top(args) -> int:
    """Attach to a master; render the live composite, refreshing.

    Default mode polls one reused query connection per refresh; ``--live``
    subscribes for pushed composites (the v2 ``subscribe`` frame) and
    reconnects across master restarts.  ``--by-rank`` appends the per-rank
    breakdown table — the straggler/skew view.
    """
    from .aggregate import merge_tallies
    from .stream import ProtocolError, ServerRejected, StreamClient

    if args.live and args.by_group:
        print(
            "[iprof] --by-group is poll-only; ignoring --live for this view",
            file=sys.stderr,
        )
    client_kw = {"token": args.token, "tls_ca": args.tls_ca}
    try:
        if args.live and not args.by_group:  # group view is poll-only
            return _top_live(args, client_kw)
        with StreamClient(args.addr, timeout_s=args.timeout, **client_kw) as c:
            i = 0
            while args.iterations is None or i < args.iterations:
                if i:
                    time.sleep(args.interval)
                i += 1
                if args.by_group:
                    groups, meta = c.groups()
                    if not meta.get("rollup"):
                        print(
                            f"[iprof] master at {args.addr} runs without "
                            "--rollup-groups; no group breakdown to show",
                            file=sys.stderr,
                        )
                        return 1
                    copies = [tally_plugin.Tally().merge(t) for t in groups.values()]
                    t = merge_tallies(copies)[0] if copies else tally_plugin.Tally()
                    _render_composite(args, t, meta, groups=groups)
                elif args.by_rank:
                    ranks, meta = c.ranks()
                    # merge_tallies folds in place: merge copies, keep ranks intact
                    copies = [tally_plugin.Tally().merge(t) for t in ranks.values()]
                    t = merge_tallies(copies)[0] if copies else tally_plugin.Tally()
                    _render_composite(args, t, meta, ranks=ranks)
                else:
                    t, meta = c.composite()
                    _render_composite(args, t, meta)
        return 0
    except ValueError:
        print(f"[iprof] bad master address {args.addr!r} (want host:port)", file=sys.stderr)
        return 2
    except ServerRejected as e:
        print(f"[iprof] master at {args.addr} rejected us: {e}", file=sys.stderr)
        return 1
    except (OSError, ProtocolError) as e:
        print(f"[iprof] master at {args.addr} unreachable: {e}", file=sys.stderr)
        return 1


def _combine(args) -> int:
    paths = find_aggregates(args.root)
    if not paths:
        print(f"[iprof] no .tally aggregates under {args.root}", file=sys.stderr)
        return 1
    t = combine_aggregates(paths, fanout=args.fanout)
    print(tally_plugin.render(t))
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="iprof", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("run", help="launch a traced entry point")
    r.add_argument("-m", "--mode", choices=MODES, default="default")
    r.add_argument(
        "--fidelity",
        choices=FIDELITY_MODES,
        default="full",
        help="fidelity ladder rung: full records everything enabled, sampled "
        "keeps 1/N of entry/exit pairs (tallies report unbiased ~estimates), "
        "tally-only folds in-process without writing streams, off disables "
        "collection (repro.trace.set_mode can move the run mid-flight)",
    )
    r.add_argument(
        "--sampling-interval",
        type=int,
        default=64,
        metavar="N",
        help="keep 1 of every N entry/exit pairs on the sampled rung",
    )
    r.add_argument(
        "--sampling-seed",
        type=int,
        default=None,
        help="seed the per-thread sampling phase for reproducible sampled runs",
    )
    r.add_argument("--sample", action="store_true", help="enable device telemetry (§3.5)")
    r.add_argument("--sample-period", type=float, default=0.05)
    r.add_argument("-o", "--out", required=True)
    r.add_argument("--aggregate-only", action="store_true", help="§3.7 aggregate-only mode")
    r.add_argument("--rank", type=int, default=0)
    r.add_argument("--ranks", default=None, help="comma-separated ranks to trace (§3.2)")
    r.add_argument("--online", action="store_true", help="live tally on the consumer (§6)")
    r.add_argument(
        "--stream-to", default=None, help="push live snapshots to a master at host:port"
    )
    r.add_argument("--stream-period", type=float, default=0.25)
    r.add_argument(
        "--no-stream-delta",
        action="store_true",
        help="disable v2 delta frames: push full snapshots every period",
    )
    r.add_argument(
        "--stream-resync-every",
        type=int,
        default=32,
        help="full-snapshot resync frame every N delta pushes",
    )
    r.add_argument(
        "--token",
        default=None,
        help="auth token sent in the stream hello (masters started with --token)",
    )
    r.add_argument(
        "--tls-ca",
        default=None,
        metavar="PEM",
        help="connect to the master over TLS, trusting this CA/cert bundle",
    )
    r.add_argument(
        "--serve-port",
        type=int,
        default=None,
        help="serve this process's live tally on a local master port (iprof top attaches)",
    )
    r.add_argument(
        "--legacy-graph",
        action="store_true",
        help="aggregate-only tallying via the legacy Babeltrace-style graph",
    )
    r.add_argument(
        "--columnar",
        action="store_true",
        help="also write per-stream .ctfcol columnar sidecars at drain time "
        "(tally/timeline reads skip record parsing)",
    )
    r.add_argument(
        "--no-ring-reserve",
        action="store_true",
        help="recorders use the legacy bytes-build + ring write path instead "
        "of the zero-allocation reserve/commit pack_into codegen",
    )
    r.add_argument("entry", help="pkg.module:function")
    r.add_argument("args", nargs="*")
    r.set_defaults(fn=_run)

    t = sub.add_parser("tally", help="summary table (§4.3)")
    t.add_argument("trace_dir")
    t.add_argument("--top", type=int, default=None)
    t.add_argument("--device", action="store_true")
    t.add_argument(
        "--legacy-graph",
        action="store_true",
        help="tally via the full Babeltrace-style graph instead of the "
        "single-pass fold engine (slow; identical result)",
    )
    t.add_argument(
        "--jobs",
        type=int,
        default=1,
        help="shard the fold across N worker processes (0 = one per CPU); "
        "identical result for every N",
    )
    t.add_argument(
        "--no-sidecar",
        action="store_true",
        help="ignore .ctfcol columnar sidecars; always parse records",
    )
    t.set_defaults(fn=_tally)

    ix = sub.add_parser(
        "index", help="build columnar .ctfcol sidecars for an existing trace"
    )
    ix.add_argument("trace_dir")
    ix.set_defaults(fn=_index)

    pr = sub.add_parser("pretty", help="pretty-print events (§3.4)")
    pr.add_argument("trace_dir")
    pr.add_argument("-n", type=int, default=None)
    pr.add_argument("--filter", default=None)
    pr.set_defaults(fn=_pretty)

    tl = sub.add_parser("timeline", help="Perfetto timeline export (§3.6)")
    tl.add_argument("trace_dir")
    tl.add_argument("-o", "--out", default="timeline.json")
    tl.set_defaults(fn=_timeline)

    v = sub.add_parser("validate", help="post-mortem validation (§4.2)")
    v.add_argument("trace_dir")
    v.set_defaults(fn=_validate)

    c = sub.add_parser("combine", help="merge rank aggregates (§3.7)")
    c.add_argument("root")
    c.add_argument("--fanout", type=int, default=32)
    c.set_defaults(fn=_combine)

    s = sub.add_parser("serve", help="run a streaming aggregation master (§3.7+§6)")
    s.add_argument("--port", type=int, default=9000, help="0 picks an ephemeral port")
    s.add_argument("--bind", default="127.0.0.1")
    s.add_argument(
        "--forward-to", default=None, help="parent master host:port (makes this a local master)"
    )
    s.add_argument("--forward-period", type=float, default=0.5)
    s.add_argument("--fanout", type=int, default=32)
    s.add_argument(
        "--duration", type=float, default=None, help="serve for N seconds then exit (default: forever)"
    )
    s.add_argument(
        "--no-forward-ranks",
        action="store_true",
        help="forward one merged composite upstream instead of the per-rank breakdown",
    )
    s.add_argument(
        "--rollup-groups",
        default=None,
        metavar="HOST|N",
        help="aggregate sources into node-level rollup groups on ingest: "
        "'host' groups by hostname, an integer N buckets ranks N-at-a-time "
        "(pre-aggregation for >1k-rank trees; query with iprof top --by-group)",
    )
    s.add_argument(
        "--tls-cert",
        default=None,
        metavar="PEM",
        help="serve over TLS with this certificate (chain) file",
    )
    s.add_argument(
        "--tls-key",
        default=None,
        metavar="PEM",
        help="private key for --tls-cert (default: key is in the cert file)",
    )
    s.add_argument(
        "--tls-ca",
        default=None,
        metavar="PEM",
        help="with --tls-cert: require and verify client certificates against "
        "this CA (mutual TLS); without it, also used as the CA for "
        "--forward-to upstream TLS",
    )
    s.add_argument(
        "--token",
        action="append",
        default=None,
        metavar="TOK[=TENANT]",
        help="require hello auth; repeatable — each token maps its clients "
        "into TENANT's namespace (default tenant when omitted)",
    )
    s.add_argument(
        "--max-sources",
        type=int,
        default=0,
        help="per-tenant source quota (0 = unlimited)",
    )
    s.add_argument(
        "--max-tally-rows",
        type=int,
        default=0,
        help="per-source tally-row quota, host+device (0 = unlimited)",
    )
    s.add_argument(
        "--max-subscribers",
        type=int,
        default=0,
        help="per-tenant live-subscriber quota (0 = unlimited)",
    )
    s.add_argument(
        "--source-ttl",
        type=float,
        default=0.0,
        metavar="SECONDS",
        help="garbage-collect sources with no frames for this long "
        "(0 = keep forever; evicted/dead ranks then linger in composites)",
    )
    s.add_argument(
        "--forward-token",
        default=None,
        help="auth token for the --forward-to upstream master",
    )
    s.add_argument(
        "--forward-tls-ca",
        default=None,
        metavar="PEM",
        help="connect to --forward-to over TLS, trusting this CA/cert bundle",
    )
    s.set_defaults(fn=_serve)

    tp = sub.add_parser("top", help="attach to a master and render the live composite")
    tp.add_argument("addr", help="master host:port")
    tp.add_argument(
        "--live",
        action="store_true",
        help="subscribe for pushed composite updates instead of polling queries",
    )
    tp.add_argument(
        "--by-rank",
        action="store_true",
        help="append the per-rank breakdown table (straggler/skew view)",
    )
    tp.add_argument(
        "--by-group",
        action="store_true",
        help="poll the rollup-group breakdown instead (masters started with "
        "--rollup-groups); node-granularity view of >1k-rank trees",
    )
    tp.add_argument("--interval", type=float, default=1.0)
    tp.add_argument(
        "--iterations", type=int, default=None, help="refresh N times then exit (default: forever)"
    )
    tp.add_argument("--timeout", type=float, default=3.0)
    tp.add_argument("--top", type=int, default=None)
    tp.add_argument("--device", action="store_true")
    tp.add_argument("--no-clear", action="store_true", help="don't clear the screen between refreshes")
    tp.add_argument(
        "--token", default=None, help="auth token (masters started with --token)"
    )
    tp.add_argument(
        "--tls-ca",
        default=None,
        metavar="PEM",
        help="connect over TLS, trusting this CA/cert bundle",
    )
    tp.add_argument(
        "--no-reconnect",
        action="store_true",
        help="--live: exit when the master goes away instead of reconnecting",
    )
    tp.add_argument(
        "--reconnect-max-wait",
        type=float,
        default=15.0,
        help="--live: cap for the exponential reconnect backoff (seconds)",
    )
    tp.set_defaults(fn=_top)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

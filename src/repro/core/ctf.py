"""CTF-lite: compact binary trace streams + JSON metadata (THAPI §3.1, §3.4).

LTTng writes Common Trace Format: binary event streams described by a
metadata document, parsed post-mortem by Babeltrace2.  We reproduce the
shape: a trace is a *directory* containing

    metadata.json            trace model + clock + environment (≙ CTF TSDL)
    stream_<pid>_<tid>.ctf   one binary stream per producer ring
    <prefix>...              multiple ranks may share a dir with rank prefixes

Stream layout: 16-byte magic/version header, then packets of framed records
exactly as produced by the ring buffers (ringbuffer.RECORD_HEADER framing).
The consumer daemon appends ring drains verbatim — zero re-encoding on the
write path, which is how LTTng keeps the consumer cheap.

Discarded events are materialized as ``ctf:events_discarded`` records
(event id 0) whenever the consumer observes a ring's drop counter advance —
the CTF discarded-events counter made explicit.
"""

from __future__ import annotations

import json
import os
import struct
from typing import Callable, Iterator, List, Optional, Tuple

from .api_model import DISCARD_EVENT_ID, TraceModel
from .clock import ClockInfo
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE

MAGIC = b"THAPIctf"  # 8 bytes
VERSION = 1
STREAM_HEADER = struct.Struct("<8sII")  # magic, version, reserved

METADATA_FILE = "metadata.json"


# ---------------------------------------------------------------------------
# Write side
# ---------------------------------------------------------------------------


class _ZlibStreamWriter:
    """stdlib fallback container used when ``zstandard`` is not installed."""

    def __init__(self, f, level: int = 6):
        import zlib

        self._c = zlib.compressobj(level)
        self._f = f

    def write(self, b) -> None:
        self._f.write(self._c.compress(bytes(b)))

    def finish(self) -> None:
        self._f.write(self._c.flush())


class StreamWriter:
    """One binary stream (one ring → one file).

    ``compress=True`` writes a compressed frame around the stream (the Fig 8
    space knob taken further: CTF stays the inner format; the container is
    zstd when available, zlib otherwise — readers sniff the frame magic).
    """

    def __init__(self, path: str, pid: int, tid: int, compress: bool = False):
        self.path = path
        self.pid = pid
        self.tid = tid
        self.compress = compress
        self._f = open(path, "wb", buffering=1 << 16)
        if compress:
            try:
                import zstandard as zstd

                self._zw = zstd.ZstdCompressor(level=3).stream_writer(self._f)
                self._finish = lambda: self._zw.flush(zstd.FLUSH_FRAME)
            except ImportError:
                self._zw = _ZlibStreamWriter(self._f)
                self._finish = self._zw.finish
            self._out = self._zw
        else:
            self._zw = None
            self._finish = None
            self._out = self._f
        self._out.write(STREAM_HEADER.pack(MAGIC, VERSION, 0))
        #: drop count already materialized as discard records; the consumer
        #: compares against the ring's live counter to skip no-op calls
        self.seen_dropped = 0
        self.bytes_written = STREAM_HEADER.size

    def append(self, chunk) -> None:
        """Append raw framed-record bytes — accepts any bytes-like object.

        The zero-copy drain hands ``memoryview`` regions straight from ring
        storage; the buffered file object copies them out during ``write``,
        so the view may be released as soon as this returns.
        """
        if chunk:
            self._out.write(chunk)
            self.bytes_written += len(chunk)

    def note_drops(self, total_dropped: int, ts_ns: int) -> None:
        """Emit a ctf:events_discarded record if the drop counter advanced."""
        delta = total_dropped - self.seen_dropped
        if delta > 0:
            payload = struct.pack("<Q", delta)
            rec = RECORD_HEADER.pack(
                RECORD_HEADER_SIZE + len(payload), DISCARD_EVENT_ID, ts_ns
            ) + payload
            self._out.write(rec)
            self.bytes_written += len(rec)
            self.seen_dropped = total_dropped

    def close(self) -> None:
        if not self._f.closed:
            if self._finish is not None:
                self._finish()
            self._f.flush()
            self._f.close()


def write_metadata(
    trace_dir: str,
    model: TraceModel,
    clock: ClockInfo,
    env: Optional[dict] = None,
    mode: str = "default",
) -> None:
    doc = {
        "format": "thapi-ctf-lite",
        "version": VERSION,
        "mode": mode,
        "clock": clock.to_json(),
        "env": env or {},
        "events": model.to_json(),
    }
    tmp = os.path.join(trace_dir, METADATA_FILE + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, os.path.join(trace_dir, METADATA_FILE))


# ---------------------------------------------------------------------------
# Read side (consumed by the Babeltrace-style source component)
# ---------------------------------------------------------------------------


class TraceMeta:
    def __init__(self, doc: dict):
        self.doc = doc
        self.model = TraceModel.from_json(doc["events"])
        self.clock = ClockInfo.from_json(doc["clock"])
        self.mode: str = doc.get("mode", "default")
        self.env: dict = doc.get("env", {})

    @staticmethod
    def load(trace_dir: str) -> "TraceMeta":
        with open(os.path.join(trace_dir, METADATA_FILE)) as f:
            return TraceMeta(json.load(f))


#: (eid, ts_ns, payload) — payload is a memoryview into the stream buffer.
RawEvent = Tuple[int, int, memoryview]


class StreamReader:
    """Iterates framed records of one stream file.

    Uncompressed streams are mapped (``mmap``) rather than read into a heap
    buffer — the analysis side of a 10⁷-event trace then walks page-cache
    memory directly, with one ``memoryview`` over the whole record region
    (``records_region``) instead of a Python-bytes copy of the file.
    Compressed streams (zstd/zlib containers) decompress into one buffer and
    take the same code path.
    """

    def __init__(self, path: str):
        self.path = path
        base = os.path.basename(path)
        # stream_<pid>_<tid>.ctf, possibly with a rank prefix
        stem = base[: -len(".ctf")] if base.endswith(".ctf") else base
        parts = stem.split("_")
        try:
            self.pid, self.tid = int(parts[-2]), int(parts[-1])
        except (ValueError, IndexError):
            self.pid, self.tid = 0, 0

    def _load(self) -> Tuple[memoryview, Callable[[], None]]:
        """(whole-stream buffer, release) — mmap-backed when uncompressed."""
        with open(self.path, "rb") as f:
            head = f.read(4)
            if head[:4] == b"\x28\xb5\x2f\xfd":  # zstd frame magic
                import zstandard as zstd

                f.seek(0)
                raw = zstd.ZstdDecompressor().stream_reader(f.read()).read()
                return memoryview(raw), lambda: None
            if head[:1] == b"\x78":  # zlib header (MAGIC starts with 'T')
                import zlib

                f.seek(0)
                raw = zlib.decompress(f.read())
                return memoryview(raw), lambda: None
            import mmap

            try:
                mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            except (ValueError, OSError):  # empty file or exotic fs: plain read
                f.seek(0)
                raw = f.read()
                return memoryview(raw), lambda: None
        # the mapping outlives the (now closed) fd
        mv = memoryview(mm)

        def release(mv=mv, mm=mm) -> None:
            mv.release()
            try:
                mm.close()
            except BufferError:  # a sliced view still exported — GC will close
                pass

        return mv, release

    def records_region(self) -> Tuple[memoryview, Callable[[], None]]:
        """Validated record region (past the stream header) + release callable.

        The batched-scan entry point used by the fold engine: callers walk
        ``RECORD_HEADER``-framed records over one buffer with zero per-record
        allocation.  An empty/too-short stream yields an empty view.
        """
        mv, release = self._load()
        if len(mv) < STREAM_HEADER.size:
            return mv[0:0], release
        magic, version, _ = STREAM_HEADER.unpack_from(mv)
        if magic != MAGIC:
            release()
            raise ValueError(f"{self.path}: not a THAPI ctf-lite stream")
        if version != VERSION:
            release()
            raise ValueError(f"{self.path}: unsupported version {version}")
        return mv[STREAM_HEADER.size :], release

    def __iter__(self) -> Iterator[RawEvent]:
        data, release = self.records_region()
        try:
            off, n = 0, len(data)
            while off + RECORD_HEADER_SIZE <= n:
                total, eid, ts = RECORD_HEADER.unpack_from(data, off)
                if total < RECORD_HEADER_SIZE or off + total > n:
                    break  # truncated tail (e.g. crash mid-write) — stop cleanly
                yield eid, ts, data[off + RECORD_HEADER_SIZE : off + total]
                off += total
        finally:
            release()


def stream_files(trace_dir: str) -> List[str]:
    out = []
    for name in sorted(os.listdir(trace_dir)):
        if name.endswith(".ctf"):
            out.append(os.path.join(trace_dir, name))
    return out


def trace_size_bytes(trace_dir: str) -> int:
    return sum(os.path.getsize(p) for p in stream_files(trace_dir))

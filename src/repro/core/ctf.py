"""CTF-lite: compact binary trace streams + JSON metadata (THAPI §3.1, §3.4).

LTTng writes Common Trace Format: binary event streams described by a
metadata document, parsed post-mortem by Babeltrace2.  We reproduce the
shape: a trace is a *directory* containing

    metadata.json            trace model + clock + environment (≙ CTF TSDL)
    stream_<pid>_<tid>.ctf   one binary stream per producer ring
    stream_<pid>_<tid>.ctfcol  optional columnar sidecar (see below)
    <prefix>...              multiple ranks may share a dir with rank prefixes

Stream layout: 16-byte magic/version header, then packets of framed records
exactly as produced by the ring buffers (ringbuffer.RECORD_HEADER framing).
The consumer daemon appends ring drains verbatim — zero re-encoding on the
write path, which is how LTTng keeps the consumer cheap.

Discarded events are materialized as ``ctf:events_discarded`` records
(event id 0) whenever the consumer observes a ring's drop counter advance —
the CTF discarded-events counter made explicit.

Columnar sidecar (the Anderson-et-al. "scalable trace format" argument):
when ``TraceConfig.columnar`` is on (or ``iprof index`` is run post-hoc),
each stream gains a ``.ctfcol`` sidecar holding the analysis-relevant view
of its records as four contiguous packed-u64 columns — interval timestamp,
event id (kernel-name table index packed in the high bits), duration, and
pair link (row index of the matching entry/exit) — plus a JSON footer that
carries the per-stream folded tally, the kernel-name table, and the exact
stream byte count the sidecar was built against.  Analysis that trusts a
sidecar never parses records: ``fold_trace`` reads the footer tally,
timeline interval queries walk the columns.  Trust is strict: wrong magic,
unknown version, structural mismatch, or a stream whose on-disk size no
longer equals ``stream_bytes`` (truncated tail, appended records) all make
``load_sidecar`` return ``None`` and analysis falls back to record parsing
— the sidecar is a cache, never a source of truth.
"""

from __future__ import annotations

import json
import os
import struct
from typing import Callable, Iterator, List, Optional, Tuple

from .api_model import DISCARD_EVENT_ID, TraceModel
from .clock import ClockInfo
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE

MAGIC = b"THAPIctf"  # 8 bytes
VERSION = 1
STREAM_HEADER = struct.Struct("<8sII")  # magic, version, reserved

METADATA_FILE = "metadata.json"


# ---------------------------------------------------------------------------
# Write side
# ---------------------------------------------------------------------------


class _ZlibStreamWriter:
    """stdlib fallback container used when ``zstandard`` is not installed."""

    def __init__(self, f, level: int = 6):
        import zlib

        self._c = zlib.compressobj(level)
        self._f = f

    def write(self, b) -> None:
        self._f.write(self._c.compress(bytes(b)))

    def finish(self) -> None:
        self._f.write(self._c.flush())


class StreamWriter:
    """One binary stream (one ring → one file).

    ``compress=True`` writes a compressed frame around the stream (the Fig 8
    space knob taken further: CTF stays the inner format; the container is
    zstd when available, zlib otherwise — readers sniff the frame magic).
    """

    def __init__(self, path: str, pid: int, tid: int, compress: bool = False):
        self.path = path
        self.pid = pid
        self.tid = tid
        self.compress = compress
        self._f = open(path, "wb", buffering=1 << 16)
        if compress:
            try:
                import zstandard as zstd

                self._zw = zstd.ZstdCompressor(level=3).stream_writer(self._f)
                self._finish = lambda: self._zw.flush(zstd.FLUSH_FRAME)
            except ImportError:
                self._zw = _ZlibStreamWriter(self._f)
                self._finish = self._zw.finish
            self._out = self._zw
        else:
            self._zw = None
            self._finish = None
            self._out = self._f
        self._out.write(STREAM_HEADER.pack(MAGIC, VERSION, 0))
        #: drop count already materialized as discard records; the consumer
        #: compares against the ring's live counter to skip no-op calls
        self.seen_dropped = 0
        self.bytes_written = STREAM_HEADER.size

    def append(self, chunk) -> None:
        """Append raw framed-record bytes — accepts any bytes-like object.

        The zero-copy drain hands ``memoryview`` regions straight from ring
        storage; the buffered file object copies them out during ``write``,
        so the view may be released as soon as this returns.
        """
        if chunk:
            self._out.write(chunk)
            self.bytes_written += len(chunk)

    def note_drops(self, total_dropped: int, ts_ns: int) -> None:
        """Emit a ctf:events_discarded record if the drop counter advanced."""
        delta = total_dropped - self.seen_dropped
        if delta > 0:
            payload = struct.pack("<Q", delta)
            rec = RECORD_HEADER.pack(
                RECORD_HEADER_SIZE + len(payload), DISCARD_EVENT_ID, ts_ns
            ) + payload
            self._out.write(rec)
            self.bytes_written += len(rec)
            self.seen_dropped = total_dropped

    def close(self) -> None:
        if not self._f.closed:
            if self._finish is not None:
                self._finish()
            self._f.flush()
            self._f.close()


def write_metadata(
    trace_dir: str,
    model: TraceModel,
    clock: ClockInfo,
    env: Optional[dict] = None,
    mode: str = "default",
) -> None:
    doc = {
        "format": "thapi-ctf-lite",
        "version": VERSION,
        "mode": mode,
        "clock": clock.to_json(),
        "env": env or {},
        "events": model.to_json(),
    }
    tmp = os.path.join(trace_dir, METADATA_FILE + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, os.path.join(trace_dir, METADATA_FILE))


# ---------------------------------------------------------------------------
# Read side (consumed by the Babeltrace-style source component)
# ---------------------------------------------------------------------------


class TraceMeta:
    def __init__(self, doc: dict):
        self.doc = doc
        self.model = TraceModel.from_json(doc["events"])
        self.clock = ClockInfo.from_json(doc["clock"])
        self.mode: str = doc.get("mode", "default")
        self.env: dict = doc.get("env", {})

    @staticmethod
    def load(trace_dir: str) -> "TraceMeta":
        with open(os.path.join(trace_dir, METADATA_FILE)) as f:
            return TraceMeta(json.load(f))


#: (eid, ts_ns, payload) — payload is a memoryview into the stream buffer.
RawEvent = Tuple[int, int, memoryview]


class StreamReader:
    """Iterates framed records of one stream file.

    Uncompressed streams are mapped (``mmap``) rather than read into a heap
    buffer — the analysis side of a 10⁷-event trace then walks page-cache
    memory directly, with one ``memoryview`` over the whole record region
    (``records_region``) instead of a Python-bytes copy of the file.
    Compressed streams (zstd/zlib containers) decompress into one buffer and
    take the same code path.
    """

    def __init__(self, path: str):
        self.path = path
        base = os.path.basename(path)
        # stream_<pid>_<tid>.ctf, possibly with a rank prefix
        stem = base[: -len(".ctf")] if base.endswith(".ctf") else base
        parts = stem.split("_")
        try:
            self.pid, self.tid = int(parts[-2]), int(parts[-1])
        except (ValueError, IndexError):
            self.pid, self.tid = 0, 0

    def _load(self) -> Tuple[memoryview, Callable[[], None]]:
        """(whole-stream buffer, release) — mmap-backed when uncompressed."""
        with open(self.path, "rb") as f:
            head = f.read(4)
            if head[:4] == b"\x28\xb5\x2f\xfd":  # zstd frame magic
                import zstandard as zstd

                f.seek(0)
                raw = zstd.ZstdDecompressor().stream_reader(f.read()).read()
                return memoryview(raw), lambda: None
            if head[:1] == b"\x78":  # zlib header (MAGIC starts with 'T')
                import zlib

                f.seek(0)
                raw = zlib.decompress(f.read())
                return memoryview(raw), lambda: None
            import mmap

            try:
                mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            except (ValueError, OSError):  # empty file or exotic fs: plain read
                f.seek(0)
                raw = f.read()
                return memoryview(raw), lambda: None
        # the mapping outlives the (now closed) fd
        mv = memoryview(mm)

        def release(mv=mv, mm=mm) -> None:
            mv.release()
            try:
                mm.close()
            except BufferError:  # a sliced view still exported — GC will close
                pass

        return mv, release

    def records_region(self) -> Tuple[memoryview, Callable[[], None]]:
        """Validated record region (past the stream header) + release callable.

        The batched-scan entry point used by the fold engine: callers walk
        ``RECORD_HEADER``-framed records over one buffer with zero per-record
        allocation.  An empty/too-short stream yields an empty view.
        """
        mv, release = self._load()
        if len(mv) < STREAM_HEADER.size:
            return mv[0:0], release
        magic, version, _ = STREAM_HEADER.unpack_from(mv)
        if magic != MAGIC:
            release()
            raise ValueError(f"{self.path}: not a THAPI ctf-lite stream")
        if version != VERSION:
            release()
            raise ValueError(f"{self.path}: unsupported version {version}")
        return mv[STREAM_HEADER.size :], release

    def __iter__(self) -> Iterator[RawEvent]:
        data, release = self.records_region()
        try:
            off, n = 0, len(data)
            while off + RECORD_HEADER_SIZE <= n:
                total, eid, ts = RECORD_HEADER.unpack_from(data, off)
                if total < RECORD_HEADER_SIZE or off + total > n:
                    break  # truncated tail (e.g. crash mid-write) — stop cleanly
                yield eid, ts, data[off + RECORD_HEADER_SIZE : off + total]
                off += total
        finally:
            release()


def stream_files(trace_dir: str) -> List[str]:
    out = []
    for name in sorted(os.listdir(trace_dir)):
        if name.endswith(".ctf"):
            out.append(os.path.join(trace_dir, name))
    return out


def trace_size_bytes(trace_dir: str) -> int:
    return sum(os.path.getsize(p) for p in stream_files(trace_dir))


# ---------------------------------------------------------------------------
# Columnar sidecar (.ctfcol): per-stream packed-u64 columns + JSON footer
# ---------------------------------------------------------------------------

COL_MAGIC = b"THAPIcol"  # 8 bytes, distinct from the stream MAGIC
COL_VERSION = 1
COL_HEADER = struct.Struct("<8sII")  # magic, version, reserved
_COL_COUNT = struct.Struct("<Q")
_COL_FLEN = struct.Struct("<I")
N_COLUMNS = 4  # ts, eid+name, duration, pair link

#: pair-link / "no link" sentinel in the pair column
NO_PAIR = (1 << 64) - 1


def sidecar_path(stream_path: str) -> str:
    """``stream_<pid>_<tid>.ctf`` → ``stream_<pid>_<tid>.ctfcol``."""
    if stream_path.endswith(".ctf"):
        return stream_path + "col"
    return stream_path + ".ctfcol"


def _le_u64s(a) -> bytes:
    """array('Q') → little-endian bytes regardless of host byte order."""
    import sys as _sys

    if _sys.byteorder != "little":
        a = a[:]
        a.byteswap()
    return a.tobytes()


class ColumnarWriter:
    """Builds one stream's ``.ctfcol`` sidecar from drained record chunks.

    Fed the exact framed-record bytes the :class:`StreamWriter` receives
    (the tracer's zero-copy drain memoryviews, or a whole-stream
    ``records_region`` when indexing post-hoc).  Two derived views are
    maintained per chunk:

      * the **folded tally** — every chunk goes through the shared
        :class:`~repro.core.fold.FoldEngine`, so the footer tally is
        *by construction* what a record-parse fold of the stream produces
        (identical pairing, clamping, unmatched and discard semantics);
      * the **interval columns** — one row per analysis-relevant record
        (entries, exits, device spans; samples/discards/unknown eids
        contribute nothing a query reads and get no row):

          ts    u64  interval-semantic timestamp: header ts for entry/exit
                     records, payload ``ts_begin`` for spans
          eid   u64  low 16 bits: event id; high bits: 1 + index into the
                     footer name table for named launch spans (0 = unnamed)
          dur   u64  completed-interval duration (on exit and span rows)
          pair  u64  row index of the matching entry (on exits) / exit (on
                     entries); NO_PAIR when unmatched or not a pair event

    ``close(stream_bytes)`` flushes unmatched entries through the engine
    (mirroring the offline fold) and writes the file atomically.
    """

    def __init__(self, engine, pid: int, tid: int, path: str):
        # engine is a repro.core.fold.FoldEngine (imported lazily by callers:
        # fold.py imports this module, so ctf cannot import fold at top level)
        from array import array

        self.engine = engine
        self.pid = pid
        self.tid = tid
        self.path = path
        self.state = engine.new_state()
        self.ts = array("Q")
        self.en = array("Q")
        self.dur = array("Q")
        self.pair = array("Q")
        self._stacks: dict = {}  # pair_id → [row indexes of open entries]
        self._names: list = []  # kernel-name table (footer)
        self._nids: dict = {}  # name → table index

    def append(self, chunk) -> None:
        """Index one framed-record chunk (and fold it into the tally)."""
        self.engine.fold_chunk(self.state, chunk, self.pid, self.tid)
        self._index_chunk(chunk)

    def note_discard(self, count: int) -> None:
        """Account discard records the consumer writes straight to the stream
        (``StreamWriter.note_drops`` bytes never pass through ``append``)."""
        self.state.discarded += count

    def _name_id(self, name) -> int:
        nid = self._nids.get(name)
        if nid is None:
            nid = self._nids[name] = len(self._names)
            self._names.append(name)
        return nid

    def _index_chunk(self, buf) -> None:
        # mirrors FoldEngine.fold_chunk's walk (same skip rules, so a row
        # exists exactly when the fold read the record) with column output
        from .fold import (
            K_ENTRY,
            K_EXIT,
            K_SPAN,
            K_SPAN_NAMED,
            K_SPAN_NAMED_GENERIC,
            _LEN,
            _SPAN_TS,
        )

        if type(buf) is not memoryview:
            buf = memoryview(buf)
        plan_rows = self.engine.plan.rows
        nplans = len(plan_rows)
        hdr_unpack = RECORD_HEADER.unpack_from
        col_ts, col_en, col_dur, col_pair = self.ts, self.en, self.dur, self.pair
        stacks = self._stacks
        off = 0
        n = len(buf)
        limit = n - RECORD_HEADER_SIZE
        while off <= limit:
            total, eid, ts = hdr_unpack(buf, off)
            if total < RECORD_HEADER_SIZE or off + total > n:
                break  # truncated tail
            rec_end = off + total
            if eid < nplans:
                kind, key, aid, noff, _ = plan_rows[eid]
                if kind == K_ENTRY:
                    stack = stacks.get(aid)
                    if stack is None:
                        stack = stacks[aid] = []
                    stack.append(len(col_ts))
                    col_ts.append(ts)
                    col_en.append(eid)
                    col_dur.append(0)
                    col_pair.append(NO_PAIR)
                elif kind == K_EXIT:
                    stack = stacks.get(aid)
                    row = len(col_ts)
                    if stack:
                        eidx = stack.pop()
                        d = ts - col_ts[eidx]
                        if d < 0:
                            d = 0
                        col_pair[eidx] = row
                        col_ts.append(ts)
                        col_en.append(eid)
                        col_dur.append(d)
                        col_pair.append(eidx)
                    else:  # unmatched exit: row kept, contributes no interval
                        col_ts.append(ts)
                        col_en.append(eid)
                        col_dur.append(0)
                        col_pair.append(NO_PAIR)
                elif kind in (K_SPAN, K_SPAN_NAMED, K_SPAN_NAMED_GENERIC):
                    poff = off + RECORD_HEADER_SIZE
                    if poff + 16 > rec_end:  # short payload: fold skipped it
                        off = rec_end
                        continue
                    t0, t1 = _SPAN_TS.unpack_from(buf, poff)
                    d = t1 - t0
                    if d < 0:
                        d = 0
                    nid = 0
                    if kind == K_SPAN_NAMED:
                        nb_off = poff + noff
                        if nb_off + 4 > rec_end:
                            off = rec_end
                            continue
                        (ln,) = _LEN.unpack_from(buf, nb_off)
                        if nb_off + 4 + ln > rec_end:
                            off = rec_end
                            continue
                        name = bytes(buf[nb_off + 4 : nb_off + 4 + ln]).decode(
                            errors="replace"
                        )
                        nid = 1 + self._name_id(name)
                    elif kind == K_SPAN_NAMED_GENERIC:
                        try:
                            name = self.engine._unpack[eid](buf[poff:rec_end])[noff]
                        except struct.error:
                            off = rec_end
                            continue
                        if type(name) is not str:  # footer table is JSON
                            name = str(name)
                        nid = 1 + self._name_id(name)
                    col_ts.append(t0)
                    col_en.append(eid | (nid << 16))
                    col_dur.append(d)
                    col_pair.append(NO_PAIR)
                # K_SKIP / K_DISCARD: nothing a query reads — no row
            off = rec_end

    def close(self, stream_bytes: int) -> None:
        """Finalize: flush unmatched entries into the footer tally and write
        the sidecar atomically (readers see a complete file or none)."""
        tally = self.engine.finish(self.state)
        footer = {
            "format": "thapi-ctf-col",
            "version": COL_VERSION,
            "rows": len(self.ts),
            "stream_bytes": int(stream_bytes),
            "names": self._names,
            "tally": tally.to_obj(),
            "events_seen": self.state.events_seen,
        }
        fb = json.dumps(footer, sort_keys=True).encode()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(COL_HEADER.pack(COL_MAGIC, COL_VERSION, 0))
            f.write(_COL_COUNT.pack(len(self.ts)))
            for col in (self.ts, self.en, self.dur, self.pair):
                f.write(_le_u64s(col))
            f.write(fb)
            f.write(_COL_FLEN.pack(len(fb)))
        os.replace(tmp, self.path)


class ColumnarSidecar:
    """A validated, loaded ``.ctfcol`` sidecar (see :func:`load_sidecar`)."""

    __slots__ = ("path", "rows", "footer")

    def __init__(self, path: str, rows: int, footer: dict):
        self.path = path
        self.rows = rows
        self.footer = footer

    def tally(self):
        """The per-stream folded tally recorded in the footer."""
        from .plugins.tally import Tally

        return Tally.from_obj(self.footer["tally"])

    def columns(self) -> tuple:
        """(ts, eid+name, dur, pair) as array('Q') columns."""
        import sys as _sys
        from array import array

        out = []
        with open(self.path, "rb") as f:
            f.seek(COL_HEADER.size + _COL_COUNT.size)
            for _ in range(N_COLUMNS):
                a = array("Q")
                a.frombytes(f.read(8 * self.rows))
                if _sys.byteorder != "little":
                    a.byteswap()
                out.append(a)
        return tuple(out)


def load_sidecar(stream_path: str) -> Optional[ColumnarSidecar]:
    """Load and validate the sidecar for one stream, or None.

    None (→ callers fall back to record parsing) whenever the sidecar is
    missing, carries an unknown magic/version (forward compatibility: newer
    formats are skipped, never crashed on), is structurally inconsistent, or
    is **stale** — the stream's current on-disk byte count differs from the
    ``stream_bytes`` the sidecar was built against (truncation or append).
    """
    path = sidecar_path(stream_path)
    try:
        fsize = os.path.getsize(path)
        with open(path, "rb") as f:
            head_len = COL_HEADER.size + _COL_COUNT.size
            if fsize < head_len + _COL_FLEN.size:
                return None
            magic, version, _ = COL_HEADER.unpack(f.read(COL_HEADER.size))
            if magic != COL_MAGIC or version != COL_VERSION:
                return None
            (rows,) = _COL_COUNT.unpack(f.read(_COL_COUNT.size))
            base = head_len + 8 * N_COLUMNS * rows
            if fsize < base + _COL_FLEN.size:
                return None
            f.seek(fsize - _COL_FLEN.size)
            (flen,) = _COL_FLEN.unpack(f.read(_COL_FLEN.size))
            if base + flen + _COL_FLEN.size != fsize:
                return None
            f.seek(base)
            footer = json.loads(f.read(flen))
        stream_size = os.path.getsize(stream_path)
    except (OSError, ValueError, struct.error):
        return None
    if not isinstance(footer, dict) or "tally" not in footer:
        return None
    if footer.get("version") != COL_VERSION:
        return None
    if footer.get("stream_bytes") != stream_size:
        return None  # stale: stream truncated or grew since indexing
    return ColumnarSidecar(path, rows, footer)


def build_sidecars(trace_dir: str) -> int:
    """Index an existing trace post-hoc: write/refresh a ``.ctfcol`` sidecar
    for every stream (``iprof index``).  Returns the stream count."""
    from .fold import FoldEngine

    meta = TraceMeta.load(trace_dir)
    engine = FoldEngine(meta.model)
    n = 0
    for path in stream_files(trace_dir):
        reader = StreamReader(path)
        cw = ColumnarWriter(engine, reader.pid, reader.tid, sidecar_path(path))
        buf, release = reader.records_region()
        try:
            cw.append(buf)
        finally:
            release()
        cw.close(os.path.getsize(path))
        n += 1
    return n

"""Adaptive-optimization consumer (THAPI §6, the paper's closing vision).

    "we are also working on online trace analysis, where tracing and analysis
     can be performed concurrently to enable adaptive optimizations during
     application runtime."

``online.py`` gives a rank a *live tally*; ``stream.py`` gives the cluster a
*live composite* — and, since protocol v2.1, a live **per-rank breakdown**.
This module closes the loop at both scopes:

  * an :class:`AdaptiveController` rides the tracer's consumer thread,
    computes **windowed** rates from successive live snapshots of *this
    rank* (busy fraction, per-call latency, ring-buffer drops), and hands
    them to pluggable :class:`AdaptivePolicy` objects that may turn session
    knobs *mid-run* — widen event sampling, resize ring buffers for new
    threads, retune snapshot cadence;
  * a :class:`ClusterAdaptiveController` reads the per-rank tally map of a
    streaming master (in-process via ``MasterServer.ranks()`` or remote via
    ``query_ranks``), diffs consecutive per-rank snapshots into cross-rank
    windowed metrics (per-rank busy fraction / latency, rank-vs-median skew
    ratios), and hands them to :class:`ClusterPolicy` objects —
    :class:`StragglerRankPolicy` flags lagging ranks and feeds API-level
    evidence (which rank, which API, how far behind) into the trainer's
    straggler watchdog; :class:`RankImbalanceAdvisoryPolicy` narrates load
    skew.  The signals these policies act on only exist *across* ranks: a
    straggler looks healthy in its own tally and only lags relative to the
    cluster median.

Wiring:

  * ``TraceConfig(adaptive=[...policies...])`` — the tracer builds a
    controller and ticks it from the consumer loop every
    ``adaptive_period_s`` (collection hot paths never see it);
  * ``TraceConfig(cluster_adaptive=[...], serve_port=...)`` — the tracer
    binds a cluster controller to its in-process master and ticks it from
    the same consumer loop every ``cluster_period_s``;
  * ``ServeEngine(..., adaptive=…, cluster_adaptive=…)`` — the serving loop
    ticks the same machinery between decode steps, with ``ctx.engine`` set
    so policies can reach serving knobs;
  * every knob change is recorded as an :class:`AdaptiveAction` (see
    ``controller.actions``) *and* traced as an advisory event, so the
    reconfiguration itself is visible post-mortem.

Windowed metrics, not cumulative ones: ``OnlineAnalyzer.busy_fraction`` is
share-of-total since session start; a policy reacting mid-run needs the
share over the *last* window, so the controllers diff consecutive snapshots.
"""

from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from .plugins.tally import Tally


@dataclasses.dataclass
class AdaptiveAction:
    """One knob change (or advisory) taken by a policy, for the audit log."""

    ts: float  # wall clock
    policy: str
    knob: str
    value: str
    reason: str

    def __str__(self) -> str:
        return f"[adaptive] {self.policy}: {self.knob}={self.value} ({self.reason})"


class AdaptiveContext:
    """What a policy sees on one tick: windowed live metrics + session knobs.

    Metrics are computed from the difference between the previous tick's
    tally snapshot and the current one over ``window_s`` of wall time, so
    they describe *recent* behavior.  Knob setters go through the
    controller, which records an :class:`AdaptiveAction` and emits an
    advisory event into the trace.
    """

    def __init__(
        self,
        controller: "AdaptiveController",
        prev: Tally,
        cur: Tally,
        window_s: float,
        engine=None,
    ):
        self._controller = controller
        self._prev = prev
        self._cur = cur
        self.window_s = window_s
        #: the ServeEngine driving this tick, when ticked from the serving
        #: loop (None on consumer-thread ticks)
        self.engine = engine
        self._policy = "?"  # set by the controller per policy

    # -- windowed metrics ----------------------------------------------------
    def _window(self, provider: str, api: str, device: bool) -> Tuple[int, int]:
        """(calls, total_ns) accumulated inside the last window."""
        cur_t = self._cur.device_apis if device else self._cur.apis
        prev_t = self._prev.device_apis if device else self._prev.apis
        c = cur_t.get((provider, api))
        if c is None:
            return 0, 0
        p = prev_t.get((provider, api))
        if p is None:
            return c.calls, c.total_ns
        return c.calls - p.calls, c.total_ns - p.total_ns

    def busy_fraction(self, provider: str, api: str, device: bool = False) -> float:
        """Share of the last window's wall time spent inside ``api``."""
        if self.window_s <= 0:
            return 0.0
        _, total_ns = self._window(provider, api, device)
        return total_ns / (self.window_s * 1e9)

    def window_calls(self, provider: str, api: str, device: bool = False) -> int:
        """Calls to ``api`` completed during the last window."""
        calls, _ = self._window(provider, api, device)
        return calls

    def window_latency_ns(self, provider: str, api: str, device: bool = False) -> float:
        """Mean per-call latency of ``api`` over the last window (0 if idle)."""
        calls, total_ns = self._window(provider, api, device)
        return total_ns / calls if calls > 0 else 0.0

    def dropped_in_window(self) -> int:
        """Ring-buffer events discarded during the last window."""
        return self._controller._window_dropped

    def snapshot(self) -> Tally:
        """The current cumulative live tally (for policies that need it)."""
        return self._cur

    # -- knobs ---------------------------------------------------------------
    def set_stream_period(self, seconds: float, reason: str = "") -> None:
        """Retune the live-snapshot push cadence (``stream_period_s``)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.cfg.stream_period_s = max(0.01, float(seconds))
        self._act("stream_period_s", f"{tr.cfg.stream_period_s:g}", reason)

    def set_flush_period(self, seconds: float, reason: str = "") -> None:
        """Retune the consumer drain period (``flush_period_s``)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.cfg.flush_period_s = max(0.005, float(seconds))
        self._act("flush_period_s", f"{tr.cfg.flush_period_s:g}", reason)

    def set_sample_period(self, seconds: float, reason: str = "") -> None:
        """Retune the telemetry daemon's sampling period, when it runs."""
        tr = self._controller._tracer
        sampler = getattr(tr, "_sampler", None) if tr is not None else None
        if sampler is None:
            return
        sampler.period_s = max(0.005, float(seconds))
        self._act("sample_period_s", f"{sampler.period_s:g}", reason)

    def set_event(self, name: str, on: bool, reason: str = "") -> None:
        """Enable/disable one tracepoint live (widen or narrow sampling)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.tp.set_event(name, on)
        self._act(f"event:{name}", "on" if on else "off", reason)

    def set_ring_bytes(self, nbytes: int, reason: str = "") -> None:
        """Resize the ring-buffer capacity used for *future* threads."""
        tr = self._controller._tracer
        if tr is None or tr.registry is None:
            return
        tr.registry.set_capacity(int(nbytes))
        self._act("ring_bytes", str(int(nbytes)), reason)

    def set_mode(self, mode: str, reason: str = "") -> None:
        """Move the session along the fidelity ladder mid-run
        (``"full" | "sampled" | "tally-only" | "off"``) — the
        escalate-on-trouble lever.  No-op when already on that rung."""
        tr = self._controller._tracer
        if tr is None:
            return
        prev = tr.set_mode(mode)
        if prev != mode:
            self._act("fidelity", mode, reason)

    @property
    def mode(self) -> str:
        """Current fidelity rung of the attached session ("full" unbound)."""
        tr = self._controller._tracer
        return tr.fidelity if tr is not None else "full"

    def advise(self, knob: str, value: str, reason: str = "") -> None:
        """Record an advisory-only action (no knob turned): it lands in the
        controller log and as an ``ust_repro:advisory`` trace event."""
        self._act(knob, value, reason)

    def _act(self, knob: str, value: str, reason: str) -> None:
        self._controller._record(self._policy, knob, value, reason)


class AdaptivePolicy:
    """Base class: look at an :class:`AdaptiveContext`, optionally turn knobs.

    Policies are stateful objects, invoked once per controller tick on the
    consumer (or serving) thread; they must be fast and must never raise —
    the controller isolates exceptions, but a throwing policy stops
    adapting.  ``name`` labels the policy in action logs and advisory
    events.
    """

    name = "policy"

    def tick(self, ctx: AdaptiveContext) -> None:
        raise NotImplementedError


class WidenSamplingPolicy(AdaptivePolicy):
    """Widen tally sampling when one API dominates the window.

    While ``busy_fraction(provider, api)`` stays above ``high``, the events
    in ``widen_events`` (typically polling / telemetry events excluded by
    the mode preset) are enabled to capture *why* the API is hot; once the
    fraction falls below ``low`` they are disabled again — Fig 7's overhead
    ladder applied dynamically instead of picked up front.
    """

    name = "widen-sampling"

    def __init__(
        self,
        provider: str,
        api: str,
        widen_events: Sequence[str],
        high: float = 0.5,
        low: float = 0.1,
    ):
        self.provider = provider
        self.api = api
        self.widen_events = tuple(widen_events)
        self.high = high
        self.low = low
        self.widened = False

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if not self.widened and busy >= self.high:
            self.widened = True
            for name in self.widen_events:
                ctx.set_event(
                    name, True, f"busy_fraction({self.api})={busy:.2f}≥{self.high}"
                )
        elif self.widened and busy <= self.low:
            self.widened = False
            for name in self.widen_events:
                ctx.set_event(
                    name, False, f"busy_fraction({self.api})={busy:.2f}≤{self.low}"
                )


class StreamCadencePolicy(AdaptivePolicy):
    """Snapshot faster while a watched API is hot, slower while idle.

    A live dashboard wants fresh composites exactly when something is
    happening; when the window is quiet, pushing snapshots is pure wire
    noise.  Moves ``stream_period_s`` between ``fast_s`` and ``slow_s`` on
    the ``high`` / ``low`` busy-fraction thresholds.
    """

    name = "stream-cadence"

    def __init__(
        self,
        provider: str,
        api: str,
        high: float = 0.3,
        low: float = 0.05,
        fast_s: float = 0.1,
        slow_s: float = 1.0,
    ):
        self.provider = provider
        self.api = api
        self.high = high
        self.low = low
        self.fast_s = fast_s
        self.slow_s = slow_s
        self._state = ""  # "", "fast", "slow"

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if busy >= self.high and self._state != "fast":
            self._state = "fast"
            ctx.set_stream_period(
                self.fast_s, f"busy_fraction({self.api})={busy:.2f}≥{self.high}"
            )
        elif busy <= self.low and self._state != "slow":
            self._state = "slow"
            ctx.set_stream_period(
                self.slow_s, f"busy_fraction({self.api})={busy:.2f}≤{self.low}"
            )


class RingPressurePolicy(AdaptivePolicy):
    """Grow ring-buffer capacity when the window shows discarded events.

    Rings drop rather than block (§3.1); sustained drops mean the configured
    capacity undershoots the event rate.  Each tick that observes new drops
    doubles the capacity used for future threads' rings (bounded by
    ``max_bytes``) and emits an advisory either way, so the drop burst is
    visible in the trace even when the cap is reached.
    """

    name = "ring-pressure"

    def __init__(self, factor: float = 2.0, max_bytes: int = 1 << 26):
        self.factor = factor
        self.max_bytes = max_bytes

    def tick(self, ctx: AdaptiveContext) -> None:
        dropped = ctx.dropped_in_window()
        if dropped <= 0:
            return
        tr = ctx._controller._tracer
        if tr is None or tr.registry is None:
            return
        cur = tr.registry.capacity
        if cur >= self.max_bytes:
            ctx.advise("ring_bytes", str(cur), f"{dropped} drops but cap reached")
            return
        ctx.set_ring_bytes(
            min(self.max_bytes, int(cur * self.factor)),
            f"{dropped} events dropped in window",
        )


class EscalateFidelity(AdaptivePolicy):
    """Walk the fidelity ladder on evidence: cheap by default, full on trouble.

    The run sits at ``floor`` (default ``tally-only`` — counts but no stream
    files).  Each window that shows trouble — the watched API's mean latency
    at or above ``latency_high_ns``, or (``on_drops``) ring-buffer discards —
    climbs one rung toward ``ceiling``; ``healthy_windows`` consecutive calm
    windows step one rung back down toward ``floor``.  Every transition goes
    through the torn-free :meth:`~repro.core.tracer.Tracer.set_mode` handoff
    and is logged as an ``AdaptiveAction`` + advisory event, so the trace
    records *when* and *why* its own fidelity changed.

    ``floor`` should stay at ``tally-only`` or higher: on the ``off`` rung
    nothing is recorded, so no evidence can ever trigger re-escalation
    (drops excepted — rings are idle too, so there are none).
    """

    name = "escalate-fidelity"

    #: rung order, cheapest first
    LADDER = ("off", "tally-only", "sampled", "full")

    def __init__(
        self,
        provider: str,
        api: str,
        latency_high_ns: float,
        floor: str = "tally-only",
        ceiling: str = "full",
        healthy_windows: int = 3,
        on_drops: bool = True,
        device: bool = False,
    ):
        if floor not in self.LADDER or ceiling not in self.LADDER:
            raise ValueError(f"floor/ceiling must be one of {self.LADDER}")
        if self.LADDER.index(floor) > self.LADDER.index(ceiling):
            raise ValueError(f"floor {floor!r} above ceiling {ceiling!r}")
        self.provider = provider
        self.api = api
        self.latency_high_ns = latency_high_ns
        self.floor = floor
        self.ceiling = ceiling
        self.healthy_windows = max(1, int(healthy_windows))
        self.on_drops = on_drops
        self.device = device
        self._calm = 0

    def _step(self, mode: str, up: bool) -> str:
        i = self.LADDER.index(mode) + (1 if up else -1)
        i = min(max(i, self.LADDER.index(self.floor)), self.LADDER.index(self.ceiling))
        return self.LADDER[i]

    def tick(self, ctx: AdaptiveContext) -> None:
        lat = ctx.window_latency_ns(self.provider, self.api, self.device)
        dropped = ctx.dropped_in_window() if self.on_drops else 0
        trouble = lat >= self.latency_high_ns or dropped > 0
        cur = ctx.mode
        if cur not in self.LADDER:
            return
        if trouble:
            self._calm = 0
            nxt = self._step(cur, up=True)
            if nxt != cur:
                why = (
                    f"{self.provider}:{self.api} latency {lat:.0f}ns≥{self.latency_high_ns:.0f}ns"
                    if lat >= self.latency_high_ns
                    else f"{dropped} events dropped in window"
                )
                ctx.set_mode(nxt, why)
        else:
            self._calm += 1
            if self._calm >= self.healthy_windows:
                nxt = self._step(cur, up=False)
                if nxt != cur:
                    self._calm = 0
                    ctx.set_mode(
                        nxt, f"{self.healthy_windows} healthy windows, stepping down"
                    )


class ThresholdAdvisoryPolicy(AdaptivePolicy):
    """Emit an advisory whenever a busy fraction crosses a threshold.

    The no-knob policy: it only narrates.  Useful to mark phases in the
    trace ("train_step saturated from t₁ to t₂") or as the template for
    application-defined reactions — subclass and override :meth:`react`.
    """

    name = "threshold-advisory"

    def __init__(self, provider: str, api: str, high: float = 0.5, low: float = 0.1):
        self.provider = provider
        self.api = api
        self.high = high
        self.low = low
        self.above = False

    def react(self, ctx: AdaptiveContext, above: bool, busy: float) -> None:
        ctx.advise(
            f"busy:{self.provider}:{self.api}",
            "high" if above else "low",
            f"busy_fraction={busy:.2f}",
        )

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if not self.above and busy >= self.high:
            self.above = True
            self.react(ctx, True, busy)
        elif self.above and busy <= self.low:
            self.above = False
            self.react(ctx, False, busy)


class _ControllerCore:
    """Shared machinery of the per-rank and cluster-scope controllers:
    the append-only action log, the ``on_action`` observer, and the
    ``ust_repro:advisory`` trace-event plumbing."""

    def __init__(
        self,
        period_s: float,
        on_action: Optional[Callable[[AdaptiveAction], None]] = None,
    ):
        self.period_s = period_s
        self.on_action = on_action
        self.actions: List[AdaptiveAction] = []
        self.ticks = 0
        self._tracer = None
        self._advise_record = None  # ust_repro:advisory recorder, when traced
        self._lock = threading.Lock()

    def attach(self, tracer) -> "_ControllerCore":
        """Bind to a live tracing session: advisories land in its trace."""
        self._tracer = tracer
        rec = getattr(tracer, "tp", None)
        self._advise_record = rec.record.get("ust_repro:advisory") if rec else None
        return self

    def _record(self, policy: str, knob: str, value: str, reason: str) -> None:
        act = AdaptiveAction(time.time(), policy, knob, value, reason)
        self.actions.append(act)
        if self._advise_record is not None:
            try:
                self._advise_record(policy, knob, f"{value} ({reason})")
            except Exception:
                pass  # advisory must never break adaptation
        if self.on_action is not None:
            self.on_action(act)

    def render_log(self) -> str:
        """Human-readable action log (one line per action)."""
        return "\n".join(str(a) for a in self.actions)


class AdaptiveController(_ControllerCore):
    """Owns the policies; diffs live snapshots; rate-limits ticks.

    Built by the tracer from ``TraceConfig.adaptive`` (or handed to a
    :class:`ServeEngine`); both call :meth:`tick` from their loops and the
    controller decides (every ``period_s``) whether a window has elapsed.
    Thread-safe: consumer-thread and serving-thread ticks may interleave.

    ``actions`` is the append-only audit log; ``on_action`` (optional
    callable) observes every action as it happens — handy for tests and
    for surfacing adaptations in training logs.
    """

    def __init__(
        self,
        policies: Sequence[AdaptivePolicy],
        period_s: float = 0.5,
        on_action: Optional[Callable[[AdaptiveAction], None]] = None,
    ):
        super().__init__(period_s, on_action)
        self.policies = list(policies)
        self._prev_snap: Optional[Tally] = None
        self._prev_t = 0.0
        self._prev_dropped = 0
        self._window_dropped = 0

    def attach(self, tracer) -> "AdaptiveController":
        """Bind to a live tracing session (the tracer calls this at start)."""
        super().attach(tracer)
        with self._lock:
            self._prev_snap = None
            self._prev_t = 0.0
            self._prev_dropped = 0
        return self

    def tick(self, engine=None, force: bool = False) -> bool:
        """Run one adaptation window if due; True when policies actually ran.

        The first due tick only baselines (no policy sees a window computed
        against an empty history). Policy exceptions are swallowed per
        policy, so one misbehaving policy cannot stop the others — or the
        consumer thread.

        An unattached controller (e.g. a ``ServeEngine`` built before its
        ``Tracer`` started) attaches itself to the process's active session
        on first tick, so construction order doesn't matter.
        """
        if self._tracer is None:
            from .tracer import active_tracer

            tr = active_tracer()
            if tr is not None:
                self.attach(tr)
        tr = self._tracer
        if tr is None or tr.online is None:
            return False
        with self._lock:
            now = time.monotonic()
            if not force and self._prev_snap is not None and (
                now - self._prev_t < self.period_s
            ):
                return False
            cur = tr.online.snapshot()
            dropped_total = tr.registry.total_dropped if tr.registry is not None else 0
            prev, prev_t = self._prev_snap, self._prev_t
            self._window_dropped = dropped_total - self._prev_dropped
            self._prev_snap, self._prev_t = cur, now
            self._prev_dropped = dropped_total
            if prev is None:
                return False  # baseline window
            self.ticks += 1
            ctx = AdaptiveContext(self, prev, cur, max(1e-9, now - prev_t), engine)
            for pol in self.policies:
                ctx._policy = pol.name
                try:
                    pol.tick(ctx)
                except Exception:
                    pass  # a policy must never kill the consumer thread
            return True


# ---------------------------------------------------------------------------
# Cluster scope: per-rank composites → cross-rank policies
# ---------------------------------------------------------------------------


class ClusterContext:
    """What a cluster policy sees on one tick: per-rank windowed metrics.

    Built from two consecutive per-rank tally maps (source id → cumulative
    tally, the ``query_ranks`` / ``MasterServer.ranks`` shape) ``window_s``
    apart, so every metric describes *recent, per-rank* behavior.  The
    cross-rank views (``latency_by_rank``, ``busy_by_rank``,
    ``skew_by_rank``) are where cluster-only signals appear: a straggling
    rank looks normal in its own window and only stands out against the
    cluster median.
    """

    def __init__(
        self,
        controller: "ClusterAdaptiveController",
        prev: Dict[str, Tally],
        cur: Dict[str, Tally],
        window_s: float,
        telemetry: Optional[Dict[str, dict]] = None,
    ):
        self._controller = controller
        self._prev = prev
        self._cur = cur
        self.window_s = window_s
        self._telemetry = telemetry or {}
        self._policy = "?"  # set by the controller per policy
        #: sources flagged (any kind) during this tick — the controller
        #: reports every other active source healthy afterwards (hysteresis
        #: channel of the remediation engine)
        self.flagged_sources: set = set()

    # -- per-rank windowed metrics -------------------------------------------
    def rank_ids(self) -> List[str]:
        """Sorted source ids present in the current per-rank map."""
        return sorted(self._cur)

    def window(
        self, source: str, provider: str, api: str, device: bool = False
    ) -> Tuple[int, int]:
        """(calls, total_ns) ``source`` accumulated inside the last window.

        A source absent from the *previous* map is newly joined (elastic
        scale-up, late rank): its whole cumulative history — jit compiles
        included — is not a window, so it baselines as (0, 0) and starts
        contributing from the next observation, exactly like the
        controller's own first tick.  An API absent from the previous map
        of a *known* source genuinely appeared this window and counts in
        full.
        """
        cur_tally = self._cur.get(source)
        prev_tally = self._prev.get(source)
        if cur_tally is None or prev_tally is None:
            return 0, 0
        cur_t = cur_tally.device_apis if device else cur_tally.apis
        c = cur_t.get((provider, api))
        if c is None:
            return 0, 0
        prev_t = prev_tally.device_apis if device else prev_tally.apis
        p = prev_t.get((provider, api))
        if p is None:
            return c.calls, c.total_ns
        return c.calls - p.calls, c.total_ns - p.total_ns

    def busy_fraction(
        self, source: str, provider: str, api: str, device: bool = False
    ) -> float:
        """Share of the last window's wall time ``source`` spent in ``api``."""
        if self.window_s <= 0:
            return 0.0
        _, total_ns = self.window(source, provider, api, device)
        return total_ns / (self.window_s * 1e9)

    def latency_ns(
        self, source: str, provider: str, api: str, device: bool = False
    ) -> float:
        """``source``'s mean per-call latency of ``api`` over the window."""
        calls, total_ns = self.window(source, provider, api, device)
        return total_ns / calls if calls > 0 else 0.0

    def snapshot(self, source: str) -> Optional[Tally]:
        """``source``'s current cumulative tally (None if unknown)."""
        return self._cur.get(source)

    def telemetry(self, source: str) -> Optional[dict]:
        """``source``'s latest device-telemetry dict (host RSS, device
        memory pressure, memcpy/alloc bandwidth — docs/streaming.md), or
        None when its frames never carried any."""
        return self._telemetry.get(source)

    def telemetry_by_rank(self) -> Dict[str, dict]:
        """source → its latest telemetry dict (sources that shipped one)."""
        return dict(self._telemetry)

    # -- cross-rank views ----------------------------------------------------
    def busy_by_rank(
        self, provider: str, api: str, device: bool = False
    ) -> Dict[str, float]:
        """source → windowed busy fraction, ranks active this window only."""
        out = {}
        for src in self._cur:
            calls, _ = self.window(src, provider, api, device)
            if calls > 0:
                out[src] = self.busy_fraction(src, provider, api, device)
        return out

    def latency_by_rank(
        self, provider: str, api: str, device: bool = False, min_calls: int = 1
    ) -> Dict[str, float]:
        """source → windowed mean latency, ranks with ≥ ``min_calls`` only."""
        out = {}
        for src in self._cur:
            calls, total_ns = self.window(src, provider, api, device)
            if calls >= max(1, min_calls):
                out[src] = total_ns / calls
        return out

    def skew_by_rank(
        self, provider: str, api: str, metric: str = "latency", device: bool = False
    ) -> Dict[str, float]:
        """source → ratio of its windowed metric to the cluster median.

        A healthy, balanced cluster sits near 1.0 everywhere; a straggler
        shows a ratio ≫ 1.  Empty when fewer than two ranks were active (a
        median of one rank compares it to itself).
        """
        vals = (
            self.latency_by_rank(provider, api, device)
            if metric == "latency"
            else self.busy_by_rank(provider, api, device)
        )
        if len(vals) < 2:
            return {}
        med = statistics.median(vals.values())
        if med <= 0:
            return {}
        return {src: v / med for src, v in vals.items()}

    # -- actions -------------------------------------------------------------
    def advise(self, knob: str, value: str, reason: str = "") -> None:
        """Record an advisory action: controller log + trace event (when a
        session is attached)."""
        self._controller._record(self._policy, knob, value, reason)

    def flag_straggler(
        self, source: str, provider: str, api: str, ratio: float, reason: str = ""
    ) -> None:
        """Report ``source`` as a straggler: advisory + workload callback.

        This is the API-level evidence channel into the trainer — the
        controller's ``on_straggler`` callback (e.g.
        ``StragglerWatchdog.note_api_evidence``) receives *which rank*,
        *which API*, and *how far behind the median*.
        """
        self.advise(f"straggler:{source}", f"{provider}:{api}={ratio:.2f}x", reason)
        self.flagged_sources.add(source)
        self._controller._notify_straggler(source, provider, api, ratio, reason)
        self._controller._notify_flag(source, "straggler", f"{provider}:{api} {reason}")

    def flag(self, source: str, kind: str, detail: str = "") -> None:
        """Report ``source`` unhealthy for any ``kind`` of evidence
        (``"sick-host"``, ``"imbalance"``, ...): advisory + the controller's
        generic ``on_flag`` callback — the channel the remediation engine's
        escalation ladder consumes."""
        self.advise(f"{kind}:{source}", "flagged", detail)
        self.flagged_sources.add(source)
        self._controller._notify_flag(source, kind, detail)


class ClusterPolicy:
    """Base class for cluster-scope policies: look at a
    :class:`ClusterContext`, optionally advise or flag ranks.

    Same contract as :class:`AdaptivePolicy`: stateful, invoked once per
    controller tick, must be fast, exceptions are isolated per policy.
    """

    name = "cluster-policy"

    def tick(self, ctx: ClusterContext) -> None:
        raise NotImplementedError


class StragglerRankPolicy(ClusterPolicy):
    """Flag ranks whose windowed metric lags the cluster median.

    The cluster-scope answer to the trainer's wall-clock EWMA watchdog: the
    EWMA knows *this* rank had slow steps; this policy knows *which* rank is
    slow relative to the others, on *which* API, and by *how much* — the
    evidence exascale diagnostics actually need for rank replacement.

    Per tick: compute the per-rank windowed metric (``latency`` — mean ns
    per call of the watched API — or ``busy`` fraction), take the cluster
    median, and strike every rank at ≥ ``ratio`` × median.  A rank flagged
    ``patience`` consecutive windows is reported once via
    ``ctx.flag_straggler`` (advisory + ``on_straggler`` callback) and
    re-armed when it drops back below the threshold (a ``recovered``
    advisory marks the transition).
    """

    name = "straggler-rank"

    def __init__(
        self,
        provider: str,
        api: str,
        ratio: float = 1.75,
        metric: str = "latency",
        patience: int = 2,
        min_ranks: int = 2,
        min_calls: int = 1,
        device: bool = False,
    ):
        if metric not in ("latency", "busy"):
            raise ValueError(f"metric must be 'latency' or 'busy', got {metric!r}")
        self.provider = provider
        self.api = api
        self.ratio = ratio
        self.metric = metric
        self.patience = max(1, int(patience))
        self.min_ranks = max(2, int(min_ranks))
        self.min_calls = max(1, int(min_calls))
        self.device = device
        self._strikes: Dict[str, int] = {}
        #: currently-flagged ranks → last observed ratio
        self.flagged: Dict[str, float] = {}

    def tick(self, ctx: ClusterContext) -> None:
        vals = (
            ctx.latency_by_rank(
                self.provider, self.api, self.device, min_calls=self.min_calls
            )
            if self.metric == "latency"
            else ctx.busy_by_rank(self.provider, self.api, self.device)
        )
        if len(vals) < self.min_ranks:
            # no comparative window: nothing can be struck, so nothing may
            # stay struck — "patience consecutive windows" means consecutive.
            # Flags drop too: an idle/quorumless stretch ends the excursion,
            # and fresh evidence must be able to re-report the rank.
            self._strikes.clear()
            self.flagged.clear()
            return
        med = statistics.median(vals.values())
        if med <= 0:
            self._strikes.clear()
            self.flagged.clear()
            return
        for src in list(self._strikes):
            if src not in vals:  # idle this window: the streak is broken
                del self._strikes[src]
        for src in list(self.flagged):
            if src not in vals:  # idle flagged rank: excursion over, re-arm
                del self.flagged[src]
        for src, v in vals.items():
            r = v / med
            if r >= self.ratio:
                self._strikes[src] = self._strikes.get(src, 0) + 1
                if self._strikes[src] >= self.patience and src not in self.flagged:
                    self.flagged[src] = r
                    ctx.flag_straggler(
                        src,
                        self.provider,
                        self.api,
                        r,
                        f"window {self.metric} {r:.2f}x cluster median "
                        f"({self._strikes[src]} consecutive windows, "
                        f"{len(vals)} ranks)",
                    )
            else:
                self._strikes[src] = 0
                if src in self.flagged:
                    del self.flagged[src]
                    ctx.advise(
                        f"straggler:{src}",
                        "recovered",
                        f"window {self.metric} back to {r:.2f}x median",
                    )


class SickHostPolicy(ClusterPolicy):
    """Flag ranks whose *device telemetry* says the host is sick.

    The straggler policy sees API latency — it cannot tell a slow kernel
    (workload) from a dying host (infrastructure).  This policy reads the
    per-rank telemetry carried in the forwarded breakdown (host RSS, device
    memory pressure, memcpy bandwidth — ``ClusterContext.telemetry``) and
    flags ranks on *host-level* evidence, so the remediation ladder can pick
    the right rung: escalate fidelity on a slow kernel, drain-and-evict a
    sick host.

    Evidence, any of which counts as a strike:

    * device memory pressure: ``mem_in_use / mem_limit ≥ mem_frac``;
    * host RSS blow-up: RSS ≥ ``rss_ratio`` × the cluster median RSS;
    * transfer collapse: the rank's ``memcpy_bw`` ≤ ``bw_floor`` × the
      cluster median while the median is non-trivial (others are moving
      data, this host is not).

    ``patience`` consecutive striking windows flag the rank once via
    ``ctx.flag(source, "sick-host", ...)``; dropping back below every
    threshold re-arms it with a ``recovered`` advisory.
    """

    name = "sick-host"

    def __init__(
        self,
        rss_ratio: float = 2.0,
        mem_frac: float = 0.95,
        bw_floor: float = 0.05,
        patience: int = 2,
        min_ranks: int = 2,
    ):
        if not (0.0 < mem_frac <= 1.0):
            raise ValueError(f"mem_frac must be in (0,1], got {mem_frac}")
        self.rss_ratio = rss_ratio
        self.mem_frac = mem_frac
        self.bw_floor = bw_floor
        self.patience = max(1, int(patience))
        self.min_ranks = max(2, int(min_ranks))
        self._strikes: Dict[str, int] = {}
        #: currently-flagged ranks → last evidence string
        self.flagged: Dict[str, str] = {}

    def _evidence(self, telem: dict, med_rss: float, med_bw: float) -> Optional[str]:
        limit = float(telem.get("mem_limit", 0) or 0)
        in_use = float(telem.get("mem_in_use", 0) or 0)
        if limit > 0 and in_use / limit >= self.mem_frac:
            return f"device-memory {100.0 * in_use / limit:.0f}% of limit"
        rss = float(telem.get("host_rss", 0) or 0)
        if med_rss > 0 and rss >= self.rss_ratio * med_rss:
            return f"host-rss {rss / med_rss:.2f}x cluster median"
        bw = float(telem.get("memcpy_bw", 0) or 0)
        if med_bw > 0 and bw <= self.bw_floor * med_bw:
            return f"memcpy-bw {bw:.0f} B/s vs median {med_bw:.0f} B/s"
        return None

    def tick(self, ctx: ClusterContext) -> None:
        telem = ctx.telemetry_by_rank()
        if len(telem) < self.min_ranks:
            self._strikes.clear()
            self.flagged.clear()
            return
        rss_vals = [float(t.get("host_rss", 0) or 0) for t in telem.values()]
        bw_vals = [float(t.get("memcpy_bw", 0) or 0) for t in telem.values()]
        med_rss = statistics.median(rss_vals) if rss_vals else 0.0
        med_bw = statistics.median(bw_vals) if bw_vals else 0.0
        for src in list(self._strikes):
            if src not in telem:  # no telemetry this window: streak broken
                del self._strikes[src]
        for src in list(self.flagged):
            if src not in telem:
                del self.flagged[src]
        for src, t in telem.items():
            ev = self._evidence(t, med_rss, med_bw)
            if ev is not None:
                self._strikes[src] = self._strikes.get(src, 0) + 1
                if self._strikes[src] >= self.patience and src not in self.flagged:
                    self.flagged[src] = ev
                    ctx.flag(
                        src,
                        "sick-host",
                        f"{ev} ({self._strikes[src]} consecutive windows, "
                        f"{len(telem)} ranks)",
                    )
            else:
                self._strikes[src] = 0
                if src in self.flagged:
                    del self.flagged[src]
                    ctx.advise(f"sick-host:{src}", "recovered", "telemetry back in range")


class RankImbalanceAdvisoryPolicy(ClusterPolicy):
    """Narrate cluster-wide load imbalance on a watched API.

    Emits a ``high`` advisory when the max-rank-to-median spread of the
    windowed busy fraction crosses ``high``, and a ``low`` advisory once it
    falls back under ``low`` (hysteresis, like
    :class:`ThresholdAdvisoryPolicy` but across ranks).  No knobs turned —
    the trace simply gains "the cluster ran imbalanced from t₁ to t₂".
    """

    name = "rank-imbalance"

    def __init__(
        self, provider: str, api: str, high: float = 2.0, low: float = 1.25
    ):
        self.provider = provider
        self.api = api
        self.high = high
        self.low = low
        self.above = False

    def tick(self, ctx: ClusterContext) -> None:
        vals = ctx.busy_by_rank(self.provider, self.api)
        if len(vals) < 2:
            return
        med = statistics.median(vals.values())
        if med <= 0:
            return
        spread = max(vals.values()) / med
        knob = f"imbalance:{self.provider}:{self.api}"
        if not self.above and spread >= self.high:
            self.above = True
            ctx.advise(knob, "high", f"max/median busy={spread:.2f} over {len(vals)} ranks")
        elif self.above and spread <= self.low:
            self.above = False
            ctx.advise(knob, "low", f"max/median busy={spread:.2f}")


class ClusterAdaptiveController(_ControllerCore):
    """Owns cluster policies; diffs per-rank maps; rate-limits ticks.

    Reads the per-rank breakdown from a streaming master — in-process
    (``master=MasterServer``, zero-copy via :meth:`MasterServer.ranks`) or
    remote (``addr="host:port"`` via ``query_ranks``) — or from explicit
    :meth:`observe` calls (tests drive synthetic rank maps with an explicit
    clock, no sockets, no sleeps).

    ``on_straggler(source, provider, api, ratio, reason)`` is the workload
    feedback channel: wire ``trainer.straggler_callback`` here and the
    training loop's watchdog receives API-level straggler evidence.
    An unbound controller (no master, no addr) binds itself to the active
    tracing session's in-process master on first tick, mirroring
    :class:`AdaptiveController`'s construction-order independence.
    """

    def __init__(
        self,
        policies: Sequence[ClusterPolicy],
        master=None,
        addr: Optional[str] = None,
        period_s: float = 1.0,
        on_action: Optional[Callable[[AdaptiveAction], None]] = None,
        on_straggler: Optional[Callable[[str, str, str, float, str], None]] = None,
        on_flag: Optional[Callable[[str, str, str], None]] = None,
        on_healthy: Optional[Callable[[str], None]] = None,
        clock: Callable[[], float] = time.monotonic,
        timeout_s: float = 2.0,
        token: Optional[str] = None,
        tls_ca: Optional[str] = None,
        ssl_context=None,
    ):
        super().__init__(period_s, on_action)
        self.policies = list(policies)
        self.master = master
        self.addr = addr
        self.on_straggler = on_straggler
        #: generic unhealthy-rank channel: ``(source, kind, detail)`` per
        #: flag — wire ``RemediationEngine.ingest_flag`` here to close the
        #: loop.  ``on_healthy(source)`` fires for every active-but-unflagged
        #: source after each adaptation window (the engine's hysteresis).
        self.on_flag = on_flag
        self.on_healthy = on_healthy
        self.clock = clock
        self.timeout_s = timeout_s
        #: credentials for the remote (``addr``) fetch path: hardened
        #: masters demand a token and may sit behind TLS (tls_ca pins them)
        self.token = token
        self.tls_ca = tls_ca
        self.ssl_context = ssl_context
        self._client = None  # persistent StreamClient for the addr path
        self._prev: Optional[Dict[str, Tally]] = None
        self._prev_t = 0.0
        self._attempt_t: Optional[float] = None  # last fetch attempt (any outcome)

    def bind(self, master=None, addr: Optional[str] = None) -> "ClusterAdaptiveController":
        """Point the controller at a master after construction."""
        if master is not None:
            self.master = master
        if addr is not None:
            self.addr = addr
        return self

    def close(self) -> None:
        """Drop the remote connection (the addr path reuses one socket)."""
        c, self._client = self._client, None
        if c is not None:
            c.close()

    def _fetch(self) -> Optional[Tuple[Dict[str, Tally], Dict[str, dict]]]:
        if self.master is not None:
            # frozen snapshots (replaced wholesale on change, never mutated):
            # the windowed diffs only read them, so skip the per-tick deep
            # copy of every rank's table — O(changed) per adaptation window
            return self.master.ranks(copy=False), self.master.telemetry()
        if self.addr is not None:
            from .stream import ProtocolError, StreamClient

            try:
                if self._client is None:
                    self._client = StreamClient(
                        self.addr,
                        timeout_s=self.timeout_s,
                        token=self.token,
                        tls_ca=self.tls_ca,
                        ssl_context=self.ssl_context,
                    )
                ranks, meta = self._client.ranks()
                return ranks, meta.get("telemetry", {})
            except (OSError, ProtocolError, ValueError):
                self.close()  # reconnect fresh on the next attempt
                return None  # master absent: adaptation pauses, never raises
        return None

    def tick(self, force: bool = False) -> bool:
        """Fetch the per-rank map and run one adaptation window if due.

        The rate limit gates *attempts*, not successes: an unreachable
        master (a blocking connect of up to ``timeout_s``) is retried once
        per ``period_s``, never once per caller iteration — a consumer loop
        or decode loop must not stall every pass on a master that is down.
        """
        if self.master is None and self.addr is None:
            from .tracer import active_tracer

            tr = active_tracer()
            if tr is not None and getattr(tr, "server", None) is not None:
                self.master = tr.server
                if self._tracer is None:
                    self.attach(tr)
            else:
                return False
        now = self.clock()
        with self._lock:
            if not force and self._attempt_t is not None and (
                now - self._attempt_t < self.period_s
            ):
                return False
            self._attempt_t = now
        fetched = self._fetch()
        if fetched is None:
            return False
        ranks, telemetry = fetched
        return self.observe(ranks, now, telemetry=telemetry)

    def observe(
        self,
        ranks: Dict[str, Tally],
        now: float,
        telemetry: Optional[Dict[str, dict]] = None,
    ) -> bool:
        """Ingest one per-rank map observed at ``now``; True when policies
        ran.  The first observation only baselines.  Public so tests (and
        alternative transports) can drive the controller with explicit
        clocks and synthetic maps.  ``telemetry`` optionally maps source →
        its device-telemetry dict (the ``meta["telemetry"]`` shape)."""
        with self._lock:
            prev, prev_t = self._prev, self._prev_t
            self._prev, self._prev_t = ranks, now
            if prev is None:
                return False  # baseline window
            self.ticks += 1
            ctx = ClusterContext(
                self, prev, ranks, max(1e-9, now - prev_t), telemetry=telemetry
            )
            for pol in self.policies:
                ctx._policy = pol.name
                try:
                    pol.tick(ctx)
                except Exception:
                    pass  # a policy must never kill the consumer thread
            if self.on_healthy is not None:
                # every source seen this window and not flagged by any policy
                # counts as a healthy observation (remediation hysteresis)
                for src in ranks:
                    if src not in ctx.flagged_sources:
                        try:
                            self.on_healthy(src)
                        except Exception:
                            pass  # callback must never break adaptation
            return True

    def _notify_straggler(
        self, source: str, provider: str, api: str, ratio: float, reason: str
    ) -> None:
        if self.on_straggler is not None:
            try:
                self.on_straggler(source, provider, api, ratio, reason)
            except Exception:
                pass  # workload callback must never break adaptation

    def _notify_flag(self, source: str, kind: str, detail: str) -> None:
        if self.on_flag is not None:
            try:
                self.on_flag(source, kind, detail)
            except Exception:
                pass  # workload callback must never break adaptation


def build_cluster_controller(
    policies: Union["ClusterAdaptiveController", Sequence[ClusterPolicy], None],
    period_s: float = 1.0,
    **kw,
) -> Optional[ClusterAdaptiveController]:
    """Normalize ``TraceConfig.cluster_adaptive`` / ``ServeEngine`` input:
    pass through a ready controller, wrap a policy list, map None to None."""
    if policies is None:
        return None
    if isinstance(policies, ClusterAdaptiveController):
        return policies
    return ClusterAdaptiveController(list(policies), period_s=period_s, **kw)


def build_controller(
    policies: Union["AdaptiveController", Sequence[AdaptivePolicy], None],
    period_s: float = 0.5,
) -> Optional[AdaptiveController]:
    """Normalize ``TraceConfig.adaptive`` / ``ServeEngine(adaptive=…)`` input:
    pass through a ready controller, wrap a policy list, map None to None."""
    if policies is None:
        return None
    if isinstance(policies, AdaptiveController):
        return policies
    return AdaptiveController(list(policies), period_s=period_s)

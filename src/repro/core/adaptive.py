"""Adaptive-optimization consumer (THAPI §6, the paper's closing vision).

    "we are also working on online trace analysis, where tracing and analysis
     can be performed concurrently to enable adaptive optimizations during
     application runtime."

``online.py`` gives a rank a *live tally*; ``stream.py`` gives the cluster a
*live composite*.  This module closes the loop: an :class:`AdaptiveController`
rides the tracer's consumer thread, computes **windowed** rates from
successive live snapshots (busy fraction, per-call latency, ring-buffer
drops), and hands them to pluggable :class:`AdaptivePolicy` objects that may
turn session knobs *mid-run* — widen event sampling, resize ring buffers for
new threads, retune snapshot cadence — or emit ``ust_repro:advisory`` events
into the trace so the reconfiguration itself is visible post-mortem.

Wiring:

  * ``TraceConfig(adaptive=[...policies...])`` — the tracer builds a
    controller and ticks it from the consumer loop every
    ``adaptive_period_s`` (collection hot paths never see it);
  * ``ServeEngine(..., adaptive=controller_or_policies)`` — the serving loop
    ticks the same machinery between decode steps, with ``ctx.engine`` set
    so policies can reach serving knobs;
  * every knob change is recorded as an :class:`AdaptiveAction` (see
    ``controller.actions``) *and* traced as an advisory event.

Windowed metrics, not cumulative ones: ``OnlineAnalyzer.busy_fraction`` is
share-of-total since session start; a policy reacting mid-run needs the
share over the *last* window, so the controller diffs consecutive snapshots.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

from .plugins.tally import Tally


@dataclasses.dataclass
class AdaptiveAction:
    """One knob change (or advisory) taken by a policy, for the audit log."""

    ts: float  # wall clock
    policy: str
    knob: str
    value: str
    reason: str

    def __str__(self) -> str:
        return f"[adaptive] {self.policy}: {self.knob}={self.value} ({self.reason})"


class AdaptiveContext:
    """What a policy sees on one tick: windowed live metrics + session knobs.

    Metrics are computed from the difference between the previous tick's
    tally snapshot and the current one over ``window_s`` of wall time, so
    they describe *recent* behavior.  Knob setters go through the
    controller, which records an :class:`AdaptiveAction` and emits an
    advisory event into the trace.
    """

    def __init__(
        self,
        controller: "AdaptiveController",
        prev: Tally,
        cur: Tally,
        window_s: float,
        engine=None,
    ):
        self._controller = controller
        self._prev = prev
        self._cur = cur
        self.window_s = window_s
        #: the ServeEngine driving this tick, when ticked from the serving
        #: loop (None on consumer-thread ticks)
        self.engine = engine
        self._policy = "?"  # set by the controller per policy

    # -- windowed metrics ----------------------------------------------------
    def _window(self, provider: str, api: str, device: bool) -> Tuple[int, int]:
        """(calls, total_ns) accumulated inside the last window."""
        cur_t = self._cur.device_apis if device else self._cur.apis
        prev_t = self._prev.device_apis if device else self._prev.apis
        c = cur_t.get((provider, api))
        if c is None:
            return 0, 0
        p = prev_t.get((provider, api))
        if p is None:
            return c.calls, c.total_ns
        return c.calls - p.calls, c.total_ns - p.total_ns

    def busy_fraction(self, provider: str, api: str, device: bool = False) -> float:
        """Share of the last window's wall time spent inside ``api``."""
        if self.window_s <= 0:
            return 0.0
        _, total_ns = self._window(provider, api, device)
        return total_ns / (self.window_s * 1e9)

    def window_calls(self, provider: str, api: str, device: bool = False) -> int:
        """Calls to ``api`` completed during the last window."""
        calls, _ = self._window(provider, api, device)
        return calls

    def window_latency_ns(self, provider: str, api: str, device: bool = False) -> float:
        """Mean per-call latency of ``api`` over the last window (0 if idle)."""
        calls, total_ns = self._window(provider, api, device)
        return total_ns / calls if calls > 0 else 0.0

    def dropped_in_window(self) -> int:
        """Ring-buffer events discarded during the last window."""
        return self._controller._window_dropped

    def snapshot(self) -> Tally:
        """The current cumulative live tally (for policies that need it)."""
        return self._cur

    # -- knobs ---------------------------------------------------------------
    def set_stream_period(self, seconds: float, reason: str = "") -> None:
        """Retune the live-snapshot push cadence (``stream_period_s``)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.cfg.stream_period_s = max(0.01, float(seconds))
        self._act("stream_period_s", f"{tr.cfg.stream_period_s:g}", reason)

    def set_flush_period(self, seconds: float, reason: str = "") -> None:
        """Retune the consumer drain period (``flush_period_s``)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.cfg.flush_period_s = max(0.005, float(seconds))
        self._act("flush_period_s", f"{tr.cfg.flush_period_s:g}", reason)

    def set_sample_period(self, seconds: float, reason: str = "") -> None:
        """Retune the telemetry daemon's sampling period, when it runs."""
        tr = self._controller._tracer
        sampler = getattr(tr, "_sampler", None) if tr is not None else None
        if sampler is None:
            return
        sampler.period_s = max(0.005, float(seconds))
        self._act("sample_period_s", f"{sampler.period_s:g}", reason)

    def set_event(self, name: str, on: bool, reason: str = "") -> None:
        """Enable/disable one tracepoint live (widen or narrow sampling)."""
        tr = self._controller._tracer
        if tr is None:
            return
        tr.tp.set_event(name, on)
        self._act(f"event:{name}", "on" if on else "off", reason)

    def set_ring_bytes(self, nbytes: int, reason: str = "") -> None:
        """Resize the ring-buffer capacity used for *future* threads."""
        tr = self._controller._tracer
        if tr is None or tr.registry is None:
            return
        tr.registry.set_capacity(int(nbytes))
        self._act("ring_bytes", str(int(nbytes)), reason)

    def advise(self, knob: str, value: str, reason: str = "") -> None:
        """Record an advisory-only action (no knob turned): it lands in the
        controller log and as an ``ust_repro:advisory`` trace event."""
        self._act(knob, value, reason)

    def _act(self, knob: str, value: str, reason: str) -> None:
        self._controller._record(self._policy, knob, value, reason)


class AdaptivePolicy:
    """Base class: look at an :class:`AdaptiveContext`, optionally turn knobs.

    Policies are stateful objects, invoked once per controller tick on the
    consumer (or serving) thread; they must be fast and must never raise —
    the controller isolates exceptions, but a throwing policy stops
    adapting.  ``name`` labels the policy in action logs and advisory
    events.
    """

    name = "policy"

    def tick(self, ctx: AdaptiveContext) -> None:
        raise NotImplementedError


class WidenSamplingPolicy(AdaptivePolicy):
    """Widen tally sampling when one API dominates the window.

    While ``busy_fraction(provider, api)`` stays above ``high``, the events
    in ``widen_events`` (typically polling / telemetry events excluded by
    the mode preset) are enabled to capture *why* the API is hot; once the
    fraction falls below ``low`` they are disabled again — Fig 7's overhead
    ladder applied dynamically instead of picked up front.
    """

    name = "widen-sampling"

    def __init__(
        self,
        provider: str,
        api: str,
        widen_events: Sequence[str],
        high: float = 0.5,
        low: float = 0.1,
    ):
        self.provider = provider
        self.api = api
        self.widen_events = tuple(widen_events)
        self.high = high
        self.low = low
        self.widened = False

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if not self.widened and busy >= self.high:
            self.widened = True
            for name in self.widen_events:
                ctx.set_event(
                    name, True, f"busy_fraction({self.api})={busy:.2f}≥{self.high}"
                )
        elif self.widened and busy <= self.low:
            self.widened = False
            for name in self.widen_events:
                ctx.set_event(
                    name, False, f"busy_fraction({self.api})={busy:.2f}≤{self.low}"
                )


class StreamCadencePolicy(AdaptivePolicy):
    """Snapshot faster while a watched API is hot, slower while idle.

    A live dashboard wants fresh composites exactly when something is
    happening; when the window is quiet, pushing snapshots is pure wire
    noise.  Moves ``stream_period_s`` between ``fast_s`` and ``slow_s`` on
    the ``high`` / ``low`` busy-fraction thresholds.
    """

    name = "stream-cadence"

    def __init__(
        self,
        provider: str,
        api: str,
        high: float = 0.3,
        low: float = 0.05,
        fast_s: float = 0.1,
        slow_s: float = 1.0,
    ):
        self.provider = provider
        self.api = api
        self.high = high
        self.low = low
        self.fast_s = fast_s
        self.slow_s = slow_s
        self._state = ""  # "", "fast", "slow"

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if busy >= self.high and self._state != "fast":
            self._state = "fast"
            ctx.set_stream_period(
                self.fast_s, f"busy_fraction({self.api})={busy:.2f}≥{self.high}"
            )
        elif busy <= self.low and self._state != "slow":
            self._state = "slow"
            ctx.set_stream_period(
                self.slow_s, f"busy_fraction({self.api})={busy:.2f}≤{self.low}"
            )


class RingPressurePolicy(AdaptivePolicy):
    """Grow ring-buffer capacity when the window shows discarded events.

    Rings drop rather than block (§3.1); sustained drops mean the configured
    capacity undershoots the event rate.  Each tick that observes new drops
    doubles the capacity used for future threads' rings (bounded by
    ``max_bytes``) and emits an advisory either way, so the drop burst is
    visible in the trace even when the cap is reached.
    """

    name = "ring-pressure"

    def __init__(self, factor: float = 2.0, max_bytes: int = 1 << 26):
        self.factor = factor
        self.max_bytes = max_bytes

    def tick(self, ctx: AdaptiveContext) -> None:
        dropped = ctx.dropped_in_window()
        if dropped <= 0:
            return
        tr = ctx._controller._tracer
        if tr is None or tr.registry is None:
            return
        cur = tr.registry.capacity
        if cur >= self.max_bytes:
            ctx.advise("ring_bytes", str(cur), f"{dropped} drops but cap reached")
            return
        ctx.set_ring_bytes(
            min(self.max_bytes, int(cur * self.factor)),
            f"{dropped} events dropped in window",
        )


class ThresholdAdvisoryPolicy(AdaptivePolicy):
    """Emit an advisory whenever a busy fraction crosses a threshold.

    The no-knob policy: it only narrates.  Useful to mark phases in the
    trace ("train_step saturated from t₁ to t₂") or as the template for
    application-defined reactions — subclass and override :meth:`react`.
    """

    name = "threshold-advisory"

    def __init__(self, provider: str, api: str, high: float = 0.5, low: float = 0.1):
        self.provider = provider
        self.api = api
        self.high = high
        self.low = low
        self.above = False

    def react(self, ctx: AdaptiveContext, above: bool, busy: float) -> None:
        ctx.advise(
            f"busy:{self.provider}:{self.api}",
            "high" if above else "low",
            f"busy_fraction={busy:.2f}",
        )

    def tick(self, ctx: AdaptiveContext) -> None:
        busy = ctx.busy_fraction(self.provider, self.api)
        if not self.above and busy >= self.high:
            self.above = True
            self.react(ctx, True, busy)
        elif self.above and busy <= self.low:
            self.above = False
            self.react(ctx, False, busy)


class AdaptiveController:
    """Owns the policies; diffs live snapshots; rate-limits ticks.

    Built by the tracer from ``TraceConfig.adaptive`` (or handed to a
    :class:`ServeEngine`); both call :meth:`tick` from their loops and the
    controller decides (every ``period_s``) whether a window has elapsed.
    Thread-safe: consumer-thread and serving-thread ticks may interleave.

    ``actions`` is the append-only audit log; ``on_action`` (optional
    callable) observes every action as it happens — handy for tests and
    for surfacing adaptations in training logs.
    """

    def __init__(
        self,
        policies: Sequence[AdaptivePolicy],
        period_s: float = 0.5,
        on_action: Optional[Callable[[AdaptiveAction], None]] = None,
    ):
        self.policies = list(policies)
        self.period_s = period_s
        self.on_action = on_action
        self.actions: List[AdaptiveAction] = []
        self.ticks = 0
        self._tracer = None
        self._advise_record = None  # ust_repro:advisory recorder, when traced
        self._lock = threading.Lock()
        self._prev_snap: Optional[Tally] = None
        self._prev_t = 0.0
        self._prev_dropped = 0
        self._window_dropped = 0

    def attach(self, tracer) -> "AdaptiveController":
        """Bind to a live tracing session (the tracer calls this at start)."""
        self._tracer = tracer
        rec = getattr(tracer, "tp", None)
        self._advise_record = rec.record.get("ust_repro:advisory") if rec else None
        with self._lock:
            self._prev_snap = None
            self._prev_t = 0.0
            self._prev_dropped = 0
        return self

    def tick(self, engine=None, force: bool = False) -> bool:
        """Run one adaptation window if due; True when policies actually ran.

        The first due tick only baselines (no policy sees a window computed
        against an empty history). Policy exceptions are swallowed per
        policy, so one misbehaving policy cannot stop the others — or the
        consumer thread.

        An unattached controller (e.g. a ``ServeEngine`` built before its
        ``Tracer`` started) attaches itself to the process's active session
        on first tick, so construction order doesn't matter.
        """
        if self._tracer is None:
            from .tracer import active_tracer

            tr = active_tracer()
            if tr is not None:
                self.attach(tr)
        tr = self._tracer
        if tr is None or tr.online is None:
            return False
        with self._lock:
            now = time.monotonic()
            if not force and self._prev_snap is not None and (
                now - self._prev_t < self.period_s
            ):
                return False
            cur = tr.online.snapshot()
            dropped_total = tr.registry.total_dropped if tr.registry is not None else 0
            prev, prev_t = self._prev_snap, self._prev_t
            self._window_dropped = dropped_total - self._prev_dropped
            self._prev_snap, self._prev_t = cur, now
            self._prev_dropped = dropped_total
            if prev is None:
                return False  # baseline window
            self.ticks += 1
            ctx = AdaptiveContext(self, prev, cur, max(1e-9, now - prev_t), engine)
            for pol in self.policies:
                ctx._policy = pol.name
                try:
                    pol.tick(ctx)
                except Exception:
                    pass  # a policy must never kill the consumer thread
            return True

    def _record(self, policy: str, knob: str, value: str, reason: str) -> None:
        act = AdaptiveAction(time.time(), policy, knob, value, reason)
        self.actions.append(act)
        if self._advise_record is not None:
            try:
                self._advise_record(policy, knob, f"{value} ({reason})")
            except Exception:
                pass  # advisory must never break adaptation
        if self.on_action is not None:
            self.on_action(act)

    def render_log(self) -> str:
        """Human-readable action log (one line per action)."""
        return "\n".join(str(a) for a in self.actions)


def build_controller(
    policies: Union["AdaptiveController", Sequence[AdaptivePolicy], None],
    period_s: float = 0.5,
) -> Optional[AdaptiveController]:
    """Normalize ``TraceConfig.adaptive`` / ``ServeEngine(adaptive=…)`` input:
    pass through a ready controller, wrap a policy list, map None to None."""
    if policies is None:
        return None
    if isinstance(policies, AdaptiveController):
        return policies
    return AdaptiveController(list(policies), period_s=period_s)

"""Pretty Print plugin (THAPI §3.4): human-readable event dump.

Renders each event like the paper's §1.1 example — full argument detail,
pointers in hex (``preferred_display_base: 16`` from the trace model),
metadata (timestamp, pid, tid, name):

  12:00:01.123456789 - host - vpid: 71, vtid: 71 - ust_jaxrt:memcpy_entry:
      { src: 0x0000563412, dst: 0xff00abc412, nbytes: 1048576, kind: 0 }
"""

from __future__ import annotations

import io
from typing import Optional, TextIO

from ..babeltrace import CTFSource, Event
from ..clock import ClockInfo


def format_value(param, value) -> str:
    if param.display_base == 16 and isinstance(value, int):
        return f"0x{value:012x}"
    if param.cls == "bytes":
        return "0x" + bytes(value).hex() if value else "b''"
    if param.cls in ("f32", "f64"):
        return f"{value:.6g}"
    return repr(value) if isinstance(value, str) else str(value)


def format_event(ev: Event, clock: Optional[ClockInfo] = None, hostname: str = "host") -> str:
    ts = ev.ts if clock is None else clock.to_realtime(ev.ts)
    s, ns = divmod(ts, 1_000_000_000)
    fields = ", ".join(
        f"{p.name}: {format_value(p, v)}" for p, v in zip(ev.etype.fields, ev.fields)
    )
    return (
        f"{s}.{ns:09d} - {hostname} - vpid: {ev.pid}, vtid: {ev.tid} - "
        f"{ev.name}: {{ {fields} }}"
    )


def pretty_print(
    trace_dir: str,
    out: Optional[TextIO] = None,
    limit: Optional[int] = None,
    name_filter: Optional[str] = None,
) -> int:
    """Dump a trace directory; returns the number of events printed."""
    src = CTFSource(trace_dir)
    host = src.meta.env.get("hostname", "host")
    sink = out or io.StringIO()
    n = 0
    for ev in src:
        if name_filter and name_filter not in ev.name:
            continue
        sink.write(format_event(ev, src.meta.clock, host) + "\n")
        n += 1
        if limit is not None and n >= limit:
            break
    if out is None:
        print(sink.getvalue(), end="")
    return n

"""Tally plugin (THAPI §3.4, §4.3): per-API summary tables.

Produces the paper's table: per API call — total time, share, call count,
average/min/max — grouped under backend headers, plus the hostname/process/
thread counts banner.  Tallies are *mergeable monoids*, which is what makes
the §3.7 aggregation tree (local master → global master) possible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..babeltrace import CTFSource, Interval, IntervalFilter

#: interned (provider, api) key tuples — the row keys of every tally in the
#: process.  Analysis folds, merges, and delta application all funnel their
#: keys through :func:`intern_key`, so a 2000-row tally merged across 1000
#: ranks reuses 2000 tuple objects instead of allocating per row per merge
#: (and identity-equal keys let dict lookups short-circuit on pointer
#: comparison before falling back to string equality).  Capped: a long-lived
#: master fed unbounded key cardinality (e.g. shape-specialized kernel names
#: across many jobs) must not pin every key it ever saw — past the cap, keys
#: are returned uninterned (correctness is unaffected; only sharing stops).
_KEY_INTERN: Dict[Tuple[str, str], Tuple[str, str]] = {}
_KEY_INTERN_MAX = 1 << 16


def intern_key(provider: str, api: str) -> Tuple[str, str]:
    """Canonical shared (provider, api) tuple for tally row keys."""
    key = (provider, api)
    cached = _KEY_INTERN.get(key)
    if cached is not None:
        return cached
    if len(_KEY_INTERN) < _KEY_INTERN_MAX:
        _KEY_INTERN[key] = key
    return key


@dataclasses.dataclass
class ApiStat:
    calls: int = 0
    total_ns: int = 0
    min_ns: int = 2**63 - 1
    max_ns: int = 0

    def add(self, dur_ns: int) -> None:
        self.calls += 1
        self.total_ns += dur_ns
        if dur_ns < self.min_ns:
            self.min_ns = dur_ns
        if dur_ns > self.max_ns:
            self.max_ns = dur_ns

    def merge(self, other: "ApiStat") -> None:
        self.calls += other.calls
        self.total_ns += other.total_ns
        self.min_ns = min(self.min_ns, other.min_ns)
        self.max_ns = max(self.max_ns, other.max_ns)

    @property
    def avg_ns(self) -> float:
        return self.total_ns / self.calls if self.calls else 0.0


@dataclasses.dataclass
class Tally:
    #: (provider, api) → stats
    apis: Dict[Tuple[str, str], ApiStat] = dataclasses.field(default_factory=dict)
    hostnames: Set[str] = dataclasses.field(default_factory=set)
    processes: Set[int] = dataclasses.field(default_factory=set)
    threads: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)
    discarded: int = 0
    #: device-side totals (kernel/transfer spans) kept separately, like the
    #: paper's host vs device timeline rows
    device_apis: Dict[Tuple[str, str], ApiStat] = dataclasses.field(default_factory=dict)
    #: host rows are scaled 1/N-sampling estimates (see :meth:`scale`) — the
    #: renderer marks them, merges propagate the flag
    estimated: bool = False
    #: sampling interval behind the estimates (display only; 1 = exact)
    sample_interval: int = 1

    def scale(self, n: int) -> "Tally":
        """Apply the 1/N systematic-sampling estimator to the host rows.

        Every host ``apis`` row originates from an entry/exit pair, and the
        sampled tier gates exactly those — so scaling calls and total
        durations by N yields the unbiased estimate (uniform random phase ⇒
        each call is selected with probability exactly 1/N).  ``min``/``max``
        are observed extrema of the sample and stay unscaled; device spans
        and counter samples are never gated, so ``device_apis`` stays exact.
        """
        for st in self.apis.values():
            st.calls *= n
            st.total_ns *= n
        self.estimated = True
        self.sample_interval = n
        return self

    def add_interval(self, iv: Interval) -> None:
        table = self.device_apis if iv.device else self.apis
        api = iv.api
        if iv.device and iv.api == "launch":
            # kernel spans tally per kernel name (the paper's per-API rows)
            api = iv.entry.get("name", iv.api)
        key = intern_key(iv.provider, api)
        st = table.get(key)
        if st is None:
            st = table[key] = ApiStat()
        st.add(iv.dur)
        self.processes.add(iv.pid)
        self.threads.add((iv.pid, iv.tid))

    def merge(self, other: "Tally") -> "Tally":
        for key, st in other.apis.items():
            mine = self.apis.get(key)
            if mine is None:
                self.apis[key] = dataclasses.replace(st)
            else:
                mine.merge(st)
        for key, st in other.device_apis.items():
            mine = self.device_apis.get(key)
            if mine is None:
                self.device_apis[key] = dataclasses.replace(st)
            else:
                mine.merge(st)
        self.hostnames |= other.hostnames
        self.processes |= other.processes
        self.threads |= other.threads
        self.discarded += other.discarded
        if other.estimated:
            self.estimated = True
            self.sample_interval = max(self.sample_interval, other.sample_interval)
        return self

    # -- (de)serialization for the aggregation tree --------------------------
    def to_obj(self) -> dict:
        def enc(t):
            return [
                [p, a, s.calls, s.total_ns, s.min_ns, s.max_ns] for (p, a), s in t.items()
            ]

        out = {
            "apis": enc(self.apis),
            "device_apis": enc(self.device_apis),
            "hostnames": sorted(self.hostnames),
            "processes": sorted(self.processes),
            "threads": sorted(list(t) for t in self.threads),
            "discarded": self.discarded,
        }
        if self.estimated:  # omitted when exact: wire compat with old readers
            out["estimated"] = True
            out["sample_interval"] = self.sample_interval
        return out

    @staticmethod
    def from_obj(d: dict) -> "Tally":
        def dec(items):
            return {
                intern_key(p, a): ApiStat(calls=c, total_ns=t, min_ns=mn, max_ns=mx)
                for p, a, c, t, mn, mx in items
            }

        return Tally(
            apis=dec(d["apis"]),
            device_apis=dec(d["device_apis"]),
            hostnames=set(d["hostnames"]),
            processes=set(d["processes"]),
            threads={tuple(t) for t in d["threads"]},
            discarded=int(d["discarded"]),
            estimated=bool(d.get("estimated", False)),
            sample_interval=int(d.get("sample_interval", 1)),
        )

    # -- delta encoding for the streaming protocol (v2) -----------------------
    def delta_to(self, prev: "Tally") -> dict:
        """Encode the change from ``prev`` (an older cumulative state of this
        same tally) as a delta object for the v2 streaming protocol.

        Cumulative tallies only grow: API entries accumulate, sets gain
        members, keys never disappear.  A delta therefore carries the *full
        cumulative value* of every changed or new entry (so applying it is a
        per-key replace, not an add — idempotent for a given seq) plus only
        the newly-seen set members.  ``discarded`` is shipped cumulatively.

        Raises ``ValueError`` if ``prev`` is not a prefix of this tally (an
        API entry or set member present in ``prev`` but missing here) — the
        delta format cannot express removal, and callers must fall back to a
        full snapshot.
        """

        def enc_changed(cur, old, label):
            if old.keys() - cur.keys():
                raise ValueError(f"delta cannot express removed {label} entries")
            out = []
            for key, st in cur.items():
                ps = old.get(key)
                if ps is None or (
                    ps.calls != st.calls
                    or ps.total_ns != st.total_ns
                    or ps.min_ns != st.min_ns
                    or ps.max_ns != st.max_ns
                ):
                    out.append([key[0], key[1], st.calls, st.total_ns, st.min_ns, st.max_ns])
            return out

        for cur_set, old_set, label in (
            (self.hostnames, prev.hostnames, "hostnames"),
            (self.processes, prev.processes, "processes"),
            (self.threads, prev.threads, "threads"),
        ):
            if old_set - cur_set:
                raise ValueError(f"delta cannot express removed {label}")
        out = {
            "apis": enc_changed(self.apis, prev.apis, "apis"),
            "device_apis": enc_changed(self.device_apis, prev.device_apis, "device_apis"),
            "hostnames": sorted(self.hostnames - prev.hostnames),
            "processes": sorted(self.processes - prev.processes),
            "threads": sorted(list(t) for t in self.threads - prev.threads),
            "discarded": self.discarded,
        }
        if self.estimated:
            out["estimated"] = True
            out["sample_interval"] = self.sample_interval
        return out

    def apply_delta(self, d: dict) -> "Tally":
        """Apply a delta produced by :meth:`delta_to` against this tally.

        Listed API entries carry cumulative values, so application replaces
        them key-by-key; set members and the discarded count are merged in.
        Only valid when this tally is exactly the base state the delta was
        computed against (the streaming layer enforces that with seq /
        base_seq numbering). Returns ``self``.
        """
        for p, a, c, t, mn, mx in d["apis"]:
            self.apis[intern_key(p, a)] = ApiStat(
                calls=c, total_ns=t, min_ns=mn, max_ns=mx
            )
        for p, a, c, t, mn, mx in d["device_apis"]:
            self.device_apis[intern_key(p, a)] = ApiStat(
                calls=c, total_ns=t, min_ns=mn, max_ns=mx
            )
        self.hostnames |= set(d["hostnames"])
        self.processes |= set(d["processes"])
        self.threads |= {tuple(t) for t in d["threads"]}
        self.discarded = int(d["discarded"])
        if d.get("estimated"):
            self.estimated = True
            self.sample_interval = max(self.sample_interval, int(d.get("sample_interval", 1)))
        return self


def tally_intervals(intervals: Iterable[Interval], hostname: str = "") -> Tally:
    t = Tally()
    if hostname:
        t.hostnames.add(hostname)
    for iv in intervals:
        t.add_interval(iv)
    return t


def tally_trace(
    trace_dir: str,
    legacy_graph: bool = False,
    jobs: int = 1,
    use_sidecar: bool = True,
) -> Tally:
    """Tally a CTF-lite trace directory.

    Default: the single-pass fold engine (``core/fold.py``) — no Event/
    Interval materialization, no global time-sort, ~an order of magnitude
    faster on large traces.  ``jobs`` shards the fold across worker
    processes (``jobs=None`` = one per CPU; identical result for every job
    count), and ``use_sidecar`` lets validated ``.ctfcol`` columnar
    sidecars short-circuit record parsing entirely.  ``legacy_graph=True``
    is the escape hatch that routes through the full Babeltrace-style graph
    (CTFSource → IntervalFilter → tally_intervals), single-process and
    sidecar-blind; all paths produce identical tallies (property-tested in
    ``tests/test_fold.py`` and ``tests/test_parallel_fold.py``).
    """
    if not legacy_graph:
        from ..fold import fold_trace  # deferred: fold imports this module

        return fold_trace(trace_dir, jobs=jobs, use_sidecar=use_sidecar)
    src = CTFSource(trace_dir)
    filt = IntervalFilter(iter(src))
    t = tally_intervals(filt)
    t.discarded = src.discarded
    host = src.meta.env.get("hostname", "")
    if host:
        t.hostnames.add(host)
    # mirror fold_trace's sampled-session estimator so every analysis path
    # reports the same (scaled) tally for a pure-sampled trace
    fid = src.meta.env.get("fidelity")
    if isinstance(fid, dict) and fid.get("modes_used") == ["sampled"]:
        interval = int(fid.get("interval", 1))
        if interval > 1:
            t.scale(interval)
    return t


# ---------------------------------------------------------------------------
# Rendering (the §4.3 table)
# ---------------------------------------------------------------------------

_UNITS = ((1_000_000_000, "s"), (1_000_000, "ms"), (1_000, "us"), (1, "ns"))


def fmt_ns(ns: float) -> str:
    for div, unit in _UNITS:
        if abs(ns) >= div:
            return f"{ns / div:.2f}{unit}"
    return f"{ns:.0f}ns"


_BACKEND_LABEL = {
    "ust_repro": "BACKEND_REPRO",
    "ust_jaxrt": "BACKEND_JAXRT",
    "ust_kernel": "BACKEND_KERNEL",
    "ust_collective": "BACKEND_COLL",
    "ust_thapi": "BACKEND_THAPI",
    "ust_user": "BACKEND_USER",
}


def _table(header: Tuple[str, ...], body: List[Tuple[str, ...]]) -> List[str]:
    """Aligned rows: first column left-justified, the rest right-justified."""
    widths = [
        max(len(h), *(len(r[i]) for r in body)) if body else len(h)
        for i, h in enumerate(header)
    ]

    def line(cells):
        return " | ".join(
            c.ljust(w) if i == 0 else c.rjust(w)
            for i, (c, w) in enumerate(zip(cells, widths))
        )

    out = [line(header), "-+-".join("-" * w for w in widths)]
    out.extend(line(r) for r in body)
    return out


def render_by_rank(
    ranks: Dict[str, Tally],
    top: Optional[int] = None,
    device: bool = False,
    label: str = "Rank",
    incarnations: Optional[Dict[str, int]] = None,
    retired: Optional[Sequence[str]] = None,
) -> str:
    """Per-rank summary table (`iprof top --by-rank`, §3.7 + §6).

    One row per source (rank identity): busy time, cluster share, calls,
    mean call latency, and the API that dominates the rank's time — the
    view where stragglers and rank skew are visible.  The merged composite
    (:func:`render`) hides exactly this: a rank 3× slower than its peers
    disappears into the cluster-wide sums.  ``label`` renames the first
    column (``iprof top --by-group`` renders rollup groups with it).

    Elastic annotations (from the master's by-rank metadata): a source with
    ``incarnations[src] > 0`` is a replacement and renders as ``src#N`` so
    it never silently merges with its dead predecessor's identity; a source
    in ``retired`` renders a tombstone marker (``[evicted]``) — its totals
    still count (history is history) but the row is visibly not a live rank.
    """
    incs = incarnations or {}
    dead = set(retired or ())
    per_rank = []
    for src, t in ranks.items():
        table = t.device_apis if device else t.apis
        calls = sum(s.calls for s in table.values())
        total = sum(s.total_ns for s in table.values())
        if table:
            (_, top_api), top_st = max(table.items(), key=lambda kv: kv[1].total_ns)
        else:
            top_api, top_st = "-", None
        per_rank.append((src, calls, total, top_api, top_st))
    per_rank.sort(key=lambda r: -r[2])
    cluster_total = sum(r[2] for r in per_rank) or 1
    if top is not None:
        per_rank = per_rank[:top]

    def name(src: str) -> str:
        inc = int(incs.get(src, 0))
        n = f"{src}#{inc}" if inc else src
        return f"{n} [evicted]" if src in dead else n

    body = [
        (
            name(src),
            fmt_ns(total),
            f"{100.0 * total / cluster_total:.2f}%",
            str(calls),
            fmt_ns(total / calls if calls else 0),
            top_api,
            fmt_ns(top_st.avg_ns) if top_st is not None else "-",
        )
        for src, calls, total, top_api, top_st in per_rank
    ]
    header = (label, "Time", "Time(%)", "Calls", "Average", "Top API", "Top API Avg")
    live = len(ranks) - sum(1 for s in ranks if s in dead)
    summary = f"{len(ranks)} {label.lower()}s"
    if len(ranks) != live:
        summary += f" ({live} live, {len(ranks) - live} evicted)"
    out = [summary]
    out.extend(_table(header, body))
    return "\n".join(out)


def render(t: Tally, top: Optional[int] = None, device: bool = False) -> str:
    table = t.device_apis if device else t.apis
    backends = sorted({_BACKEND_LABEL.get(p, p.upper()) for p, _ in table})
    banner = " | ".join(
        [f"{b}" for b in backends]
        + [
            f"{len(t.hostnames) or 1} Hostnames",
            f"{len(t.processes)} Processes",
            f"{len(t.threads)} Threads",
        ]
    )
    #: host rows of a sampled session are scaled estimates — call counts and
    #: times get a "~" prefix, and the banner says what they are
    est = t.estimated and not device
    total = sum(s.total_ns for s in table.values()) or 1
    rows: List[Tuple] = sorted(table.items(), key=lambda kv: -kv[1].total_ns)
    if top is not None:
        rows = rows[:top]
    header = ("Name", "Time", "Time(%)", "Calls", "Average", "Min", "Max")
    tilde = "~" if est else ""
    body = [
        (
            api,
            tilde + fmt_ns(s.total_ns),
            f"{100.0 * s.total_ns / total:.2f}%",
            tilde + str(s.calls),
            fmt_ns(s.avg_ns),
            fmt_ns(s.min_ns if s.calls else 0),
            fmt_ns(s.max_ns),
        )
        for (prov, api), s in rows
    ]
    out = [banner]
    if est:
        out.append(
            f"[estimated] host rows scaled from 1/{t.sample_interval} "
            "systematic sampling (~ marks unbiased estimates)"
        )
    out.extend(_table(header, body))
    if t.discarded:
        out.append(f"[warning] {t.discarded} events discarded (ring-buffer pressure)")
    return "\n".join(out)

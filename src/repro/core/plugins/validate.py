"""Post-mortem validation plugin (THAPI §4.2).

The paper built a validation plugin to catch low-level API misuse that leads
to undefined behavior (uninitialized pNext, unhandled release events,
non-reset command lists).  Our stack's equivalents:

  unmatched_entry     API entered, never exited (crash / dropped exit)
  unmatched_exit      exit without entry (dropped entry under pressure)
  unreleased_alloc    ust_jaxrt:alloc without matching free (≙ unreleased events)
  zero_copy           memcpy with nbytes == 0 (≙ degenerate command)
  self_copy           memcpy src == dst
  nan_loss            train/eval step whose loss OutScalar is NaN (UB analogue)
  nonfinite_gradnorm  gradient norm inf/NaN — diverged step
  time_regression     device span with end < begin
  discarded_events    ring-buffer drops present → coverage warning
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from ..babeltrace import CTFSource, IntervalFilter
from ..metababel import Dispatcher


@dataclasses.dataclass(frozen=True)
class Finding:
    severity: str  # "error" | "warning"
    rule: str
    message: str
    ts: int = 0


def validate_trace(trace_dir: str) -> List[Finding]:
    findings: List[Finding] = []
    src = CTFSource(trace_dir)

    allocs: Dict[int, int] = {}  # ptr → ts
    freed_unknown = 0

    # Metababel-style callback plugin over raw events for the alloc/free and
    # scalar checks; intervals pass below for matching/duration checks.
    d = Dispatcher(src.model)

    def on_alloc_exit(ev):
        allocs[ev.field("ptr")] = ev.ts

    def on_free_entry(ev):
        nonlocal freed_unknown
        if allocs.pop(ev.field("ptr"), None) is None:
            freed_unknown += 1

    def on_memcpy_entry(ev):
        f = ev.asdict()
        if f["nbytes"] == 0:
            findings.append(Finding("warning", "zero_copy", "memcpy with nbytes == 0", ev.ts))
        if f["src"] == f["dst"]:
            findings.append(Finding("warning", "self_copy", "memcpy src == dst", ev.ts))

    def check_loss(ev):
        f = ev.asdict()
        loss = f.get("loss")
        if loss is not None and not math.isfinite(loss):
            findings.append(
                Finding("error", "nan_loss", f"non-finite loss {loss} in {ev.etype.api}", ev.ts)
            )
        gn = f.get("grad_norm")
        if gn is not None and not math.isfinite(gn):
            findings.append(
                Finding("error", "nonfinite_gradnorm", f"non-finite grad_norm {gn}", ev.ts)
            )

    d.on("ust_jaxrt:alloc_exit", on_alloc_exit)
    d.on("ust_jaxrt:free_entry", on_free_entry)
    d.on("ust_jaxrt:memcpy_entry", on_memcpy_entry)
    d.on("ust_repro:train_step_exit", check_loss)
    d.on("ust_repro:eval_step_exit", check_loss)
    d.run(iter(src))

    for ptr, ts in allocs.items():
        findings.append(
            Finding("warning", "unreleased_alloc", f"alloc 0x{ptr:012x} never freed", ts)
        )
    if freed_unknown:
        findings.append(
            Finding("warning", "unknown_free", f"{freed_unknown} frees of untracked pointers")
        )

    # second pass: interval matching + durations (needs a fresh source)
    src2 = CTFSource(trace_dir)
    filt = IntervalFilter(iter(src2))
    for iv in filt:
        if iv.exit is None and not iv.device:
            findings.append(
                Finding(
                    "warning",
                    "unmatched_entry",
                    f"{iv.provider}:{iv.api} entered at {iv.ts} but never exited",
                    iv.ts,
                )
            )
        if iv.device and iv.dur == 0:
            findings.append(
                Finding("warning", "time_regression", f"device span {iv.api} has end <= begin", iv.ts)
            )
    if filt.unmatched_exits:
        findings.append(
            Finding("warning", "unmatched_exit", f"{filt.unmatched_exits} exits without entries")
        )
    if src2.discarded or src.discarded:
        findings.append(
            Finding(
                "warning",
                "discarded_events",
                f"{max(src.discarded, src2.discarded)} events discarded — coverage incomplete",
            )
        )
    return findings


def render(findings: List[Finding]) -> str:
    if not findings:
        return "validation: clean (0 findings)"
    lines = [f"validation: {len(findings)} finding(s)"]
    for f in findings:
        lines.append(f"  [{f.severity}] {f.rule}: {f.message}")
    return "\n".join(lines)

"""Timeline plugin (THAPI §3.6): Perfetto-compatible visualization export.

THAPI converts traces into Perfetto's format and lays the view out as: the
host API row, the device row, then per-GPU telemetry counter rows (power,
frequency, engine utilization — Fig 5).  We emit the Chrome/Perfetto JSON
trace format (opened natively by ui.perfetto.dev):

  row 1  host API calls   (one track per traced thread)
  row 2  device spans     (pseudo-thread per device: kernels, transfers, collectives)
  rows…  counter tracks   (device memory, host RSS, host CPU%, step rate)

Complete events ("ph":"X") carry the full argument payload in ``args`` — the
rich context is preserved all the way into the visualization.
"""

from __future__ import annotations

import json
from typing import Dict, List

from ..babeltrace import CTFSource, IntervalFilter

_DEVICE_TID_BASE = 1 << 20  # pseudo-tids for device rows


def _us(ts_ns: int) -> float:
    return ts_ns / 1000.0


def timeline_events(trace_dir: str) -> List[dict]:
    src = CTFSource(trace_dir)
    filt = IntervalFilter(iter(src))
    host = src.meta.env.get("hostname", "host")
    out: List[dict] = []
    pids_seen: Dict[int, bool] = {}
    for iv in filt:
        pid = iv.pid
        if pid not in pids_seen:
            pids_seen[pid] = True
            out.append(
                {
                    "ph": "M",
                    "name": "process_name",
                    "pid": pid,
                    "args": {"name": f"Hostname {host} Process {pid}"},
                }
            )
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid,
                    "tid": _DEVICE_TID_BASE,
                    "args": {"name": "Device 0"},
                }
            )
        tid = _DEVICE_TID_BASE if iv.device else iv.tid
        args = dict(iv.entry)
        if iv.exit:
            args.update(iv.exit)
        dev_name = args.get("name", iv.api) if iv.device else None
        out.append(
            {
                "ph": "X",
                "name": f"{iv.provider}:{iv.api}" if not iv.device else dev_name,
                "cat": iv.provider,
                "pid": pid,
                "tid": tid,
                "ts": _us(iv.ts),
                "dur": max(_us(iv.dur), 0.001),
                "args": {k: (v if not isinstance(v, bytes) else v.hex()) for k, v in args.items()},
            }
        )
    # counter rows (Fig 5's telemetry rows)
    counters = (
        ("mem_in_use", "Device Memory In Use"),
        ("mem_peak", "Device Memory Peak"),
        ("host_rss", "Host RSS"),
        ("host_cpu_pct", "Host CPU (%)"),
        ("step_rate", "Step Rate (steps/s)"),
    )
    for ev in filt.samples:
        d = ev.asdict()
        for key, label in counters:
            out.append(
                {
                    "ph": "C",
                    "name": label,
                    "pid": ev.pid,
                    "ts": _us(ev.ts),
                    "args": {label: d.get(key, 0)},
                }
            )
    out.sort(key=lambda e: e.get("ts", 0))
    return out


def write_timeline(trace_dir: str, out_path: str) -> int:
    """Write Perfetto-loadable JSON; returns the number of trace events."""
    events = timeline_events(trace_dir)
    with open(out_path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ns"}, f)
    return len(events)


# ---------------------------------------------------------------------------
# Interval queries (zoom/window reads) — columnar fast path
# ---------------------------------------------------------------------------

#: one queried interval: (ts, dur, pid, tid, name, device)
IntervalRow = tuple


def _overlaps(ts: int, dur: int, begin, end) -> bool:
    """Closed-start overlap with [begin, end): zero-duration intervals on the
    window's begin edge are included (flushed unmatched entries stay
    visible when zooming to their timestamp)."""
    if end is not None and ts >= end:
        return False
    if begin is not None and ts + dur < begin:
        return False
    return True


def _graph_intervals(trace_dir: str):
    """Reference/record-parse path: the full Babeltrace-style graph."""
    src = CTFSource(trace_dir)
    for iv in IntervalFilter(iter(src)):
        if iv.device:
            # per-kernel naming mirrors the tally: only launch spans key on
            # the payload name; other spans keep their API name
            name = iv.entry.get("name", iv.api) if iv.api == "launch" else iv.api
        else:
            name = f"{iv.provider}:{iv.api}"
        yield (iv.ts, iv.dur, iv.pid, iv.tid, name, iv.device)


def _sidecar_intervals(trace_dir: str, sidecars):
    """Columnar path: derive intervals from (ts, eid, dur, pair) columns —
    no record parsing, no payload unpacking (names come from the footer
    name table)."""
    from ..ctf import NO_PAIR, TraceMeta
    from ..fold import K_ENTRY, K_EXIT, FoldPlan

    meta = TraceMeta.load(trace_dir)
    plan_rows = FoldPlan(meta.model).rows
    nplans = len(plan_rows)
    events = meta.model.events
    for pid, tid, sc in sidecars:
        ts, en, dur, pair = sc.columns()
        names = sc.footer.get("names", [])
        for i in range(sc.rows):
            e = en[i]
            eid = e & 0xFFFF
            if eid >= nplans:
                continue
            kind = plan_rows[eid][0]
            if kind == K_EXIT:
                j = pair[i]
                if j == NO_PAIR:
                    continue  # unmatched exit: no interval (graph parity)
                ev = events[eid]
                yield (ts[j], dur[i], pid, tid, f"{ev.provider}:{ev.api}", False)
            elif kind == K_ENTRY:
                if pair[i] == NO_PAIR:  # unmatched entry: zero-duration flush
                    ev = events[eid]
                    yield (ts[i], 0, pid, tid, f"{ev.provider}:{ev.api}", False)
            else:  # span kinds — the only other row-producing kinds
                nid = e >> 16
                name = names[nid - 1] if nid else events[eid].api
                yield (ts[i], dur[i], pid, tid, name, True)


def query_intervals(
    trace_dir: str,
    begin=None,
    end=None,
    use_sidecar: bool = True,
) -> List[IntervalRow]:
    """Time-window interval query: ``(ts, dur, pid, tid, name, device)``
    rows overlapping ``[begin, end)``, sorted deterministically.

    When every stream carries a valid columnar sidecar (and no two streams
    share a ``(pid, tid)``), the query walks the packed columns and never
    parses a record; otherwise — any sidecar missing, stale, or of an
    unknown version — it transparently falls back to the record-parse graph
    path.  Both paths return identical rows (``tests/test_columnar.py``).
    """
    from ..ctf import load_sidecar, stream_files
    from ..ctf import StreamReader as _SR

    rows = None
    if use_sidecar:
        sidecars = []
        seen = set()
        for path in stream_files(trace_dir):
            r = _SR(path)
            sc = load_sidecar(path)
            if sc is None or (r.pid, r.tid) in seen:
                sidecars = None  # incomplete coverage: fall back wholesale
                break
            seen.add((r.pid, r.tid))
            sidecars.append((r.pid, r.tid, sc))
        if sidecars is not None:
            rows = _sidecar_intervals(trace_dir, sidecars)
    if rows is None:
        rows = _graph_intervals(trace_dir)
    out = [r for r in rows if _overlaps(r[0], r[1], begin, end)]
    out.sort(key=lambda r: (r[0], r[1], str(r[4]), r[2], r[3]))
    return out

"""Timeline plugin (THAPI §3.6): Perfetto-compatible visualization export.

THAPI converts traces into Perfetto's format and lays the view out as: the
host API row, the device row, then per-GPU telemetry counter rows (power,
frequency, engine utilization — Fig 5).  We emit the Chrome/Perfetto JSON
trace format (opened natively by ui.perfetto.dev):

  row 1  host API calls   (one track per traced thread)
  row 2  device spans     (pseudo-thread per device: kernels, transfers, collectives)
  rows…  counter tracks   (device memory, host RSS, host CPU%, step rate)

Complete events ("ph":"X") carry the full argument payload in ``args`` — the
rich context is preserved all the way into the visualization.
"""

from __future__ import annotations

import json
from typing import Dict, List

from ..babeltrace import CTFSource, IntervalFilter

_DEVICE_TID_BASE = 1 << 20  # pseudo-tids for device rows


def _us(ts_ns: int) -> float:
    return ts_ns / 1000.0


def timeline_events(trace_dir: str) -> List[dict]:
    src = CTFSource(trace_dir)
    filt = IntervalFilter(iter(src))
    host = src.meta.env.get("hostname", "host")
    out: List[dict] = []
    pids_seen: Dict[int, bool] = {}
    for iv in filt:
        pid = iv.pid
        if pid not in pids_seen:
            pids_seen[pid] = True
            out.append(
                {
                    "ph": "M",
                    "name": "process_name",
                    "pid": pid,
                    "args": {"name": f"Hostname {host} Process {pid}"},
                }
            )
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid,
                    "tid": _DEVICE_TID_BASE,
                    "args": {"name": "Device 0"},
                }
            )
        tid = _DEVICE_TID_BASE if iv.device else iv.tid
        args = dict(iv.entry)
        if iv.exit:
            args.update(iv.exit)
        dev_name = args.get("name", iv.api) if iv.device else None
        out.append(
            {
                "ph": "X",
                "name": f"{iv.provider}:{iv.api}" if not iv.device else dev_name,
                "cat": iv.provider,
                "pid": pid,
                "tid": tid,
                "ts": _us(iv.ts),
                "dur": max(_us(iv.dur), 0.001),
                "args": {k: (v if not isinstance(v, bytes) else v.hex()) for k, v in args.items()},
            }
        )
    # counter rows (Fig 5's telemetry rows)
    counters = (
        ("mem_in_use", "Device Memory In Use"),
        ("mem_peak", "Device Memory Peak"),
        ("host_rss", "Host RSS"),
        ("host_cpu_pct", "Host CPU (%)"),
        ("step_rate", "Step Rate (steps/s)"),
    )
    for ev in filt.samples:
        d = ev.asdict()
        for key, label in counters:
            out.append(
                {
                    "ph": "C",
                    "name": label,
                    "pid": ev.pid,
                    "ts": _us(ev.ts),
                    "args": {label: d.get(key, 0)},
                }
            )
    out.sort(key=lambda e: e.get("ts", 0))
    return out


def write_timeline(trace_dir: str, out_path: str) -> int:
    """Write Perfetto-loadable JSON; returns the number of trace events."""
    events = timeline_events(trace_dir)
    with open(out_path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ns"}, f)
    return len(events)

"""Babeltrace2-style analysis plugins generated over the trace model
(THAPI §3.4): Pretty Print, Tally, Timeline, and the post-mortem validation
plugin of §4.2."""

from . import pretty, tally, timeline, validate  # noqa: F401

"""Trace clock (THAPI §3.1).

LTTng timestamps events with a monotonic ns clock and records a realtime
offset so traces from different nodes can be aligned during the muxing phase.
We reproduce that: ``now()`` is the hot-path monotonic ns clock, and
``ClockInfo`` captures the monotonic→realtime offset once per session, stored
in the trace metadata so the Muxer (plugins/intervals/babeltrace) can align
streams from different ranks/hosts.
"""

from __future__ import annotations

import dataclasses
import time

# Hot path: a single C-level call, ~60ns. Bound at module level so generated
# tracepoints reference it directly (no attribute lookup chain).
now = time.monotonic_ns


@dataclasses.dataclass(frozen=True)
class ClockInfo:
    """Monotonic clock description persisted in trace metadata."""

    #: realtime_ns - monotonic_ns at capture; aligns streams across hosts.
    offset_ns: int
    #: monotonic timestamp when the session started (trace-local epoch).
    session_start_ns: int

    @staticmethod
    def capture() -> "ClockInfo":
        m = time.monotonic_ns()
        r = time.time_ns()
        return ClockInfo(offset_ns=r - m, session_start_ns=m)

    def to_realtime(self, ts_monotonic_ns: int) -> int:
        return ts_monotonic_ns + self.offset_ns

    def to_json(self) -> dict:
        return {"offset_ns": self.offset_ns, "session_start_ns": self.session_start_ns}

    @staticmethod
    def from_json(d: dict) -> "ClockInfo":
        return ClockInfo(offset_ns=int(d["offset_ns"]), session_start_ns=int(d["session_start_ns"]))

"""Live streaming multi-rank aggregation (THAPI §3.7 joined with §6).

The offline path (aggregate.py) is a *batch* tree reduction over ``.tally``
files; the online path (online.py) is a *single-process* live tally.  This
module joins them into a streaming service — the network-transported,
always-current version of ``aggregate_tree``:

    rank (OnlineAnalyzer) ──snapshot/delta──▶ local master ──composite──▶ global master
                                                   ▲                          ▲
                                              iprof top                  iprof top

  * Each traced rank periodically pushes its cumulative tally over TCP to a
    master (:class:`SnapshotStreamer`, driven by the tracer's consumer
    thread).  Protocol **v2** ships *delta frames* in steady state: only the
    ApiStats entries that changed since the last delivered state (each with
    its full cumulative value), with periodic full-snapshot resync frames
    bounding drift.  On very wide tallies this is the difference between
    shipping the whole table every interval and shipping a few hot rows.
  * A :class:`MasterServer` keeps the latest cumulative tally per source —
    rebuilt incrementally from deltas — and merges them with the tally
    monoid on demand.  Snapshots are cumulative, so latest-wins merging is
    idempotent and converges to exactly the offline ``combine_aggregates``
    result once every rank has pushed its final state (tracer stop pushes a
    final frame unconditionally).
  * Masters compose into a configurable-fanout tree: a master constructed
    with ``forward_to=`` periodically pushes its state upstream, exactly the
    paper's "each local master sends its aggregate to the global master" —
    but live, while the ranks still run.  Forwarded state is delta-encoded
    too, and (by default) **per rank**: every origin source rides its own
    multiplexed frame chain, so the per-rank breakdown survives each hop of
    the tree instead of collapsing into an anonymous composite.
  * ``iprof serve`` runs a master; ``iprof top`` attaches to any master and
    renders the refreshing composite (``--live`` subscribes for pushed
    updates instead of polling, ``--by-rank`` adds the per-rank table);
    :func:`query_composite` / :func:`query_ranks` /
    :func:`subscribe_composites` are the programmatic clients.  Cluster-
    scope adaptive policies (``core/adaptive.py``) read the per-rank map to
    detect stragglers and rank skew the merged composite hides.

Transport is deliberately tiny: length-prefixed msgpack frames (4-byte
big-endian length + body), one dict message per frame, ``type`` key selects
the handler.  Snapshots are kilobytes (§3.7), so a 64 MiB frame cap is
generous headroom, not a tuning knob.

Delta correctness contract (see docs/streaming.md for the full spec):

  * every frame carries ``seq``; delta frames also carry ``base_seq`` — the
    seq of the state they were computed against.  A master applies a delta
    only when its stored seq for the source equals ``base_seq``; otherwise
    it drops the frame and answers ``resync`` on the same connection, and
    the streamer's next push is a full snapshot.
  * a streamer only sends deltas after the master's ``hello_ack`` proves the
    peer speaks v2 — unknown or v1 masters receive full snapshots forever,
    so the wire stays backward compatible.
  * any reconnect starts with a full snapshot (the delta base is
    connection-local state).

Failure model: the traced application must never block or crash because a
master is slow, absent, or restarting.  The streamer connects lazily,
retries with backoff, and *drops* frames it cannot deliver (counted in
``dropped``) — the next successful full push carries the entire cumulative
state, so nothing is lost but latency.
"""

from __future__ import annotations

import os
import select
import socket
import struct
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import msgpack

from .aggregate import merge_tallies
from .plugins.tally import ApiStat, Tally, intern_key

#: v2 adds delta frames, ``hello_ack`` and ``resync`` control frames, and
#: ``subscribe`` push mode. v1 peers are still understood (full snapshots).
PROTOCOL_VERSION = 2
#: oldest peer version that accepts ``delta`` frames
DELTA_MIN_VERSION = 2
MAX_FRAME = 64 << 20  # frames are tally snapshots: KBs in practice (§3.7)
_HDR = struct.Struct("!I")


class ProtocolError(RuntimeError):
    """Malformed or truncated frame on a stream connection."""


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def pack_frame(msg: dict) -> bytes:
    """One message → one length-prefixed msgpack frame."""
    body = msgpack.packb(msg, use_bin_type=True)
    if len(body) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds cap {MAX_FRAME}")
    return _HDR.pack(len(body)) + body


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[dict]:
    """Read one frame; None on clean EOF, ProtocolError on a torn frame."""
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_FRAME:
        raise ProtocolError(f"peer announced {n}-byte frame (cap {MAX_FRAME})")
    body = _recv_exact(sock, n)
    if body is None:
        raise ProtocolError("connection closed mid-frame")
    return msgpack.unpackb(body, raw=False)


def parse_addr(addr: Union[str, Tuple[str, int]]) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``(host, port)`` → ``(host, port)``."""
    if isinstance(addr, tuple):
        return addr[0], int(addr[1])
    host, _, port = addr.rpartition(":")
    return (host or "127.0.0.1"), int(port)


def default_source(rank: int = 0) -> str:
    """Canonical source id for a traced rank: ``host:pid:rankN``."""
    return f"{socket.gethostname()}:{os.getpid()}:rank{rank}"


# ---------------------------------------------------------------------------
# Rank side: snapshot push client
# ---------------------------------------------------------------------------


class _SourceState:
    """Per-source seq/delta bookkeeping on the *current* connection."""

    __slots__ = ("seq", "last_sent", "sends_since_full", "force_full")

    def __init__(self):
        self.seq = 0
        self.last_sent: Optional[Tally] = None
        self.sends_since_full = 0
        self.force_full = False


class SnapshotStreamer:
    """Pushes cumulative tally state to a master; never blocks tracing.

    Push cadence belongs to the caller (the tracer's consumer thread, a
    master's forwarder loop); ``push(tally)`` always sends — the tracer's
    stop path relies on that for the final, authoritative state.

    One streamer, one connection, **many sources**: every frame names its
    ``source``, so a local master can forward its whole per-rank breakdown
    over a single upstream connection (``push(tally, source=rank_id)`` per
    rank) — each source keeps an independent seq chain and delta base.
    Plain leaf ranks never pass ``source`` and behave exactly as before.

    With ``delta=True`` (the default) the streamer tracks the last state
    delivered per source on the current connection and ships only changed
    entries once the master's ``hello_ack`` confirms a v2 peer.  Every
    ``resync_every``-th push — and the first push of every connection — is a
    full snapshot, so a master can always rebuild from the wire alone.
    Counters: ``pushed`` / ``dropped`` / ``skipped`` (frames),
    ``full_frames`` / ``delta_frames`` (mix), ``bytes_sent`` (on-wire
    payload), ``resyncs`` (master-requested fallbacks to full).
    """

    def __init__(
        self,
        addr: Union[str, Tuple[str, int]],
        source: str,
        retry_s: float = 0.5,
        timeout_s: float = 2.0,
        delta: bool = True,
        resync_every: int = 32,
    ):
        self.addr = parse_addr(addr)
        self.source = source
        self.retry_s = retry_s
        self.timeout_s = timeout_s
        self.delta = delta
        self.resync_every = max(1, int(resync_every))
        self.pushed = 0
        self.dropped = 0
        self.skipped = 0
        self.full_frames = 0
        self.delta_frames = 0
        self.bytes_sent = 0
        self.resyncs = 0
        self._sock: Optional[socket.socket] = None
        self._next_retry = 0.0
        self._lock = threading.Lock()
        #: per-source state on the *current* connection (reset on reconnect)
        self._src: Dict[str, _SourceState] = {}
        self._peer_version: Optional[int] = None  # learned from hello_ack

    @property
    def peer_version(self) -> Optional[int]:
        """Master's protocol version once its ``hello_ack`` arrived, else None."""
        return self._peer_version

    def poll_control(self) -> None:
        """Drain pending control frames (``hello_ack`` / ``resync``) now.

        ``push`` does this automatically before every send; callers that
        want deterministic delta engagement (benchmarks, tests) may call it
        after the first push instead of waiting for the next cadence tick.
        """
        with self._lock:
            if self._sock is not None:
                self._drain_control(self._sock)

    def push(
        self,
        tally: Union[Tally, dict],
        source: Optional[str] = None,
        skip_unchanged: bool = False,
    ) -> bool:
        """Deliver the current cumulative ``tally``; returns delivery success.

        Chooses delta vs full per the protocol contract, never blocks beyond
        ``timeout_s``, and on any failure drops the connection (the next
        successful push is a full snapshot again).  ``source`` defaults to
        this streamer's own identity; forwarders pass each origin rank's id
        to carry the per-rank breakdown upstream.  With ``skip_unchanged``
        a delta-eligible push whose state did not change since the last
        delivery is elided (counted in ``skipped``) — used by per-rank
        forwarding so idle ranks cost no wire traffic.
        """
        cur = tally if isinstance(tally, Tally) else Tally.from_obj(tally)
        src = source if source is not None else self.source
        with self._lock:
            sock = self._ensure_conn()
            if sock is None:
                self.dropped += 1
                return False
            if not self._drain_control(sock):
                self.dropped += 1
                return False
            st = self._src.setdefault(src, _SourceState())
            msg = self._encode(st, src, cur, skip_unchanged)
            if msg is None:  # delta-eligible and nothing changed: elide
                self.skipped += 1
                return True
            frame = pack_frame(msg)
            try:
                sock.sendall(frame)
            except OSError:
                self._drop_conn()
                self.dropped += 1
                return False
            st.seq += 1
            self.pushed += 1
            self.bytes_sent += len(frame)
            # keep a private copy: the caller may keep mutating its tally
            st.last_sent = Tally().merge(cur)
            if msg["type"] == "delta":
                self.delta_frames += 1
                st.sends_since_full += 1
            else:
                self.full_frames += 1
                st.sends_since_full = 0
                st.force_full = False
            return True

    def _encode(
        self, st: _SourceState, source: str, cur: Tally, skip_unchanged: bool = False
    ) -> Optional[dict]:
        """Build the frame for ``cur``: delta when the contract allows it.

        Returns None when ``skip_unchanged`` is set and a delta-eligible
        state shows no change since the last delivery.
        """
        use_delta = (
            self.delta
            and st.last_sent is not None
            and not st.force_full
            and self._peer_version is not None
            and self._peer_version >= DELTA_MIN_VERSION
            and st.sends_since_full < self.resync_every
        )
        if use_delta:
            try:
                d = cur.delta_to(st.last_sent)
            except ValueError:
                use_delta = False  # non-monotone state: full resync
        if use_delta:
            if skip_unchanged and not (
                d["apis"]
                or d["device_apis"]
                or d["hostnames"]
                or d["processes"]
                or d["threads"]
                or d["discarded"] != st.last_sent.discarded
            ):
                return None
            return {
                "type": "delta",
                "v": PROTOCOL_VERSION,
                "source": source,
                "seq": st.seq,
                "base_seq": st.seq - 1,
                "ts": time.time(),
                "delta": d,
            }
        return {
            "type": "snapshot",
            "v": PROTOCOL_VERSION,
            "source": source,
            "seq": st.seq,
            "ts": time.time(),
            "tally": cur.to_obj(),
        }

    def _drain_control(self, sock: socket.socket) -> bool:
        """Consume buffered master→streamer frames; False if the conn died."""
        while True:
            try:
                r, _, _ = select.select([sock], [], [], 0)
            except (OSError, ValueError):
                self._drop_conn()
                return False
            if not r:
                return True
            try:
                msg = recv_frame(sock)
            except (ProtocolError, OSError):
                self._drop_conn()
                return False
            if msg is None:  # EOF: master went away
                self._drop_conn()
                return False
            kind = msg.get("type")
            if kind == "hello_ack":
                self._peer_version = int(msg.get("v", 1))
            elif kind == "resync":
                # scoped to one source when the master names it; a v2.0
                # master (no source field) resyncs every chain
                src = msg.get("source")
                if src is None:
                    for st in self._src.values():
                        st.force_full = True
                else:
                    self._src.setdefault(str(src), _SourceState()).force_full = True
                self.resyncs += 1
            # anything else from the master is ignorable here

    def _ensure_conn(self) -> Optional[socket.socket]:
        if self._sock is not None:
            return self._sock
        if time.monotonic() < self._next_retry:
            return None
        try:
            s = socket.create_connection(self.addr, timeout=self.timeout_s)
            s.settimeout(self.timeout_s)
            s.sendall(
                pack_frame(
                    {"type": "hello", "v": PROTOCOL_VERSION, "source": self.source}
                )
            )
        except OSError:
            self._next_retry = time.monotonic() + self.retry_s
            return None
        self._sock = s
        # connection-local delta state starts fresh: first push is full
        self._src = {}
        self._peer_version = None
        return s

    def _drop_conn(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        self._src = {}
        self._peer_version = None

    def close(self) -> None:
        """Send ``bye`` (best-effort) and drop the connection."""
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.sendall(pack_frame({"type": "bye", "source": self.source}))
                except OSError:
                    pass
                self._drop_conn()


# ---------------------------------------------------------------------------
# Incremental composite maintenance (the read-path scaling layer)
# ---------------------------------------------------------------------------
#
# Cumulative tallies only grow, so a source's old→new change can be *applied*
# to a running accumulator row-by-row instead of re-merging every source per
# read: calls/total add their difference (subtraction is exact on the
# additive fields), min/max clamp (monotone growth guarantees new.min ≤
# old.min and new.max ≥ old.max, so the clamp can never miss a tighter bound
# held by the replaced state).  A change that is NOT monotone growth — a
# restarted rank, a reset counter, a shrunk table — cannot be applied
# incrementally; the helpers detect it (before touching the accumulator) and
# the caller falls back to a full rebuild on the next read.


def _acc_row(table: Dict[Tuple[str, str], ApiStat], key, st: ApiStat) -> None:
    row = table.get(key)
    if row is None:
        table[key] = ApiStat(
            calls=st.calls, total_ns=st.total_ns, min_ns=st.min_ns, max_ns=st.max_ns
        )
    else:
        row.merge(st)


def _tally_update_ops(acc: Tally, old: Optional[Tally], new: Tally) -> Optional[int]:
    """Fold one source's old→new cumulative change into accumulator ``acc``.

    Returns the number of row-ops applied — O(changed rows), the invariant
    the composite cache is built on — or None (``acc`` untouched) when the
    change is not monotone growth and the accumulator must be rebuilt.
    Validation runs fully before the first mutation, so a None return never
    leaves ``acc`` half-updated.
    """
    ops = 0
    if old is None:
        for key, st in new.apis.items():
            _acc_row(acc.apis, key, st)
            ops += 1
        for key, st in new.device_apis.items():
            _acc_row(acc.device_apis, key, st)
            ops += 1
        acc.hostnames |= new.hostnames
        acc.processes |= new.processes
        acc.threads |= new.threads
        acc.discarded += new.discarded
        return ops
    if new.discarded < old.discarded:
        return None
    if (
        old.hostnames - new.hostnames
        or old.processes - new.processes
        or old.threads - new.threads
    ):
        return None
    changed = []
    for acc_t, old_t, new_t in (
        (acc.apis, old.apis, new.apis),
        (acc.device_apis, old.device_apis, new.device_apis),
    ):
        if len(old_t) > len(new_t) or old_t.keys() - new_t.keys():
            return None
        for key, st in new_t.items():
            ost = old_t.get(key)
            if ost is None:
                changed.append((acc_t, key, None, st))
            elif (
                st.calls != ost.calls
                or st.total_ns != ost.total_ns
                or st.min_ns != ost.min_ns
                or st.max_ns != ost.max_ns
            ):
                if (
                    st.calls < ost.calls
                    or st.total_ns < ost.total_ns
                    or st.min_ns > ost.min_ns
                    or st.max_ns < ost.max_ns
                    or key not in acc_t
                ):
                    return None
                changed.append((acc_t, key, ost, st))
    for acc_t, key, ost, st in changed:
        if ost is None:
            _acc_row(acc_t, key, st)
        else:
            row = acc_t[key]
            row.calls += st.calls - ost.calls
            row.total_ns += st.total_ns - ost.total_ns
            if st.min_ns < row.min_ns:
                row.min_ns = st.min_ns
            if st.max_ns > row.max_ns:
                row.max_ns = st.max_ns
    acc.hostnames |= new.hostnames
    acc.processes |= new.processes
    acc.threads |= new.threads
    acc.discarded += new.discarded - old.discarded
    return len(changed)


def _delta_update_ops(acc: Tally, prev: Tally, delta: dict) -> Optional[int]:
    """Apply a v2 delta frame's change to accumulator ``acc``.

    The delta already names exactly the changed rows (with full cumulative
    values), so this is O(changed) with no table scan at all — the steady-
    state ingest path.  ``prev`` is the source's stored tally *before*
    ``apply_delta`` runs.  Same None-means-rebuild contract as
    :func:`_tally_update_ops`: validation — including structural validation
    of a possibly version-skewed frame — completes before the first
    mutation, so None never leaves ``acc`` half-updated.
    """
    changed = []
    try:
        for acc_t, prev_t, rows in (
            (acc.apis, prev.apis, delta["apis"]),
            (acc.device_apis, prev.device_apis, delta["device_apis"]),
        ):
            for p, a, c, t, mn, mx in rows:
                key = intern_key(p, a)
                ost = prev_t.get(key)
                if ost is not None and (
                    c < ost.calls
                    or t < ost.total_ns
                    or mn > ost.min_ns
                    or mx < ost.max_ns
                    or key not in acc_t
                ):
                    return None
                changed.append((acc_t, key, ost, c, t, mn, mx))
        hostnames = set(delta["hostnames"])
        processes = set(delta["processes"])
        threads = {tuple(x) for x in delta["threads"]}
        nd = int(delta["discarded"])
    except (KeyError, TypeError, ValueError):
        return None  # malformed frame: rebuild rather than trust it
    if nd < prev.discarded:
        return None
    for acc_t, key, ost, c, t, mn, mx in changed:
        if ost is None:
            row = acc_t.get(key)
            if row is None:
                acc_t[key] = ApiStat(calls=c, total_ns=t, min_ns=mn, max_ns=mx)
            else:
                row.calls += c
                row.total_ns += t
                if mn < row.min_ns:
                    row.min_ns = mn
                if mx > row.max_ns:
                    row.max_ns = mx
        else:
            row = acc_t[key]
            row.calls += c - ost.calls
            row.total_ns += t - ost.total_ns
            if mn < row.min_ns:
                row.min_ns = mn
            if mx > row.max_ns:
                row.max_ns = mx
    acc.hostnames |= hostnames
    acc.processes |= processes
    acc.threads |= threads
    acc.discarded += nd - prev.discarded
    return len(changed)


# ---------------------------------------------------------------------------
# Master daemon (local or global, depending on forward_to)
# ---------------------------------------------------------------------------


class _SourceEntry:
    """One source's stored state: connection generation, seq, tally, receipt
    time.  ``gen`` scopes the seq chain to the connection that produced it —
    a reconnecting sender restarts seq at 0 on a new gen, and its full
    snapshot must not be dropped as stale against the old chain.
    ``version`` stamps every state update; ``snap`` caches a frozen copy of
    the tally at ``snap_version`` so per-rank reads refresh only the sources
    that changed since the last read (O(changed), not O(ranks × rows))."""

    __slots__ = ("gen", "seq", "tally", "ts", "version", "snap", "snap_version")

    def __init__(self, gen: Optional[int], seq: int, tally: Tally, ts: float):
        self.gen = gen
        self.seq = seq
        self.tally = tally
        self.ts = ts
        self.version = 0
        self.snap: Optional[Tally] = None
        self.snap_version = -1


class MasterServer:
    """Streaming master: latest-state-per-source store + monoid merge.

    * leaf ranks (or child masters) connect and push ``snapshot`` / ``delta``
      frames; deltas are merged into the stored cumulative state
      incrementally (a per-key replace — applying frame *k* to state *k-1*
      reproduces the sender's cumulative tally exactly);
    * a delta whose ``base_seq`` doesn't match the stored state is dropped
      and answered with ``resync`` so the sender falls back to a full
      snapshot — the composite is never built from a mis-based delta;
    * any client may send ``query`` and gets the current composite back,
      ``query_ranks`` for the per-source breakdown, or ``subscribe``
      (optionally ``by_rank``) to have composites pushed periodically;
    * with ``forward_to=`` set this is a *local* master: a forwarder thread
      periodically pushes state upstream (delta-encoded like any other
      stream), making the whole arrangement the live fanout tree of §3.7.
      With ``forward_ranks`` (the default) it forwards each origin source's
      tally on its own multiplexed frame chain, so the per-rank breakdown —
      the signal cluster-scope policies need — survives every hop of the
      tree; with ``forward_ranks=False`` it collapses to one composite
      source upstream (the v2.0 behavior: cheaper at the root, anonymous).
    """

    def __init__(
        self,
        port: int = 0,
        host: str = "127.0.0.1",
        forward_to: Optional[Union[str, Tuple[str, int]]] = None,
        forward_period_s: float = 0.5,
        fanout: int = 32,
        source: Optional[str] = None,
        forward_delta: bool = True,
        forward_resync_every: int = 32,
        forward_ranks: bool = True,
        rollup_groups: Union[None, str, int, "Callable[[str], str]"] = None,
        composite_cache: bool = True,
    ):
        self.host = host
        self.port = port  # rebound to the real port at start()
        self.fanout = fanout
        self.forward_to = forward_to
        self.forward_period_s = forward_period_s
        self.forward_delta = forward_delta
        self.forward_resync_every = forward_resync_every
        self.forward_ranks = forward_ranks
        #: node-level pre-aggregation (>1k-rank trees): group sources into
        #: rollup tallies maintained incrementally on ingest.  ``"host"``
        #: groups by the host part of ``host:pid:rankN`` source ids; an int N
        #: buckets rank indices N-at-a-time (``group0`` = ranks 0..N-1); a
        #: callable maps source id → group id.  None disables rollups.
        self.rollup_groups = rollup_groups
        #: maintain the composite incrementally on ingest (O(changed) per
        #: read).  False restores the rebuild-per-read behavior — the
        #: benchmark baseline and an escape hatch, not a recommended mode.
        self.composite_cache = composite_cache
        self.source = source or f"master:{socket.gethostname()}:{os.getpid()}"
        #: source → stored state (gen, seq, cumulative tally, receipt time)
        self._latest: Dict[str, _SourceEntry] = {}
        #: sources updated since the last successful flush — per-rank
        #: forwarding copies and delta-encodes only these, so an idle rank
        #: costs nothing per forward period, not O(tally width)
        self._dirty_srcs: set = set()
        self._conn_gen = 0  # connection-generation counter (gen scope)
        self._lock = threading.Lock()
        self._dirty = False
        self._version = 0  # bumped per state update; gates subscription pushes
        #: incrementally-maintained composite + rebuild flag (generation-
        #: stamped by ``_version``; see ``_composite_locked``)
        self._comp: Optional[Tally] = None
        self._comp_dirty = True
        #: rollup state: group id → running tally, members, rebuild flags
        self._group_tallies: Dict[str, Tally] = {}
        self._group_members: Dict[str, set] = {}
        self._group_dirty: set = set()
        self._src_group: Dict[str, str] = {}
        self.frames = 0
        self.snapshots = 0  # state updates ingested (full + delta)
        self.full_snapshots = 0
        self.deltas = 0
        self.resyncs_sent = 0
        self.queries = 0
        self.comp_row_ops = 0  # ApiStat row merges spent maintaining/rebuilding
        self.comp_rebuilds = 0  # full composite rebuilds (non-monotone fallback)
        self.comp_incremental = 0  # ingests applied incrementally
        self._lsock: Optional[socket.socket] = None
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._forwarder: Optional[SnapshotStreamer] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "MasterServer":
        """Bind, start the acceptor (and forwarder, for local masters)."""
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self.host, self.port))
        ls.listen(128)
        self._lsock = ls
        self.port = ls.getsockname()[1]
        self._stop_evt.clear()
        acceptor = threading.Thread(
            target=self._accept_loop, name="thapi-master-accept", daemon=True
        )
        acceptor.start()
        self._threads.append(acceptor)
        if self.forward_to is not None:
            self._forwarder = SnapshotStreamer(
                self.forward_to,
                source=self.source,
                delta=self.forward_delta,
                resync_every=self.forward_resync_every,
            )
            fwd = threading.Thread(
                target=self._forward_loop, name="thapi-master-forward", daemon=True
            )
            fwd.start()
            self._threads.append(fwd)
        return self

    def stop(self) -> None:
        """Flush upstream (local masters), close every connection, join threads."""
        self._stop_evt.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
            self._lsock = None
        if self._forwarder is not None:
            self.flush(force=True)  # last composite must reach the parent
            self._forwarder.close()
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = list(self._threads), []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        for t in threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "MasterServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def addr(self) -> str:
        """``host:port`` once started (``port=0`` is rebound at start)."""
        return f"{self.host}:{self.port}"

    @property
    def forwarder(self) -> Optional[SnapshotStreamer]:
        """The upstream push client (local masters only), for its counters."""
        return self._forwarder

    # -- state ---------------------------------------------------------------
    def submit(
        self,
        source: str,
        tally: Union[Tally, dict],
        seq: Optional[int] = None,
        gen: Optional[int] = None,
    ) -> None:
        """Ingest a full cumulative snapshot (socket handlers and the
        in-process tracer both land here). Out-of-order frames
        (seq < stored, same connection generation) are stale duplicates of
        state we already supersede — dropped.  A frame from a *different*
        generation (reconnect, new session) always replaces: its snapshot is
        cumulative truth and its seq chain starts over.

        The master takes ownership of ``tally`` — callers must not mutate it
        afterwards (the incremental composite diffs stored states)."""
        if not isinstance(tally, Tally):
            tally = Tally.from_obj(tally)
        with self._lock:
            prev = self._latest.get(source)
            if prev is not None and seq is not None and gen == prev.gen and seq < prev.seq:
                return
            nseq = seq if seq is not None else (prev.seq + 1 if prev is not None else 0)
            old = prev.tally if prev is not None else None
            self._latest[source] = _SourceEntry(gen, nseq, tally, time.time())
            self.snapshots += 1
            self.full_snapshots += 1
            self._dirty = True
            self._dirty_srcs.add(source)
            self._version += 1
            self._caches_note_update_locked(source, old, tally, None)

    def submit_delta(
        self,
        source: str,
        delta: dict,
        seq: int,
        base_seq: int,
        gen: Optional[int] = None,
    ) -> bool:
        """Ingest a delta frame; True if applied.

        Applies only when the stored state for ``source`` is exactly
        ``base_seq`` on the same connection generation — anything else
        (unknown source after a master restart, a duplicate, an out-of-order
        frame, a reset seq, a different connection's chain) is rejected so
        the stored cumulative state is never corrupted; the socket handler
        then answers ``resync``.
        """
        with self._lock:
            prev = self._latest.get(source)
            if prev is None or prev.gen != gen or prev.seq != base_seq:
                return False
            # caches diff against the pre-apply state, so feed them first —
            # a delta names exactly the changed rows, the O(changed) path
            self._caches_note_update_locked(source, prev.tally, None, delta)
            prev.tally.apply_delta(delta)
            prev.seq = seq
            prev.ts = time.time()
            prev.version += 1
            prev.snap = None  # stale frozen copy: re-snapped on next read
            self.snapshots += 1
            self.deltas += 1
            self._dirty = True
            self._dirty_srcs.add(source)
            self._version += 1
            return True

    def _reset_seq(self, source: str) -> None:
        with self._lock:
            prev = self._latest.get(source)
            if prev is not None:
                # keep the last tally but accept any future seq from it
                prev.seq = -1

    # -- cache maintenance (all called under self._lock) ---------------------
    def _caches_note_update_locked(
        self,
        source: str,
        old: Optional[Tally],
        new: Optional[Tally],
        delta: Optional[dict],
    ) -> None:
        """Fold one source update into the composite and rollup caches.

        Exactly one of ``new`` (full snapshot replacing ``old``) or ``delta``
        (v2 delta about to be applied to ``old``) is set.  Monotone growth is
        applied incrementally — O(changed rows); anything else flips the
        affected cache to dirty and the next read rebuilds.
        """
        if self.composite_cache and not self._comp_dirty and self._comp is not None:
            ops = self._apply_to_acc(self._comp, old, new, delta)
            if ops is None:
                self._comp_dirty = True
            else:
                self.comp_row_ops += ops
                self.comp_incremental += 1
        else:
            self._comp_dirty = True
        if self.rollup_groups is not None:
            g = self._group_of_locked(source)
            self._group_members.setdefault(g, set()).add(source)
            gt = self._group_tallies.get(g)
            if g in self._group_dirty:
                return
            if gt is None:
                # first update for this group: seed from the change itself
                # (old is None on a brand-new source; otherwise seed dirty)
                if old is None and new is not None:
                    seeded = Tally()
                    _tally_update_ops(seeded, None, new)
                    self._group_tallies[g] = seeded
                else:
                    self._group_dirty.add(g)
                return
            if self._apply_to_acc(gt, old, new, delta) is None:
                self._group_dirty.add(g)

    @staticmethod
    def _apply_to_acc(
        acc: Tally, old: Optional[Tally], new: Optional[Tally], delta: Optional[dict]
    ) -> Optional[int]:
        if delta is not None:
            assert old is not None
            return _delta_update_ops(acc, old, delta)
        assert new is not None
        return _tally_update_ops(acc, old, new)

    def _comp_copies_locked(self) -> Tuple[List[Tally], int]:
        """Rebuild input: per-source copies + the row-op count, one lock hold."""
        ops = sum(
            len(e.tally.apis) + len(e.tally.device_apis)
            for e in self._latest.values()
        )
        return [Tally().merge(e.tally) for e in self._latest.values()], ops

    def _finish_rebuild(self, copies: List[Tally], ops: int, version: int) -> Tally:
        """Merge a rebuild's source copies *outside* the lock (ingest never
        stalls behind an O(ranks × rows) merge), then store the result as the
        cache only if no ingest landed mid-rebuild (``version`` unchanged —
        a stale store would silently drop those updates).  Rebuilds go
        through the same ``fanout``-ary tree merge as the offline
        ``aggregate_tree`` (merge math is associative, so fanout shapes the
        work, never the result).  Returns a tally the caller owns."""
        if copies:
            comp, _ = merge_tallies(copies, fanout=self.fanout)
        else:
            comp = Tally()
        with self._lock:
            self.comp_rebuilds += 1
            self.comp_row_ops += ops
            if self.composite_cache and self._version == version:
                self._comp = comp
                self._comp_dirty = False
                return Tally().merge(comp)
        # cache disabled, or state moved mid-rebuild (comp is still a
        # consistent read of the snapshot we copied): hand it out uncached
        return comp

    def _ranks_snapshot_locked(self) -> Dict[str, Tally]:
        """Frozen per-source copies, refreshed only for sources whose state
        changed since the last read (version-stamped).  The returned tallies
        are shared snapshots: replaced wholesale on change, never mutated in
        place — safe to serialize or merge outside the lock, never to edit."""
        out = {}
        for src, e in self._latest.items():
            if e.snap is None or e.snap_version != e.version:
                e.snap = Tally().merge(e.tally)
                e.snap_version = e.version
            out[src] = e.snap
        return out

    def _group_of_locked(self, source: str) -> str:
        g = self._src_group.get(source)
        if g is None:
            rg = self.rollup_groups
            if callable(rg):
                g = str(rg(source))
            elif isinstance(rg, int) and not isinstance(rg, bool):
                # host:pid:rankN → bucket rank indices rg-at-a-time
                tail = source.rpartition("rank")[2]
                if tail.isdigit():
                    g = f"group{int(tail) // max(1, rg)}"
                else:
                    g = source.partition(":")[0] or source
            else:  # "host" (the default string form)
                g = source.partition(":")[0] or source
            self._src_group[source] = g
        return g

    def _rebuild_group_locked(self, g: str) -> None:
        t = Tally()
        for src in self._group_members.get(g, ()):
            e = self._latest.get(src)
            if e is not None:
                t.merge(e.tally)
        self._group_tallies[g] = t
        self._group_dirty.discard(g)

    def _groups_locked(self) -> Dict[str, Tally]:
        for g in list(self._group_dirty):
            self._rebuild_group_locked(g)
        return self._group_tallies

    # -- reads ---------------------------------------------------------------
    def composite(self) -> Tally:
        """The merged cluster profile, O(changed) in steady state.

        Maintained incrementally on ingest (full snapshots diff against the
        replaced state, deltas apply their changed rows directly), so a read
        copies the cached composite — O(distinct API rows) — instead of
        re-merging every source's whole table (O(ranks × rows), the
        pre-cache behavior, still reachable via ``composite_cache=False``).
        The returned tally is the caller's to mutate."""
        with self._lock:
            if self.composite_cache and self._comp is not None and not self._comp_dirty:
                return Tally().merge(self._comp)
            version = self._version
            copies, ops = self._comp_copies_locked()
        return self._finish_rebuild(copies, ops, version)

    def ranks(self, copy: bool = True) -> Dict[str, Tally]:
        """Per-source breakdown: source id → its latest cumulative tally.
        The data ``query_ranks`` serves and cluster-scope policies consume;
        merging all values reproduces :meth:`composite`.

        ``copy=True`` (default) returns defensive copies the caller owns.
        ``copy=False`` returns the version-stamped frozen snapshots — only
        sources that changed since the last read are re-copied (O(changed)),
        but callers must treat the tallies as read-only."""
        with self._lock:
            snap = self._ranks_snapshot_locked()
            if copy:
                return {src: Tally().merge(t) for src, t in snap.items()}
            return dict(snap)

    def groups(self) -> Dict[str, Tally]:
        """Rollup breakdown: group id → aggregated member tally (empty when
        ``rollup_groups`` is off).  Group tallies are maintained
        incrementally on ingest — the pre-aggregation layer that keeps
        >1k-rank trees readable: policies and upstream forwarding touch
        O(groups) tallies instead of O(ranks).  Returns defensive copies
        (group accumulators mutate in place on ingest, so — unlike the
        per-source snapshots — they can never be handed out uncopied)."""
        if self.rollup_groups is None:
            return {}
        with self._lock:
            return {g: Tally().merge(t) for g, t in self._groups_locked().items()}

    def stats(self) -> dict:
        """Counters for monitoring: sources, frame/snapshot/delta/query
        totals, resyncs sent, composite-cache row-ops/rebuilds, rollup
        group count, last-update wall clock, forwarding role."""
        with self._lock:
            sources = len(self._latest)
            updated = max((e.ts for e in self._latest.values()), default=0.0)
            groups = len(self._group_members) if self.rollup_groups is not None else 0
        return {
            "sources": sources,
            "frames": self.frames,
            "snapshots": self.snapshots,
            "full_snapshots": self.full_snapshots,
            "deltas": self.deltas,
            "resyncs": self.resyncs_sent,
            "queries": self.queries,
            "comp_row_ops": self.comp_row_ops,
            "comp_rebuilds": self.comp_rebuilds,
            "comp_incremental": self.comp_incremental,
            "groups": groups,
            "updated": updated,
            "forwarding": self.forward_to is not None,
        }

    def flush(self, force: bool = False) -> bool:
        """Push state upstream now (local masters only): rollup-group
        tallies when ``rollup_groups`` is set (the pre-aggregated form —
        O(groups) upstream sources instead of O(ranks)), else the per-rank
        breakdown when ``forward_ranks``, else the merged composite."""
        if self._forwarder is None:
            return False
        with self._lock:
            if not self._latest or (not self._dirty and not force):
                return False
            self._dirty = False
        if self.rollup_groups is not None and self.forward_ranks:
            with self._lock:
                gro = self._groups_locked()
                if force:
                    gs = list(gro)
                else:
                    gs = sorted(
                        {self._group_of_locked(src) for src in self._dirty_srcs}
                    )
                self._dirty_srcs.clear()
                # group accumulators mutate in place on ingest: copy under
                # the lock, push outside it
                copies = {g: Tally().merge(gro[g]) for g in gs if g in gro}
            ok = True
            for g, tally in copies.items():
                ok = self._forwarder.push(
                    tally, source=g, skip_unchanged=not force
                ) and ok
            if not ok:
                with self._lock:
                    # parent unreachable: re-arm the failed groups' members
                    # so their state is re-forwarded when the parent returns
                    self._dirty = True
                    for g in copies:
                        self._dirty_srcs.update(self._group_members.get(g, ()))
        elif self.forward_ranks:
            with self._lock:
                # only updated sources are forwarded, via the version-stamped
                # frozen snapshots (no per-flush deep copies); a forced
                # (stop-path) flush re-sends every source in full
                snaps = self._ranks_snapshot_locked()
                srcs = list(snaps) if force else list(self._dirty_srcs)
                self._dirty_srcs.clear()
                copies = {src: snaps[src] for src in srcs if src in snaps}
            ok = True
            for src, tally in copies.items():
                ok = self._forwarder.push(
                    tally, source=src, skip_unchanged=not force
                ) and ok
            if not ok:
                with self._lock:
                    # parent unreachable: re-arm the failed sources so their
                    # state is re-forwarded once the parent comes back
                    self._dirty = True
                    self._dirty_srcs.update(copies)
        else:
            ok = self._forwarder.push(self.composite())
            if not ok:
                with self._lock:
                    self._dirty = True
        return ok

    # -- threads -------------------------------------------------------------
    def _accept_loop(self) -> None:
        ls = self._lsock
        while not self._stop_evt.is_set():
            try:
                conn, _peer = ls.accept()
            except OSError:
                break
            with self._lock:
                self._conns.append(conn)
            t = threading.Thread(
                target=self._client_loop, args=(conn,), name="thapi-master-conn", daemon=True
            )
            with self._lock:
                self._threads.append(t)
            t.start()

    def _client_loop(self, conn: socket.socket) -> None:
        with self._lock:
            self._conn_gen += 1
            gen = self._conn_gen  # scopes this connection's seq chains
        try:
            while not self._stop_evt.is_set():
                try:
                    msg = recv_frame(conn)
                except (ProtocolError, OSError):
                    break
                if msg is None:
                    break
                self.frames += 1
                kind = msg.get("type")
                if kind == "snapshot":
                    self.submit(
                        str(msg.get("source", "?")), msg["tally"], msg.get("seq"), gen
                    )
                elif kind == "delta":
                    source = str(msg.get("source", "?"))
                    ok = self.submit_delta(
                        source,
                        msg["delta"],
                        int(msg.get("seq", -1)),
                        int(msg.get("base_seq", -2)),
                        gen,
                    )
                    if not ok:
                        # mis-based delta: ask the sender for a full snapshot
                        # (scoped to the one source whose chain diverged)
                        self.resyncs_sent += 1
                        try:
                            conn.sendall(
                                pack_frame(
                                    {
                                        "type": "resync",
                                        "v": PROTOCOL_VERSION,
                                        "source": source,
                                    }
                                )
                            )
                        except OSError:
                            break
                elif kind == "hello":
                    # a fresh connection restarts the peer's seq counter (e.g.
                    # a new Tracer session in the same process): forget the
                    # stored seq so its snapshots aren't dropped as stale.
                    # The ack tells v2 senders they may switch to deltas.
                    self._reset_seq(str(msg.get("source", "?")))
                    try:
                        conn.sendall(
                            pack_frame({"type": "hello_ack", "v": PROTOCOL_VERSION})
                        )
                    except OSError:
                        break
                elif kind == "query":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._composite_msg()))
                    except OSError:
                        break
                elif kind == "query_ranks":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._ranks_msg()))
                    except OSError:
                        break
                elif kind == "query_groups":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._groups_msg()))
                    except OSError:
                        break
                elif kind == "subscribe":
                    # push composites on this connection until it dies; the
                    # pusher owns the socket's send side from here on
                    period = float(msg.get("period_s", 1.0))
                    by_rank = bool(msg.get("by_rank", False))
                    t = threading.Thread(
                        target=self._subscription_loop,
                        args=(conn, period, by_rank),
                        name="thapi-master-subpush",
                        daemon=True,
                    )
                    with self._lock:
                        self._threads.append(t)
                    t.start()
                elif kind == "ping":
                    try:
                        conn.sendall(pack_frame({"type": "pong", "v": PROTOCOL_VERSION}))
                    except OSError:
                        break
                elif kind == "bye":
                    break
                # unknown types: ignored, no reply needed
        finally:
            try:
                conn.close()
            except OSError:
                pass
            # long-lived masters see many short query connections: prune, or
            # _conns/_threads grow without bound
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                cur = threading.current_thread()
                if cur in self._threads:
                    self._threads.remove(cur)

    def _subscription_loop(
        self, conn: socket.socket, period_s: float, by_rank: bool = False
    ) -> None:
        """Push ``composite`` frames to a subscribed client every period.

        Change-gated: the full composite is serialized only when state
        actually updated since the last push; idle periods send a tiny
        tally-less heartbeat (``unchanged: true``) instead — a 2000-row
        composite is not re-shipped twice a second to a viewer of an idle
        master.  The first push is always full.  With ``by_rank`` every
        full push also carries the per-source breakdown.
        """
        last_version = None
        try:
            while not self._stop_evt.is_set():
                with self._lock:
                    version = self._version
                if version != last_version:
                    msg = self._composite_msg(by_rank=by_rank)
                    last_version = version
                else:
                    st = self.stats()
                    msg = {
                        "type": "composite",
                        "v": PROTOCOL_VERSION,
                        "unchanged": True,
                        "sources": st["sources"],
                        "snapshots": st["snapshots"],
                        "deltas": st["deltas"],
                        "updated": st["updated"],
                    }
                try:
                    conn.sendall(pack_frame(msg))
                except OSError:
                    break
                if self._stop_evt.wait(period_s):
                    break
        finally:
            with self._lock:
                cur = threading.current_thread()
                if cur in self._threads:
                    self._threads.remove(cur)

    def _forward_loop(self) -> None:
        while not self._stop_evt.wait(self.forward_period_s):
            self.flush()

    def _composite_msg(self, by_rank: bool = False) -> dict:
        # one snapshot under one lock: a frame's composite and per-rank map
        # must describe the same instant, or a subscriber cross-checking
        # invariant 7 (per-rank sums == composite) sees spurious mismatches
        # whenever a submit races the push.  Both sides come from the
        # incremental caches — no per-query re-merge of every source — and
        # the frozen snapshots are safe to serialize outside the lock.  On
        # the rare rebuild, the source copies and the per-rank snapshot are
        # taken under the same hold (same instant) and the merge runs
        # outside the lock so ingest never stalls behind it.
        comp = None
        with self._lock:
            if self.composite_cache and self._comp is not None and not self._comp_dirty:
                comp = Tally().merge(self._comp)
            else:
                version = self._version
                copies, ops = self._comp_copies_locked()
            snap = self._ranks_snapshot_locked() if by_rank else None
        if comp is None:
            comp = self._finish_rebuild(copies, ops, version)
        st = self.stats()
        msg = {
            "type": "composite",
            "v": PROTOCOL_VERSION,
            "tally": comp.to_obj(),
            "sources": st["sources"],
            "snapshots": st["snapshots"],
            "deltas": st["deltas"],
            "updated": st["updated"],
        }
        if by_rank:
            msg["ranks"] = {src: t.to_obj() for src, t in snap.items()}
        return msg

    def _ranks_msg(self) -> dict:
        """``query_ranks`` reply: the per-source tally map + receipt times."""
        with self._lock:
            snap = self._ranks_snapshot_locked()
            stamps = {src: e.ts for src, e in self._latest.items()}
        # frozen snapshots: replaced wholesale on change, safe to serialize
        # after the lock is released
        ranks = {src: t.to_obj() for src, t in snap.items()}
        st = self.stats()
        return {
            "type": "ranks",
            "v": PROTOCOL_VERSION,
            "ranks": ranks,
            "ts": stamps,
            "sources": st["sources"],
            "snapshots": st["snapshots"],
            "deltas": st["deltas"],
            "updated": st["updated"],
        }

    def _groups_msg(self) -> dict:
        """``query_groups`` reply: the rollup breakdown (empty when off)."""
        gro = self.groups()
        st = self.stats()
        return {
            "type": "groups",
            "v": PROTOCOL_VERSION,
            "rollup": self.rollup_groups is not None,
            "groups": {g: t.to_obj() for g, t in gro.items()},
            "sources": st["sources"],
            "snapshots": st["snapshots"],
            "deltas": st["deltas"],
            "updated": st["updated"],
        }


# ---------------------------------------------------------------------------
# Query clients (iprof top, serve layer, tests)
# ---------------------------------------------------------------------------

_COMPOSITE_META_KEYS = ("sources", "snapshots", "deltas", "updated")


def _composite_reply(msg: Optional[dict]) -> Tuple[Tally, dict]:
    if not msg or msg.get("type") != "composite":
        raise ProtocolError(f"expected composite reply, got {msg!r}")
    meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
    return Tally.from_obj(msg["tally"]), meta


def query_composite(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0
) -> Tuple[Tally, dict]:
    """One-shot request: connect to a master, fetch (composite, meta)."""
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        s.settimeout(timeout_s)
        s.sendall(pack_frame({"type": "query", "v": PROTOCOL_VERSION}))
        msg = recv_frame(s)
    return _composite_reply(msg)


def query_ranks(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0
) -> Tuple[Dict[str, Tally], dict]:
    """One-shot request: fetch a master's per-rank breakdown.

    Returns ``(ranks, meta)`` where ``ranks`` maps source id (the rank
    identity, ``host:pid:rankN``) → its latest cumulative tally, and
    ``meta`` carries the composite meta keys plus ``ts`` (source → receipt
    wall clock).  Merging every value of ``ranks`` reproduces the
    ``query_composite`` tally exactly — per-rank sums equal the composite,
    API for API.
    """
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        s.settimeout(timeout_s)
        s.sendall(pack_frame({"type": "query_ranks", "v": PROTOCOL_VERSION}))
        msg = recv_frame(s)
    if not msg or msg.get("type") != "ranks":
        raise ProtocolError(f"expected ranks reply, got {msg!r}")
    meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
    meta["ts"] = msg.get("ts", {})
    return {src: Tally.from_obj(o) for src, o in msg["ranks"].items()}, meta


def query_groups(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0
) -> Tuple[Dict[str, Tally], dict]:
    """One-shot request: fetch a master's rollup-group breakdown.

    Returns ``(groups, meta)`` where ``groups`` maps group id (e.g. a
    hostname, or ``groupK`` rank buckets) → the aggregated tally of its
    member sources, and ``meta`` carries the composite meta keys plus
    ``rollup`` (False when the master runs without ``rollup_groups`` — the
    map is then empty).  Merging every group reproduces the composite, so
    >1k-rank trees can be read at node granularity without shipping or
    merging per-rank tables.
    """
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        s.settimeout(timeout_s)
        s.sendall(pack_frame({"type": "query_groups", "v": PROTOCOL_VERSION}))
        msg = recv_frame(s)
    if not msg or msg.get("type") != "groups":
        raise ProtocolError(f"expected groups reply, got {msg!r}")
    meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
    meta["rollup"] = bool(msg.get("rollup", False))
    return {g: Tally.from_obj(o) for g, o in msg["groups"].items()}, meta


def subscribe_composites(
    addr: Union[str, Tuple[str, int]],
    period_s: float = 1.0,
    timeout_s: float = 10.0,
    by_rank: bool = False,
) -> Iterator[Tuple[Tally, dict]]:
    """Subscribe to a master: yields (composite, meta) as the master pushes.

    The generator owns the connection; it ends on master shutdown (clean
    EOF) and raises ``OSError`` / ``ProtocolError`` on transport trouble —
    exactly the errors ``query_composite`` raises, so callers can share
    handling.  Close the generator to disconnect.

    Idle periods arrive as tally-less heartbeats (the master only
    re-serializes the composite when state changed); the generator then
    re-yields the previous tally with ``meta["unchanged"] = True``, so
    consumers always see a renderable composite per period.

    With ``by_rank`` every full push also carries the per-source breakdown,
    surfaced as ``meta["ranks"]`` (source → Tally); heartbeats re-yield the
    cached breakdown like the cached composite.
    """
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        s.settimeout(max(timeout_s, 2 * period_s))
        s.sendall(
            pack_frame(
                {
                    "type": "subscribe",
                    "v": PROTOCOL_VERSION,
                    "period_s": period_s,
                    "by_rank": by_rank,
                }
            )
        )
        last_tally: Optional[Tally] = None
        last_ranks: Optional[Dict[str, Tally]] = None
        while True:
            msg = recv_frame(s)
            if msg is None:  # master stopped: end of stream
                return
            if not msg or msg.get("type") != "composite":
                raise ProtocolError(f"expected composite frame, got {msg!r}")
            meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
            if "tally" in msg:
                last_tally = Tally.from_obj(msg["tally"])
                if "ranks" in msg:
                    last_ranks = {
                        src: Tally.from_obj(o) for src, o in msg["ranks"].items()
                    }
            elif last_tally is None:
                raise ProtocolError("unchanged heartbeat before any composite")
            else:
                meta["unchanged"] = True
            if by_rank and last_ranks is not None:
                meta["ranks"] = last_ranks
            yield last_tally, meta


def live_snapshot() -> Optional[Tally]:
    """Global live profile of the *current process*, if a session is tracing.

    With ``serve_port`` set the tracer runs an in-process master, so the
    snapshot covers every source streaming to it (the global view); plain
    ``online=True`` yields this rank's own live tally; otherwise None.
    """
    from .tracer import active_tracer

    tr = active_tracer()
    if tr is None:
        return None
    server = getattr(tr, "server", None)
    if server is not None:
        return server.composite()
    if tr.online is not None:
        return tr.online.snapshot()
    return None

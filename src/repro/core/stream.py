"""Live streaming multi-rank aggregation (THAPI §3.7 joined with §6).

The offline path (aggregate.py) is a *batch* tree reduction over ``.tally``
files; the online path (online.py) is a *single-process* live tally.  This
module joins them into a streaming service — the network-transported,
always-current version of ``aggregate_tree``:

    rank (OnlineAnalyzer) ──snapshot/delta──▶ local master ──composite──▶ global master
                                                   ▲                          ▲
                                              iprof top                  iprof top

  * Each traced rank periodically pushes its cumulative tally over TCP to a
    master (:class:`SnapshotStreamer`, driven by the tracer's consumer
    thread).  Protocol **v2** ships *delta frames* in steady state: only the
    ApiStats entries that changed since the last delivered state (each with
    its full cumulative value), with periodic full-snapshot resync frames
    bounding drift.  On very wide tallies this is the difference between
    shipping the whole table every interval and shipping a few hot rows.
  * A :class:`MasterServer` keeps the latest cumulative tally per source —
    rebuilt incrementally from deltas — and merges them with the tally
    monoid on demand.  Snapshots are cumulative, so latest-wins merging is
    idempotent and converges to exactly the offline ``combine_aggregates``
    result once every rank has pushed its final state (tracer stop pushes a
    final frame unconditionally).
  * Masters compose into a configurable-fanout tree: a master constructed
    with ``forward_to=`` periodically pushes its state upstream, exactly the
    paper's "each local master sends its aggregate to the global master" —
    but live, while the ranks still run.  Forwarded state is delta-encoded
    too, and (by default) **per rank**: every origin source rides its own
    multiplexed frame chain, so the per-rank breakdown survives each hop of
    the tree instead of collapsing into an anonymous composite.
  * ``iprof serve`` runs a master; ``iprof top`` attaches to any master and
    renders the refreshing composite (``--live`` subscribes for pushed
    updates instead of polling, ``--by-rank`` adds the per-rank table);
    :func:`query_composite` / :func:`query_ranks` /
    :func:`subscribe_composites` are the programmatic clients.  Cluster-
    scope adaptive policies (``core/adaptive.py``) read the per-rank map to
    detect stragglers and rank skew the merged composite hides.

Transport is deliberately tiny: length-prefixed msgpack frames (4-byte
big-endian length + body), one dict message per frame, ``type`` key selects
the handler.  Snapshots are kilobytes (§3.7), so a 64 MiB frame cap is
generous headroom, not a tuning knob.

Delta correctness contract (see docs/streaming.md for the full spec):

  * every frame carries ``seq``; delta frames also carry ``base_seq`` — the
    seq of the state they were computed against.  A master applies a delta
    only when its stored seq for the source equals ``base_seq``; otherwise
    it drops the frame and answers ``resync`` on the same connection, and
    the streamer's next push is a full snapshot.
  * a streamer only sends deltas after the master's ``hello_ack`` proves the
    peer speaks v2 — unknown or v1 masters receive full snapshots forever,
    so the wire stays backward compatible.
  * any reconnect starts with a full snapshot (the delta base is
    connection-local state).

Failure model: the traced application must never block or crash because a
master is slow, absent, or restarting.  The streamer connects lazily,
retries with backoff, and *drops* frames it cannot deliver (counted in
``dropped``) — the next successful full push carries the entire cumulative
state, so nothing is lost but latency.
"""

from __future__ import annotations

import collections
import dataclasses
import hmac
import logging
import os
import select
import socket
import ssl
import struct
import threading
import time
import warnings
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import msgpack

from .aggregate import merge_tallies
from .plugins.tally import ApiStat, Tally, intern_key

#: v2 adds delta frames, ``hello_ack`` and ``resync`` control frames, and
#: ``subscribe`` push mode. v1 peers are still understood (full snapshots).
PROTOCOL_VERSION = 2
#: oldest peer version that accepts ``delta`` frames
DELTA_MIN_VERSION = 2
MAX_FRAME = 64 << 20  # frames are tally snapshots: KBs in practice (§3.7)
_HDR = struct.Struct("!I")
#: tenant id used when auth is off, and for tokens mapped without a tenant
DEFAULT_TENANT = "default"

logger = logging.getLogger("repro.stream")


class ProtocolError(RuntimeError):
    """Malformed or truncated frame on a stream connection."""


class ServerRejected(ProtocolError):
    """The server refused the request: auth failure or quota exceeded.

    Carries the server's ``error`` code (``"auth"`` / ``"quota"`` /
    ``"fence"``) so clients can distinguish retryable transport trouble from
    a hard rejection."""

    def __init__(self, code: str, detail: str = ""):
        super().__init__(f"server rejected request ({code}): {detail or code}")
        self.code = code
        self.detail = detail


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def pack_frame(msg: dict) -> bytes:
    """One message → one length-prefixed msgpack frame."""
    body = msgpack.packb(msg, use_bin_type=True)
    if len(body) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds cap {MAX_FRAME}")
    return _HDR.pack(len(body)) + body


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[dict]:
    """Read one frame; None on clean EOF, ProtocolError on a torn frame."""
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_FRAME:
        raise ProtocolError(f"peer announced {n}-byte frame (cap {MAX_FRAME})")
    body = _recv_exact(sock, n)
    if body is None:
        raise ProtocolError("connection closed mid-frame")
    return msgpack.unpackb(body, raw=False)


def parse_addr(addr: Union[str, Tuple[str, int]]) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``(host, port)`` → ``(host, port)``."""
    if isinstance(addr, tuple):
        return addr[0], int(addr[1])
    host, _, port = addr.rpartition(":")
    return (host or "127.0.0.1"), int(port)


def default_source(rank: int = 0) -> str:
    """Canonical source id for a traced rank: ``host:pid:rankN``."""
    return f"{socket.gethostname()}:{os.getpid()}:rank{rank}"


# ---------------------------------------------------------------------------
# Hardened serving tier: TLS contexts, token auth, tenants, quotas
# ---------------------------------------------------------------------------


def server_ssl_context(
    certfile: str, keyfile: Optional[str] = None, cafile: Optional[str] = None
) -> ssl.SSLContext:
    """Server-side TLS context for a master.

    ``cafile`` additionally demands client certificates signed by that CA
    (mutual TLS); without it any client may connect and token auth is the
    identity layer."""
    ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
    ctx.load_cert_chain(certfile, keyfile)
    if cafile:
        ctx.load_verify_locations(cafile)
        ctx.verify_mode = ssl.CERT_REQUIRED
    return ctx


def client_ssl_context(
    cafile: Optional[str] = None,
    certfile: Optional[str] = None,
    keyfile: Optional[str] = None,
) -> ssl.SSLContext:
    """Client-side TLS context for streamers and :class:`StreamClient`.

    ``cafile`` pins the master's (typically self-signed or fleet-internal)
    CA; without it the system trust store applies.  Hostname checking is
    disabled — masters live on ephemeral ports behind job schedulers, so
    identity comes from the pinned CA (and tokens), not DNS names."""
    ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
    ctx.check_hostname = False
    if cafile:
        ctx.load_verify_locations(cafile)
    else:
        ctx.load_default_certs()
    if certfile:
        ctx.load_cert_chain(certfile, keyfile)
    return ctx


@dataclasses.dataclass
class ServeOptions:
    """Every serving-tier knob for a :class:`MasterServer` in one object.

    Shared by ``MasterServer``, ``ServeEngine``, ``TraceConfig`` and the
    ``iprof serve`` flag parser, so server construction is one value instead
    of ~10 scattered keywords.  All fields have working defaults: a default-
    constructed ``ServeOptions()`` reproduces the historical open,
    plaintext, single-tenant master.

    Security knobs:

    * ``tls_cert``/``tls_key`` enable TLS on the listening socket;
      ``tls_ca`` additionally requires client certificates (mutual TLS).
    * ``auth_tokens`` maps bearer token → tenant id.  When set, every
      connection must open with a ``hello`` carrying a valid ``token``
      before any other frame; failures are rejected, logged, and counted.
      When ``None``, auth is off and every connection lands in the
      ``"default"`` tenant.
    * quotas (``max_sources``, ``max_tally_rows``, ``max_subscribers``) are
      enforced per tenant at ingest and subscribe time; ``0`` = unlimited.

    Forwarding credentials (``forward_token``/``forward_tls_ca``) are what
    *this* master presents upstream; ``forward_tenant`` names the tenant
    whose state is forwarded (interior tree hops are single-tenant
    infrastructure — see docs/streaming.md §tenants).
    """

    fanout: int = 32
    forward_ranks: bool = True
    forward_delta: bool = True
    forward_resync_every: int = 32
    rollup_groups: Union[None, str, int, Callable[[str], str]] = None
    composite_cache: bool = True
    # -- TLS --
    tls_cert: Optional[str] = None
    tls_key: Optional[str] = None
    tls_ca: Optional[str] = None
    # -- auth / tenancy --
    auth_tokens: Optional[Dict[str, str]] = None
    # -- per-tenant quotas (0 = unlimited) --
    max_sources: int = 0
    max_tally_rows: int = 0
    max_subscribers: int = 0
    #: bounded per-subscriber frame queue; a subscriber whose queue overflows
    #: (it is not draining what the hub fans out) is evicted, not waited on
    hub_queue_frames: int = 16
    # -- upstream credentials (local masters forwarding to a parent) --
    forward_token: Optional[str] = None
    forward_tls_ca: Optional[str] = None
    forward_tenant: str = DEFAULT_TENANT
    #: initial-connect resilience of the upstream forwarder: retry the
    #: *first* connect up to N times with capped-exponential backoff
    #: (base ``connect_backoff_s``) before giving up on a push — so a local
    #: master started before its parent doesn't drop early state.  0 (the
    #: default) keeps fail-fast; reconnects after a successful connection
    #: always use the non-blocking retry pacing.
    connect_retries: int = 0
    connect_backoff_s: float = 0.25
    #: garbage-collect sources whose last frame is older than this many
    #: seconds (the sender disconnected, died, or was evicted and never
    #: replaced): their rows leave ``ranks()``, the composite, and rollup
    #: groups, and each collection bumps the ``source_gc`` counter in
    #: ``stats()``.  0 (the default) keeps every source forever — the
    #: historical behavior, and the right one for short-lived runs where
    #: the final composite must include every rank that ever pushed.
    source_ttl_s: float = 0.0

    def __post_init__(self):
        if self.tls_key and not self.tls_cert:
            raise ValueError("tls_key requires tls_cert")
        if self.tls_ca and not self.tls_cert:
            raise ValueError("tls_ca (client-cert verification) requires tls_cert")
        for name in ("max_sources", "max_tally_rows", "max_subscribers"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0 (0 = unlimited)")
        if self.hub_queue_frames < 1:
            raise ValueError("hub_queue_frames must be >= 1")
        if self.connect_retries < 0:
            raise ValueError("connect_retries must be >= 0")
        if self.connect_backoff_s <= 0:
            raise ValueError("connect_backoff_s must be > 0")
        if self.source_ttl_s < 0:
            raise ValueError("source_ttl_s must be >= 0 (0 = never collect)")

    @property
    def auth_required(self) -> bool:
        return bool(self.auth_tokens)

    def tenant_for(self, token: Optional[Union[str, bytes]]) -> Optional[str]:
        """Token → tenant id; None means *rejected*.

        Compares against every configured token with
        :func:`hmac.compare_digest` (no early exit on the first mismatched
        byte, and no dict-lookup timing channel on token existence).  With
        auth off every caller maps to :data:`DEFAULT_TENANT`."""
        if not self.auth_tokens:
            return DEFAULT_TENANT
        if not isinstance(token, (str, bytes)):
            return None
        tb = token.encode() if isinstance(token, str) else token
        matched: Optional[str] = None
        for tok, tenant in self.auth_tokens.items():
            if hmac.compare_digest(tok.encode(), tb):
                matched = tenant or DEFAULT_TENANT
        return matched

    def build_server_ssl(self) -> Optional[ssl.SSLContext]:
        if self.tls_cert is None:
            return None
        return server_ssl_context(self.tls_cert, self.tls_key, self.tls_ca)

    def build_forward_ssl(self) -> Optional[ssl.SSLContext]:
        if self.forward_tls_ca is None:
            return None
        return client_ssl_context(cafile=self.forward_tls_ca)


# ---------------------------------------------------------------------------
# Rank side: snapshot push client
# ---------------------------------------------------------------------------


class _SourceState:
    """Per-source seq/delta bookkeeping on the *current* connection."""

    __slots__ = ("seq", "last_sent", "sends_since_full", "force_full")

    def __init__(self):
        self.seq = 0
        self.last_sent: Optional[Tally] = None
        self.sends_since_full = 0
        self.force_full = False


class SnapshotStreamer:
    """Pushes cumulative tally state to a master; never blocks tracing.

    Push cadence belongs to the caller (the tracer's consumer thread, a
    master's forwarder loop); ``push(tally)`` always sends — the tracer's
    stop path relies on that for the final, authoritative state.

    One streamer, one connection, **many sources**: every frame names its
    ``source``, so a local master can forward its whole per-rank breakdown
    over a single upstream connection (``push(tally, source=rank_id)`` per
    rank) — each source keeps an independent seq chain and delta base.
    Plain leaf ranks never pass ``source`` and behave exactly as before.

    With ``delta=True`` (the default) the streamer tracks the last state
    delivered per source on the current connection and ships only changed
    entries once the master's ``hello_ack`` confirms a v2 peer.  Every
    ``resync_every``-th push — and the first push of every connection — is a
    full snapshot, so a master can always rebuild from the wire alone.
    Counters: ``pushed`` / ``dropped`` / ``skipped`` (frames),
    ``full_frames`` / ``delta_frames`` (mix), ``bytes_sent`` (on-wire
    payload), ``resyncs`` (master-requested fallbacks to full).
    """

    def __init__(
        self,
        addr: Union[str, Tuple[str, int]],
        source: str,
        retry_s: float = 0.5,
        timeout_s: float = 2.0,
        delta: bool = True,
        resync_every: int = 32,
        token: Optional[str] = None,
        ssl_context: Optional[ssl.SSLContext] = None,
        server_hostname: Optional[str] = None,
        connect_retries: int = 0,
        connect_backoff_s: float = 0.25,
        incarnation: int = 0,
    ):
        self.addr = parse_addr(addr)
        self.source = source
        #: incarnation of this source's identity (elastic rank replacement:
        #: a replacement worker for the same logical rank carries a strictly
        #: larger incarnation; the master fences frames from superseded
        #: ones — docs/streaming.md §incarnations).  Rides the ``hello``
        #: and every state frame for the default source.
        if incarnation < 0:
            raise ValueError("incarnation must be >= 0")
        self.incarnation = int(incarnation)
        self.retry_s = retry_s
        self.timeout_s = timeout_s
        self.delta = delta
        self.resync_every = max(1, int(resync_every))
        #: initial-connect resilience: until the *first* connection has ever
        #: succeeded, a failed connect is retried up to ``connect_retries``
        #: times in-line with capped-exponential backoff (base
        #: ``connect_backoff_s``, doubling, capped at 8× base) — so ranks
        #: that start before their master don't drop their early pushes.
        #: The default 0 keeps the historical fail-fast behavior; once a
        #: connection has succeeded, reconnects always use the non-blocking
        #: ``retry_s`` pacing (a mid-run master outage must not stall
        #: the consumer thread).
        if connect_retries < 0 or connect_backoff_s <= 0:
            raise ValueError("connect_retries must be >= 0 and connect_backoff_s > 0")
        self.connect_retries = int(connect_retries)
        self.connect_backoff_s = connect_backoff_s
        self._ever_connected = False
        #: bearer token presented in ``hello`` (auth-enabled masters)
        self.token = token
        #: client-side TLS context (see :func:`client_ssl_context`); None
        #: keeps the plaintext wire
        self.ssl_context = ssl_context
        self.server_hostname = server_hostname or self.addr[0]
        self.pushed = 0
        self.dropped = 0
        self.skipped = 0
        self.full_frames = 0
        self.delta_frames = 0
        self.bytes_sent = 0
        self.resyncs = 0
        self.rejected = 0  # master sent an error frame (auth/quota): conn dropped
        self.fenced = 0  # master fenced this incarnation: pushing stopped for good
        self._sock: Optional[socket.socket] = None
        self._next_retry = 0.0
        self._lock = threading.Lock()
        #: per-source state on the *current* connection (reset on reconnect)
        self._src: Dict[str, _SourceState] = {}
        self._peer_version: Optional[int] = None  # learned from hello_ack

    @property
    def peer_version(self) -> Optional[int]:
        """Master's protocol version once its ``hello_ack`` arrived, else None."""
        return self._peer_version

    def poll_control(self) -> None:
        """Drain pending control frames (``hello_ack`` / ``resync``) now.

        ``push`` does this automatically before every send; callers that
        want deterministic delta engagement (benchmarks, tests) may call it
        after the first push instead of waiting for the next cadence tick.
        """
        with self._lock:
            if self._sock is not None:
                self._drain_control(self._sock)

    def push(
        self,
        tally: Union[Tally, dict],
        source: Optional[str] = None,
        skip_unchanged: bool = False,
        telemetry: Optional[dict] = None,
        incarnation: Optional[int] = None,
    ) -> bool:
        """Deliver the current cumulative ``tally``; returns delivery success.

        Chooses delta vs full per the protocol contract, never blocks beyond
        ``timeout_s``, and on any failure drops the connection (the next
        successful push is a full snapshot again).  ``source`` defaults to
        this streamer's own identity; forwarders pass each origin rank's id
        to carry the per-rank breakdown upstream.  With ``skip_unchanged``
        a delta-eligible push whose state did not change since the last
        delivery is elided (counted in ``skipped``) — used by per-rank
        forwarding so idle ranks cost no wire traffic.  ``telemetry`` is an
        optional per-source device-telemetry dict (host RSS, memory
        pressure, transfer bandwidths — docs/streaming.md) that rides the
        frame as an optional key; a push carrying telemetry is never elided
        (sick-host evidence must flow even when the tally is idle).
        ``incarnation`` overrides the streamer-level incarnation per push —
        forwarders pass each origin source's incarnation so the fence holds
        at every level of the master tree; None uses the streamer's own for
        its default source and 0 for explicitly-named ones.
        """
        cur = tally if isinstance(tally, Tally) else Tally.from_obj(tally)
        src = source if source is not None else self.source
        if incarnation is not None:
            inc = int(incarnation)
        else:
            inc = self.incarnation if source is None else 0
        with self._lock:
            sock = self._ensure_conn()
            if sock is None:
                self.dropped += 1
                return False
            if not self._drain_control(sock):
                self.dropped += 1
                return False
            st = self._src.setdefault(src, _SourceState())
            msg = self._encode(st, src, cur, skip_unchanged and telemetry is None)
            if msg is None:  # delta-eligible and nothing changed: elide
                self.skipped += 1
                return True
            if telemetry is not None:
                msg["telemetry"] = telemetry
            if inc:
                msg["incarnation"] = inc
            frame = pack_frame(msg)
            try:
                sock.sendall(frame)
            except OSError:
                self._drop_conn()
                self.dropped += 1
                return False
            st.seq += 1
            self.pushed += 1
            self.bytes_sent += len(frame)
            # keep a private copy: the caller may keep mutating its tally
            st.last_sent = Tally().merge(cur)
            if msg["type"] == "delta":
                self.delta_frames += 1
                st.sends_since_full += 1
            else:
                self.full_frames += 1
                st.sends_since_full = 0
                st.force_full = False
            return True

    def _encode(
        self, st: _SourceState, source: str, cur: Tally, skip_unchanged: bool = False
    ) -> Optional[dict]:
        """Build the frame for ``cur``: delta when the contract allows it.

        Returns None when ``skip_unchanged`` is set and a delta-eligible
        state shows no change since the last delivery.
        """
        use_delta = (
            self.delta
            and st.last_sent is not None
            and not st.force_full
            and self._peer_version is not None
            and self._peer_version >= DELTA_MIN_VERSION
            and st.sends_since_full < self.resync_every
        )
        if use_delta:
            try:
                d = cur.delta_to(st.last_sent)
            except ValueError:
                use_delta = False  # non-monotone state: full resync
        if use_delta:
            if skip_unchanged and not (
                d["apis"]
                or d["device_apis"]
                or d["hostnames"]
                or d["processes"]
                or d["threads"]
                or d["discarded"] != st.last_sent.discarded
            ):
                return None
            return {
                "type": "delta",
                "v": PROTOCOL_VERSION,
                "source": source,
                "seq": st.seq,
                "base_seq": st.seq - 1,
                "ts": time.time(),
                "delta": d,
            }
        return {
            "type": "snapshot",
            "v": PROTOCOL_VERSION,
            "source": source,
            "seq": st.seq,
            "ts": time.time(),
            "tally": cur.to_obj(),
        }

    def _drain_control(self, sock: socket.socket) -> bool:
        """Consume buffered master→streamer frames; False if the conn died."""
        while True:
            try:
                r, _, _ = select.select([sock], [], [], 0)
            except (OSError, ValueError):
                self._drop_conn()
                return False
            if not r:
                return True
            try:
                msg = recv_frame(sock)
            except (ProtocolError, OSError):
                self._drop_conn()
                return False
            if msg is None:  # EOF: master went away
                self._drop_conn()
                return False
            kind = msg.get("type")
            if kind == "hello_ack":
                self._peer_version = int(msg.get("v", 1))
            elif kind == "resync":
                # scoped to one source when the master names it; a v2.0
                # master (no source field) resyncs every chain
                src = msg.get("source")
                if src is None:
                    for st in self._src.values():
                        st.force_full = True
                else:
                    self._src.setdefault(str(src), _SourceState()).force_full = True
                self.resyncs += 1
            elif kind == "error":
                # hard rejection (bad token, quota): drop the connection and
                # let the retry backoff pace reconnects — pushes keep being
                # counted in ``dropped`` so the failure is visible, and a
                # fixed token/quota on the master side heals without restart
                self.rejected += 1
                logger.warning(
                    "master %s:%d rejected stream (%s): %s",
                    self.addr[0],
                    self.addr[1],
                    msg.get("error", "?"),
                    msg.get("detail", ""),
                )
                self._drop_conn()
                if msg.get("error") == "fence":
                    # this incarnation is superseded: a replacement took over
                    # the source identity.  Reconnecting can never succeed
                    # (the fence is monotone), so stop for good — the polite
                    # client side of zombie containment.
                    self.fenced += 1
                    self._next_retry = float("inf")
                else:
                    self._next_retry = time.monotonic() + self.retry_s
                return False
            # anything else from the master is ignorable here

    def _ensure_conn(self) -> Optional[socket.socket]:
        if self._sock is not None:
            return self._sock
        if time.monotonic() < self._next_retry:
            return None
        # initial connect only: blocking capped-exponential retry so a rank
        # that starts before its master still delivers its first push
        attempts = 0 if self._ever_connected else self.connect_retries
        while True:
            try:
                s = socket.create_connection(self.addr, timeout=self.timeout_s)
                s.settimeout(self.timeout_s)
                if self.ssl_context is not None:
                    # handshake runs under the socket timeout; a plaintext or
                    # wrong-cert master fails here (OSError) → normal retry path
                    s = self.ssl_context.wrap_socket(
                        s, server_hostname=self.server_hostname
                    )
                hello = {"type": "hello", "v": PROTOCOL_VERSION, "source": self.source}
                if self.token is not None:
                    hello["token"] = self.token
                if self.incarnation:
                    hello["incarnation"] = self.incarnation
                s.sendall(pack_frame(hello))
                break
            except OSError:
                if attempts <= 0:
                    self._next_retry = time.monotonic() + self.retry_s
                    return None
                n = self.connect_retries - attempts
                attempts -= 1
                time.sleep(min(self.connect_backoff_s * (2.0**n), 8 * self.connect_backoff_s))
        self._sock = s
        self._ever_connected = True
        # connection-local delta state starts fresh: first push is full
        self._src = {}
        self._peer_version = None
        return s

    def _drop_conn(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        self._src = {}
        self._peer_version = None

    def close(self) -> None:
        """Send ``bye`` (best-effort) and drop the connection."""
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.sendall(pack_frame({"type": "bye", "source": self.source}))
                except OSError:
                    pass
                self._drop_conn()


# ---------------------------------------------------------------------------
# Incremental composite maintenance (the read-path scaling layer)
# ---------------------------------------------------------------------------
#
# Cumulative tallies only grow, so a source's old→new change can be *applied*
# to a running accumulator row-by-row instead of re-merging every source per
# read: calls/total add their difference (subtraction is exact on the
# additive fields), min/max clamp (monotone growth guarantees new.min ≤
# old.min and new.max ≥ old.max, so the clamp can never miss a tighter bound
# held by the replaced state).  A change that is NOT monotone growth — a
# restarted rank, a reset counter, a shrunk table — cannot be applied
# incrementally; the helpers detect it (before touching the accumulator) and
# the caller falls back to a full rebuild on the next read.


def _acc_row(table: Dict[Tuple[str, str], ApiStat], key, st: ApiStat) -> None:
    row = table.get(key)
    if row is None:
        table[key] = ApiStat(
            calls=st.calls, total_ns=st.total_ns, min_ns=st.min_ns, max_ns=st.max_ns
        )
    else:
        row.merge(st)


def _tally_update_ops(acc: Tally, old: Optional[Tally], new: Tally) -> Optional[int]:
    """Fold one source's old→new cumulative change into accumulator ``acc``.

    Returns the number of row-ops applied — O(changed rows), the invariant
    the composite cache is built on — or None (``acc`` untouched) when the
    change is not monotone growth and the accumulator must be rebuilt.
    Validation runs fully before the first mutation, so a None return never
    leaves ``acc`` half-updated.
    """
    ops = 0
    if old is None:
        for key, st in new.apis.items():
            _acc_row(acc.apis, key, st)
            ops += 1
        for key, st in new.device_apis.items():
            _acc_row(acc.device_apis, key, st)
            ops += 1
        acc.hostnames |= new.hostnames
        acc.processes |= new.processes
        acc.threads |= new.threads
        acc.discarded += new.discarded
        return ops
    if new.discarded < old.discarded:
        return None
    if (
        old.hostnames - new.hostnames
        or old.processes - new.processes
        or old.threads - new.threads
    ):
        return None
    changed = []
    for acc_t, old_t, new_t in (
        (acc.apis, old.apis, new.apis),
        (acc.device_apis, old.device_apis, new.device_apis),
    ):
        if len(old_t) > len(new_t) or old_t.keys() - new_t.keys():
            return None
        for key, st in new_t.items():
            ost = old_t.get(key)
            if ost is None:
                changed.append((acc_t, key, None, st))
            elif (
                st.calls != ost.calls
                or st.total_ns != ost.total_ns
                or st.min_ns != ost.min_ns
                or st.max_ns != ost.max_ns
            ):
                if (
                    st.calls < ost.calls
                    or st.total_ns < ost.total_ns
                    or st.min_ns > ost.min_ns
                    or st.max_ns < ost.max_ns
                    or key not in acc_t
                ):
                    return None
                changed.append((acc_t, key, ost, st))
    for acc_t, key, ost, st in changed:
        if ost is None:
            _acc_row(acc_t, key, st)
        else:
            row = acc_t[key]
            row.calls += st.calls - ost.calls
            row.total_ns += st.total_ns - ost.total_ns
            if st.min_ns < row.min_ns:
                row.min_ns = st.min_ns
            if st.max_ns > row.max_ns:
                row.max_ns = st.max_ns
    acc.hostnames |= new.hostnames
    acc.processes |= new.processes
    acc.threads |= new.threads
    acc.discarded += new.discarded - old.discarded
    return len(changed)


def _delta_update_ops(acc: Tally, prev: Tally, delta: dict) -> Optional[int]:
    """Apply a v2 delta frame's change to accumulator ``acc``.

    The delta already names exactly the changed rows (with full cumulative
    values), so this is O(changed) with no table scan at all — the steady-
    state ingest path.  ``prev`` is the source's stored tally *before*
    ``apply_delta`` runs.  Same None-means-rebuild contract as
    :func:`_tally_update_ops`: validation — including structural validation
    of a possibly version-skewed frame — completes before the first
    mutation, so None never leaves ``acc`` half-updated.
    """
    changed = []
    try:
        for acc_t, prev_t, rows in (
            (acc.apis, prev.apis, delta["apis"]),
            (acc.device_apis, prev.device_apis, delta["device_apis"]),
        ):
            for p, a, c, t, mn, mx in rows:
                key = intern_key(p, a)
                ost = prev_t.get(key)
                if ost is not None and (
                    c < ost.calls
                    or t < ost.total_ns
                    or mn > ost.min_ns
                    or mx < ost.max_ns
                    or key not in acc_t
                ):
                    return None
                changed.append((acc_t, key, ost, c, t, mn, mx))
        hostnames = set(delta["hostnames"])
        processes = set(delta["processes"])
        threads = {tuple(x) for x in delta["threads"]}
        nd = int(delta["discarded"])
    except (KeyError, TypeError, ValueError):
        return None  # malformed frame: rebuild rather than trust it
    if nd < prev.discarded:
        return None
    for acc_t, key, ost, c, t, mn, mx in changed:
        if ost is None:
            row = acc_t.get(key)
            if row is None:
                acc_t[key] = ApiStat(calls=c, total_ns=t, min_ns=mn, max_ns=mx)
            else:
                row.calls += c
                row.total_ns += t
                if mn < row.min_ns:
                    row.min_ns = mn
                if mx > row.max_ns:
                    row.max_ns = mx
        else:
            row = acc_t[key]
            row.calls += c - ost.calls
            row.total_ns += t - ost.total_ns
            if mn < row.min_ns:
                row.min_ns = mn
            if mx > row.max_ns:
                row.max_ns = mx
    acc.hostnames |= hostnames
    acc.processes |= processes
    acc.threads |= threads
    acc.discarded += nd - prev.discarded
    return len(changed)


# ---------------------------------------------------------------------------
# Master daemon (local or global, depending on forward_to)
# ---------------------------------------------------------------------------


class _SourceEntry:
    """One source's stored state: connection generation, seq, tally, receipt
    time.  ``gen`` scopes the seq chain to the connection that produced it —
    a reconnecting sender restarts seq at 0 on a new gen, and its full
    snapshot must not be dropped as stale against the old chain.
    ``version`` stamps every state update; ``snap`` caches a frozen copy of
    the tally at ``snap_version`` so per-rank reads refresh only the sources
    that changed since the last read (O(changed), not O(ranks × rows)).
    ``incarnation`` scopes the whole entry to one incarnation of the source
    identity (elastic replacement): a frame from a lower incarnation is
    fenced, a higher one atomically replaces the entry."""

    __slots__ = (
        "gen",
        "seq",
        "tally",
        "ts",
        "version",
        "snap",
        "snap_version",
        "telemetry",
        "incarnation",
        "retired",
    )

    def __init__(self, gen: Optional[int], seq: int, tally: Tally, ts: float):
        self.gen = gen
        self.seq = seq
        self.tally = tally
        self.ts = ts
        self.version = 0
        self.snap: Optional[Tally] = None
        self.snap_version = -1
        #: latest device-telemetry dict shipped alongside this source's
        #: frames (optional wire key; None until the first carrying frame)
        self.telemetry: Optional[dict] = None
        #: incarnation number of the sender that produced this state
        self.incarnation = 0
        #: tombstone flag: the rank was evicted (and possibly replaced) —
        #: its contribution still counts, readers render it distinctly
        self.retired = False


class _Tenant:
    """One tenant's complete namespace inside a master: sources, composite
    cache, rollup groups, subscriber count.  Everything a client can read is
    scoped here, so tenant A's queries can never observe tenant B's state —
    isolation is structural, not filtered.  All fields are guarded by the
    owning master's ``_lock``."""

    __slots__ = (
        "name",
        "latest",
        "dirty_srcs",
        "version",
        "comp",
        "comp_dirty",
        "group_tallies",
        "group_members",
        "group_dirty",
        "src_group",
        "subscribers",
    )

    def __init__(self, name: str):
        self.name = name
        #: source → stored state (gen, seq, cumulative tally, receipt time)
        self.latest: Dict[str, _SourceEntry] = {}
        #: sources updated since the last successful upstream flush
        self.dirty_srcs: set = set()
        self.version = 0  # bumped per state update; gates subscription pushes
        #: incrementally-maintained composite + rebuild flag
        self.comp: Optional[Tally] = None
        self.comp_dirty = True
        #: rollup state: group id → running tally, members, rebuild flags
        self.group_tallies: Dict[str, Tally] = {}
        self.group_members: Dict[str, set] = {}
        self.group_dirty: set = set()
        self.src_group: Dict[str, str] = {}
        #: live subscriber count (quota enforcement)
        self.subscribers = 0


class MasterServer:
    """Streaming master: latest-state-per-source store + monoid merge.

    * leaf ranks (or child masters) connect and push ``snapshot`` / ``delta``
      frames; deltas are merged into the stored cumulative state
      incrementally (a per-key replace — applying frame *k* to state *k-1*
      reproduces the sender's cumulative tally exactly);
    * a delta whose ``base_seq`` doesn't match the stored state is dropped
      and answered with ``resync`` so the sender falls back to a full
      snapshot — the composite is never built from a mis-based delta;
    * any client may send ``query`` and gets the current composite back,
      ``query_ranks`` for the per-source breakdown, or ``subscribe``
      (optionally ``by_rank``) to have composites pushed periodically;
    * with ``forward_to=`` set this is a *local* master: a forwarder thread
      periodically pushes state upstream (delta-encoded like any other
      stream), making the whole arrangement the live fanout tree of §3.7.
      With ``forward_ranks`` (the default) it forwards each origin source's
      tally on its own multiplexed frame chain, so the per-rank breakdown —
      the signal cluster-scope policies need — survives every hop of the
      tree; with ``forward_ranks=False`` it collapses to one composite
      source upstream (the v2.0 behavior: cheaper at the root, anonymous).
    """

    def __init__(
        self,
        port: int = 0,
        host: str = "127.0.0.1",
        forward_to: Optional[Union[str, Tuple[str, int]]] = None,
        forward_period_s: float = 0.5,
        fanout: int = 32,
        source: Optional[str] = None,
        forward_delta: bool = True,
        forward_resync_every: int = 32,
        forward_ranks: bool = True,
        rollup_groups: Union[None, str, int, "Callable[[str], str]"] = None,
        composite_cache: bool = True,
        options: Optional[ServeOptions] = None,
    ):
        self.host = host
        self.port = port  # rebound to the real port at start()
        if options is None:
            # legacy keyword construction: fold the scattered knobs into a
            # ServeOptions so there is exactly one source of truth below
            options = ServeOptions(
                fanout=fanout,
                forward_ranks=forward_ranks,
                forward_delta=forward_delta,
                forward_resync_every=forward_resync_every,
                rollup_groups=rollup_groups,
                composite_cache=composite_cache,
            )
        self.options = options
        # mirrored views of the options (long-standing public attributes)
        self.fanout = options.fanout
        self.forward_to = forward_to
        self.forward_period_s = forward_period_s
        self.forward_delta = options.forward_delta
        self.forward_resync_every = options.forward_resync_every
        self.forward_ranks = options.forward_ranks
        #: node-level pre-aggregation (>1k-rank trees): group sources into
        #: rollup tallies maintained incrementally on ingest.  ``"host"``
        #: groups by the host part of ``host:pid:rankN`` source ids; an int N
        #: buckets rank indices N-at-a-time (``group0`` = ranks 0..N-1); a
        #: callable maps source id → group id.  None disables rollups.
        self.rollup_groups = options.rollup_groups
        #: maintain the composite incrementally on ingest (O(changed) per
        #: read).  False restores the rebuild-per-read behavior — the
        #: benchmark baseline and an escape hatch, not a recommended mode.
        self.composite_cache = options.composite_cache
        self.source = source or f"master:{socket.gethostname()}:{os.getpid()}"
        #: tenant id → complete per-tenant namespace (sources, composite
        #: cache, rollups, subscriber count); non-default tenants are
        #: created on first touch, the default one eagerly (so the
        #: `_latest` compatibility view is a lock-free read)
        self._tenants: Dict[str, _Tenant] = {DEFAULT_TENANT: _Tenant(DEFAULT_TENANT)}
        self._conn_gen = 0  # connection-generation counter (gen scope)
        self._lock = threading.Lock()
        self._dirty = False
        #: server-side TLS context (built eagerly: bad cert paths fail at
        #: construction, not on the first connection)
        self._tls = options.build_server_ssl()
        self.frames = 0
        self.snapshots = 0  # state updates ingested (full + delta)
        self.full_snapshots = 0
        self.deltas = 0
        self.resyncs_sent = 0
        self.queries = 0
        self.comp_row_ops = 0  # ApiStat row merges spent maintaining/rebuilding
        self.comp_rebuilds = 0  # full composite rebuilds (non-monotone fallback)
        self.comp_incremental = 0  # ingests applied incrementally
        # hardened-tier counters
        self.auth_failures = 0  # bad/missing token, or frames before auth
        self.tls_failures = 0  # TLS handshakes that did not complete
        self.quota_src_rejects = 0  # snapshots refused: tenant source quota
        self.quota_row_rejects = 0  # frames refused: tally row quota
        self.quota_sub_rejects = 0  # subscribes refused: subscriber quota
        # elastic-replacement counters
        self.fence_rejects = 0  # frames refused: superseded incarnation
        self.source_gc = 0  # long-dead sources collected (options.source_ttl_s)
        self._gc_next = 0.0  # next TTL sweep (throttled; guarded by _lock)
        self._lsock: Optional[socket.socket] = None
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._forwarder: Optional[SnapshotStreamer] = None
        self._hub = _BroadcastHub(self)

    def _tenant_locked(self, name: str) -> _Tenant:
        tn = self._tenants.get(name)
        if tn is None:
            tn = self._tenants[name] = _Tenant(name)
        return tn

    @property
    def _latest(self) -> Dict[str, _SourceEntry]:
        """Default tenant's source store (single-tenant compatibility view).

        Deliberately lock-free (the default tenant always exists): callers
        that mutate it — tests simulating master-side state loss — hold
        ``m._lock`` themselves, and taking it here would deadlock them.
        """
        return self._tenants[DEFAULT_TENANT].latest

    @property
    def sub_encodes(self) -> int:
        """Composite serializations spent on subscribers (once per tenant
        per update, regardless of subscriber count — the hub invariant)."""
        return self._hub.encodes

    @property
    def sub_frames(self) -> int:
        """Frames enqueued to subscribers (encode-shared fanout)."""
        return self._hub.frames_out

    @property
    def sub_evictions(self) -> int:
        """Slow subscribers evicted on queue overflow."""
        return self._hub.evictions

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "MasterServer":
        """Bind, start the acceptor (and forwarder, for local masters)."""
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self.host, self.port))
        ls.listen(128)
        self._lsock = ls
        self.port = ls.getsockname()[1]
        self._stop_evt.clear()
        acceptor = threading.Thread(
            target=self._accept_loop, name="thapi-master-accept", daemon=True
        )
        acceptor.start()
        self._threads.append(acceptor)
        self._hub.start()
        if self.forward_to is not None:
            self._forwarder = SnapshotStreamer(
                self.forward_to,
                source=self.source,
                delta=self.forward_delta,
                resync_every=self.forward_resync_every,
                token=self.options.forward_token,
                ssl_context=self.options.build_forward_ssl(),
                connect_retries=self.options.connect_retries,
                connect_backoff_s=self.options.connect_backoff_s,
            )
            fwd = threading.Thread(
                target=self._forward_loop, name="thapi-master-forward", daemon=True
            )
            fwd.start()
            self._threads.append(fwd)
        return self

    def stop(self) -> None:
        """Flush upstream (local masters), close every connection, join threads."""
        self._stop_evt.set()
        if self._lsock is not None:
            try:
                # shutdown() wakes an acceptor blocked in accept(); close()
                # alone leaves it pinning the listening socket (and the
                # port) for the life of the process — a restarted master
                # could then never rebind the same port
                self._lsock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._lsock.close()
            except OSError:
                pass
            self._lsock = None
        if self._forwarder is not None:
            self.flush(force=True)  # last composite must reach the parent
            self._forwarder.close()
        self._hub.stop()
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = list(self._threads), []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        for t in threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "MasterServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def addr(self) -> str:
        """``host:port`` once started (``port=0`` is rebound at start)."""
        return f"{self.host}:{self.port}"

    @property
    def forwarder(self) -> Optional[SnapshotStreamer]:
        """The upstream push client (local masters only), for its counters."""
        return self._forwarder

    # -- state ---------------------------------------------------------------
    def submit(
        self,
        source: str,
        tally: Union[Tally, dict],
        seq: Optional[int] = None,
        gen: Optional[int] = None,
        tenant: str = DEFAULT_TENANT,
        telemetry: Optional[dict] = None,
        incarnation: int = 0,
    ) -> bool:
        """Ingest a full cumulative snapshot (socket handlers and the
        in-process tracer both land here). Out-of-order frames
        (seq < stored, same connection generation and incarnation) are stale
        duplicates of state we already supersede — dropped.  A frame from a
        *different* generation (reconnect, new session) always replaces: its
        snapshot is cumulative truth and its seq chain starts over.

        Incarnation fencing (elastic replacement): a frame whose
        ``incarnation`` is lower than the stored one comes from a superseded
        zombie — dropped and counted in ``fence_rejects``; a higher one
        atomically replaces the whole per-source state (seq chain, tally,
        telemetry), so the replacement's contribution can never be mixed
        with its predecessor's.

        Returns True when the state was stored.  False means the frame was
        dropped — a stale duplicate, a fenced incarnation, or a quota
        rejection for ``tenant`` (a *new* source past ``max_sources``, or a
        tally wider than ``max_tally_rows``; counted in the ``quota_*``
        stats).

        The master takes ownership of ``tally`` — callers must not mutate it
        afterwards (the incremental composite diffs stored states)."""
        if not isinstance(tally, Tally):
            tally = Tally.from_obj(tally)
        opts = self.options
        incarnation = int(incarnation)
        with self._lock:
            tn = self._tenant_locked(tenant)
            self._gc_sweep_locked()
            prev = tn.latest.get(source)
            if prev is not None and incarnation < prev.incarnation:
                self.fence_rejects += 1
                logger.warning(
                    "tenant %r: fenced snapshot from %r incarnation %d "
                    "(current %d)",
                    tenant,
                    source,
                    incarnation,
                    prev.incarnation,
                )
                return False
            if (
                prev is not None
                and incarnation == prev.incarnation
                and seq is not None
                and gen == prev.gen
                and seq < prev.seq
            ):
                return False
            if prev is None and opts.max_sources and len(tn.latest) >= opts.max_sources:
                self.quota_src_rejects += 1
                logger.warning(
                    "tenant %r: rejected new source %r (source quota %d reached)",
                    tenant,
                    source,
                    opts.max_sources,
                )
                return False
            if opts.max_tally_rows and (
                len(tally.apis) + len(tally.device_apis) > opts.max_tally_rows
            ):
                self.quota_row_rejects += 1
                logger.warning(
                    "tenant %r: rejected snapshot from %r (%d rows > quota %d)",
                    tenant,
                    source,
                    len(tally.apis) + len(tally.device_apis),
                    opts.max_tally_rows,
                )
                return False
            nseq = seq if seq is not None else (prev.seq + 1 if prev is not None else 0)
            old = prev.tally if prev is not None else None
            entry = tn.latest[source] = _SourceEntry(gen, nseq, tally, time.time())
            entry.incarnation = incarnation
            # a frame without telemetry keeps the last-known sample (leaf
            # pushes attach it every tick; forwarded chains may interleave)
            # — but never across an incarnation swap: the replacement's
            # telemetry starts clean, a zombie's vitals must not survive it
            same_inc = prev is not None and prev.incarnation == incarnation
            entry.telemetry = (
                dict(telemetry)
                if telemetry is not None
                else (prev.telemetry if same_inc else None)
            )
            # an admitted frame un-retires the row: the rank is live again
            entry.retired = prev.retired if same_inc else False
            self.snapshots += 1
            self.full_snapshots += 1
            self._dirty = True
            tn.dirty_srcs.add(source)
            tn.version += 1
            self._caches_note_update_locked(tn, source, old, tally, None)
        return True

    def submit_delta(
        self,
        source: str,
        delta: dict,
        seq: int,
        base_seq: int,
        gen: Optional[int] = None,
        tenant: str = DEFAULT_TENANT,
        telemetry: Optional[dict] = None,
        incarnation: int = 0,
    ) -> bool:
        """Ingest a delta frame; True if applied.

        Applies only when the stored state for ``source`` is exactly
        ``base_seq`` on the same connection generation *and incarnation* —
        anything else (unknown source after a master restart, a duplicate,
        an out-of-order frame, a reset seq, a different connection's chain)
        is rejected so the stored cumulative state is never corrupted; the
        socket handler then answers ``resync``.  A delta from a *lower*
        incarnation than stored is a zombie's late frame: counted in
        ``fence_rejects`` and dropped with **no** resync — a superseded
        sender must be cut off, not coached back into the fold.  A delta
        that would grow the stored tally past the tenant's
        ``max_tally_rows`` quota is rejected the same way as a chain
        mismatch (the follow-up full snapshot is then bounced by
        :meth:`submit`, so an over-quota source parks at its last admitted
        state).
        """
        opts = self.options
        incarnation = int(incarnation)
        with self._lock:
            tn = self._tenant_locked(tenant)
            self._gc_sweep_locked()
            prev = tn.latest.get(source)
            if prev is not None and incarnation < prev.incarnation:
                self.fence_rejects += 1
                logger.warning(
                    "tenant %r: fenced delta from %r incarnation %d (current %d)",
                    tenant,
                    source,
                    incarnation,
                    prev.incarnation,
                )
                return False
            if (
                prev is None
                or prev.gen != gen
                or prev.seq != base_seq
                or prev.incarnation != incarnation
            ):
                return False
            if opts.max_tally_rows:
                try:
                    grown = sum(
                        1
                        for prev_t, rows in (
                            (prev.tally.apis, delta["apis"]),
                            (prev.tally.device_apis, delta["device_apis"]),
                        )
                        for p, a, *_ in rows
                        if intern_key(p, a) not in prev_t
                    )
                except (KeyError, TypeError, ValueError):
                    return False  # malformed frame: ask for a resync
                rows = len(prev.tally.apis) + len(prev.tally.device_apis) + grown
                if rows > opts.max_tally_rows:
                    self.quota_row_rejects += 1
                    logger.warning(
                        "tenant %r: rejected delta from %r (%d rows > quota %d)",
                        tenant,
                        source,
                        rows,
                        opts.max_tally_rows,
                    )
                    return False
            # caches diff against the pre-apply state, so feed them first —
            # a delta names exactly the changed rows, the O(changed) path
            self._caches_note_update_locked(tn, source, prev.tally, None, delta)
            prev.tally.apply_delta(delta)
            prev.seq = seq
            prev.ts = time.time()
            prev.version += 1
            prev.snap = None  # stale frozen copy: re-snapped on next read
            if telemetry is not None:
                prev.telemetry = dict(telemetry)
            self.snapshots += 1
            self.deltas += 1
            self._dirty = True
            tn.dirty_srcs.add(source)
            tn.version += 1
        return True

    def _reset_seq(self, source: str, tenant: str = DEFAULT_TENANT) -> None:
        with self._lock:
            prev = self._tenant_locked(tenant).latest.get(source)
            if prev is not None:
                # keep the last tally but accept any future seq from it
                prev.seq = -1

    def incarnation_of(self, source: str, tenant: str = DEFAULT_TENANT) -> int:
        """Stored incarnation for ``source`` (-1 when the source is unknown).

        The socket handler uses this to tell a *fenced* rejection (frame
        incarnation < stored: answer ``error`` code ``"fence"`` and drop the
        connection) from an ordinary stale/mis-based drop (answer
        ``resync``)."""
        with self._lock:
            prev = self._tenant_locked(tenant).latest.get(source)
            return prev.incarnation if prev is not None else -1

    def retire_source(self, source: str, tenant: str = DEFAULT_TENANT) -> bool:
        """Tombstone ``source``: the rank was evicted from the mesh.

        Its cumulative contribution keeps counting toward the composite
        (the work it did is real), but per-rank readers see it flagged
        ``retired`` so UIs render the row as a tombstone instead of a live
        rank.  A frame from a *newer* incarnation un-retires the row (the
        replacement took over); same-incarnation frames — e.g. a drain's
        final flush racing the eviction — keep the flag.  Returns False for
        an unknown source."""
        with self._lock:
            tn = self._tenant_locked(tenant)
            prev = tn.latest.get(source)
            if prev is None:
                return False
            if not prev.retired:
                prev.retired = True
                tn.version += 1  # subscribers re-push with the tombstone
            return True

    def _gc_sweep_locked(self) -> None:
        """TTL sweep (``options.source_ttl_s``): drop sources whose last
        frame is older than the TTL — across every tenant.  Throttled to one
        sweep per TTL/4 so the ingest path never pays a per-frame scan;
        caller holds ``_lock``.  Collected sources leave the composite and
        rollup caches (dirty → rebuilt on next read) and bump
        ``source_gc``."""
        ttl = self.options.source_ttl_s
        if not ttl:
            return
        now = time.time()
        if now < self._gc_next:
            return
        self._gc_next = now + max(0.25, ttl / 4.0)
        for tn in self._tenants.values():
            dead = [src for src, e in tn.latest.items() if now - e.ts > ttl]
            for src in dead:
                del tn.latest[src]
                tn.dirty_srcs.discard(src)
                g = tn.src_group.pop(src, None)
                if g is not None:
                    members = tn.group_members.get(g)
                    if members is not None:
                        members.discard(src)
                    tn.group_dirty.add(g)
                self.source_gc += 1
                logger.info(
                    "tenant %r: collected dead source %r (no frames for > %.1fs)",
                    tn.name,
                    src,
                    ttl,
                )
            if dead:
                tn.comp_dirty = True
                tn.version += 1

    # -- cache maintenance (all called under self._lock) ---------------------
    def _caches_note_update_locked(
        self,
        tn: _Tenant,
        source: str,
        old: Optional[Tally],
        new: Optional[Tally],
        delta: Optional[dict],
    ) -> None:
        """Fold one source update into the tenant's composite/rollup caches.

        Exactly one of ``new`` (full snapshot replacing ``old``) or ``delta``
        (v2 delta about to be applied to ``old``) is set.  Monotone growth is
        applied incrementally — O(changed rows); anything else flips the
        affected cache to dirty and the next read rebuilds.
        """
        if self.composite_cache and not tn.comp_dirty and tn.comp is not None:
            ops = self._apply_to_acc(tn.comp, old, new, delta)
            if ops is None:
                tn.comp_dirty = True
            else:
                self.comp_row_ops += ops
                self.comp_incremental += 1
        else:
            tn.comp_dirty = True
        if self.rollup_groups is not None:
            g = self._group_of_locked(tn, source)
            tn.group_members.setdefault(g, set()).add(source)
            gt = tn.group_tallies.get(g)
            if g in tn.group_dirty:
                return
            if gt is None:
                # first update for this group: seed from the change itself
                # (old is None on a brand-new source; otherwise seed dirty)
                if old is None and new is not None:
                    seeded = Tally()
                    _tally_update_ops(seeded, None, new)
                    tn.group_tallies[g] = seeded
                else:
                    tn.group_dirty.add(g)
                return
            if self._apply_to_acc(gt, old, new, delta) is None:
                tn.group_dirty.add(g)

    @staticmethod
    def _apply_to_acc(
        acc: Tally, old: Optional[Tally], new: Optional[Tally], delta: Optional[dict]
    ) -> Optional[int]:
        if delta is not None:
            assert old is not None
            return _delta_update_ops(acc, old, delta)
        assert new is not None
        return _tally_update_ops(acc, old, new)

    def _comp_copies_locked(self, tn: _Tenant) -> Tuple[List[Tally], int]:
        """Rebuild input: per-source copies + the row-op count, one lock hold."""
        ops = sum(
            len(e.tally.apis) + len(e.tally.device_apis)
            for e in tn.latest.values()
        )
        return [Tally().merge(e.tally) for e in tn.latest.values()], ops

    def _finish_rebuild(
        self, tn: _Tenant, copies: List[Tally], ops: int, version: int
    ) -> Tally:
        """Merge a rebuild's source copies *outside* the lock (ingest never
        stalls behind an O(ranks × rows) merge), then store the result as the
        cache only if no ingest landed mid-rebuild (``version`` unchanged —
        a stale store would silently drop those updates).  Rebuilds go
        through the same ``fanout``-ary tree merge as the offline
        ``aggregate_tree`` (merge math is associative, so fanout shapes the
        work, never the result).  Returns a tally the caller owns."""
        if copies:
            comp, _ = merge_tallies(copies, fanout=self.fanout)
        else:
            comp = Tally()
        with self._lock:
            self.comp_rebuilds += 1
            self.comp_row_ops += ops
            if self.composite_cache and tn.version == version:
                tn.comp = comp
                tn.comp_dirty = False
                return Tally().merge(comp)
        # cache disabled, or state moved mid-rebuild (comp is still a
        # consistent read of the snapshot we copied): hand it out uncached
        return comp

    def _ranks_snapshot_locked(self, tn: _Tenant) -> Dict[str, Tally]:
        """Frozen per-source copies, refreshed only for sources whose state
        changed since the last read (version-stamped).  The returned tallies
        are shared snapshots: replaced wholesale on change, never mutated in
        place — safe to serialize or merge outside the lock, never to edit."""
        out = {}
        for src, e in tn.latest.items():
            if e.snap is None or e.snap_version != e.version:
                e.snap = Tally().merge(e.tally)
                e.snap_version = e.version
            out[src] = e.snap
        return out

    def _group_of_locked(self, tn: _Tenant, source: str) -> str:
        g = tn.src_group.get(source)
        if g is None:
            rg = self.rollup_groups
            if callable(rg):
                g = str(rg(source))
            elif isinstance(rg, int) and not isinstance(rg, bool):
                # host:pid:rankN → bucket rank indices rg-at-a-time
                tail = source.rpartition("rank")[2]
                if tail.isdigit():
                    g = f"group{int(tail) // max(1, rg)}"
                else:
                    g = source.partition(":")[0] or source
            else:  # "host" (the default string form)
                g = source.partition(":")[0] or source
            tn.src_group[source] = g
        return g

    def _rebuild_group_locked(self, tn: _Tenant, g: str) -> None:
        t = Tally()
        for src in tn.group_members.get(g, ()):
            e = tn.latest.get(src)
            if e is not None:
                t.merge(e.tally)
        tn.group_tallies[g] = t
        tn.group_dirty.discard(g)

    def _groups_locked(self, tn: _Tenant) -> Dict[str, Tally]:
        for g in list(tn.group_dirty):
            self._rebuild_group_locked(tn, g)
        return tn.group_tallies

    # -- reads ---------------------------------------------------------------
    def composite(self, tenant: str = DEFAULT_TENANT) -> Tally:
        """The merged cluster profile of one tenant, O(changed) in steady
        state.

        Maintained incrementally on ingest (full snapshots diff against the
        replaced state, deltas apply their changed rows directly), so a read
        copies the cached composite — O(distinct API rows) — instead of
        re-merging every source's whole table (O(ranks × rows), the
        pre-cache behavior, still reachable via ``composite_cache=False``).
        The returned tally is the caller's to mutate."""
        with self._lock:
            tn = self._tenant_locked(tenant)
            if self.composite_cache and tn.comp is not None and not tn.comp_dirty:
                return Tally().merge(tn.comp)
            version = tn.version
            copies, ops = self._comp_copies_locked(tn)
        return self._finish_rebuild(tn, copies, ops, version)

    def ranks(self, copy: bool = True, tenant: str = DEFAULT_TENANT) -> Dict[str, Tally]:
        """Per-source breakdown: source id → its latest cumulative tally.
        The data ``query_ranks`` serves and cluster-scope policies consume;
        merging all values reproduces :meth:`composite`.

        ``copy=True`` (default) returns defensive copies the caller owns.
        ``copy=False`` returns the version-stamped frozen snapshots — only
        sources that changed since the last read are re-copied (O(changed)),
        but callers must treat the tallies as read-only."""
        with self._lock:
            self._gc_sweep_locked()
            snap = self._ranks_snapshot_locked(self._tenant_locked(tenant))
            if copy:
                return {src: Tally().merge(t) for src, t in snap.items()}
            return dict(snap)

    def telemetry(self, tenant: str = DEFAULT_TENANT) -> Dict[str, dict]:
        """Per-source device telemetry: source id → its latest telemetry
        dict (host RSS, device memory pressure, transfer bandwidths — the
        fields in docs/streaming.md).  Sources whose frames never carried
        telemetry are absent.  Returns copies the caller owns — the same
        evidence ``query_ranks`` serves in its ``telemetry`` key and
        sick-host policies consume."""
        with self._lock:
            tn = self._tenant_locked(tenant)
            return {
                src: dict(e.telemetry)
                for src, e in tn.latest.items()
                if e.telemetry is not None
            }

    def groups(self, tenant: str = DEFAULT_TENANT) -> Dict[str, Tally]:
        """Rollup breakdown: group id → aggregated member tally (empty when
        ``rollup_groups`` is off).  Group tallies are maintained
        incrementally on ingest — the pre-aggregation layer that keeps
        >1k-rank trees readable: policies and upstream forwarding touch
        O(groups) tallies instead of O(ranks).  Returns defensive copies
        (group accumulators mutate in place on ingest, so — unlike the
        per-source snapshots — they can never be handed out uncopied)."""
        if self.rollup_groups is None:
            return {}
        with self._lock:
            tn = self._tenant_locked(tenant)
            return {g: Tally().merge(t) for g, t in self._groups_locked(tn).items()}

    def stats(self) -> dict:
        """Counters for monitoring: sources, frame/snapshot/delta/query
        totals, resyncs sent, composite-cache row-ops/rebuilds, rollup
        group count, last-update wall clock, forwarding role, plus the
        hardened-tier counters (auth/TLS failures, per-quota rejects,
        subscriber hub encode/fanout/eviction totals) and a ``per_tenant``
        source/subscriber breakdown.  Top-level ``sources``/``updated``/
        ``groups`` aggregate across tenants, so single-tenant callers see
        the historical shape unchanged."""
        with self._lock:
            self._gc_sweep_locked()
            per_tenant = {
                name: {
                    "sources": len(tn.latest),
                    "subscribers": tn.subscribers,
                    "updated": max((e.ts for e in tn.latest.values()), default=0.0),
                }
                for name, tn in self._tenants.items()
            }
        sources = sum(t["sources"] for t in per_tenant.values())
        subscribers = sum(t["subscribers"] for t in per_tenant.values())
        updated = max((t["updated"] for t in per_tenant.values()), default=0.0)
        with self._lock:
            groups = (
                sum(len(tn.group_members) for tn in self._tenants.values())
                if self.rollup_groups is not None
                else 0
            )
        return {
            "sources": sources,
            "frames": self.frames,
            "snapshots": self.snapshots,
            "full_snapshots": self.full_snapshots,
            "deltas": self.deltas,
            "resyncs": self.resyncs_sent,
            "queries": self.queries,
            "comp_row_ops": self.comp_row_ops,
            "comp_rebuilds": self.comp_rebuilds,
            "comp_incremental": self.comp_incremental,
            "groups": groups,
            "updated": updated,
            "forwarding": self.forward_to is not None,
            "tls": self._tls is not None,
            "auth": self.options.auth_required,
            "tenants": len(per_tenant),
            "per_tenant": per_tenant,
            "subscribers": subscribers,
            "auth_failures": self.auth_failures,
            "tls_failures": self.tls_failures,
            "quota_src_rejects": self.quota_src_rejects,
            "quota_row_rejects": self.quota_row_rejects,
            "quota_sub_rejects": self.quota_sub_rejects,
            "fence_rejects": self.fence_rejects,
            "source_gc": self.source_gc,
            "sub_encodes": self._hub.encodes,
            "sub_heartbeats": self._hub.heartbeats,
            "sub_frames": self._hub.frames_out,
            "sub_evictions": self._hub.evictions,
        }

    def flush(self, force: bool = False) -> bool:
        """Push state upstream now (local masters only): rollup-group
        tallies when ``rollup_groups`` is set (the pre-aggregated form —
        O(groups) upstream sources instead of O(ranks)), else the per-rank
        breakdown when ``forward_ranks``, else the merged composite.

        Forwarding is scoped to ``options.forward_tenant`` (the default
        tenant unless configured): interior hops of a master tree are
        single-tenant infrastructure, and tenant isolation at the serving
        edge must not leak other tenants' state upstream implicitly."""
        if self._forwarder is None:
            return False
        ftenant = self.options.forward_tenant
        with self._lock:
            tn = self._tenant_locked(ftenant)
            if not tn.latest or (not self._dirty and not force):
                return False
            self._dirty = False
        if self.rollup_groups is not None and self.forward_ranks:
            with self._lock:
                gro = self._groups_locked(tn)
                if force:
                    gs = list(gro)
                else:
                    gs = sorted(
                        {self._group_of_locked(tn, src) for src in tn.dirty_srcs}
                    )
                tn.dirty_srcs.clear()
                # group accumulators mutate in place on ingest: copy under
                # the lock, push outside it
                copies = {g: Tally().merge(gro[g]) for g in gs if g in gro}
            ok = True
            for g, tally in copies.items():
                ok = self._forwarder.push(
                    tally, source=g, skip_unchanged=not force
                ) and ok
            if not ok:
                with self._lock:
                    # parent unreachable: re-arm the failed groups' members
                    # so their state is re-forwarded when the parent returns
                    self._dirty = True
                    for g in copies:
                        tn.dirty_srcs.update(tn.group_members.get(g, ()))
        elif self.forward_ranks:
            with self._lock:
                # only updated sources are forwarded, via the version-stamped
                # frozen snapshots (no per-flush deep copies); a forced
                # (stop-path) flush re-sends every source in full
                snaps = self._ranks_snapshot_locked(tn)
                srcs = list(snaps) if force else list(tn.dirty_srcs)
                tn.dirty_srcs.clear()
                copies = {src: snaps[src] for src in srcs if src in snaps}
                telem = {
                    src: e.telemetry
                    for src, e in tn.latest.items()
                    if src in copies and e.telemetry is not None
                }
                # origin incarnations ride each forwarded chain, so the
                # fence holds at every level of the master tree
                incs = {
                    src: e.incarnation
                    for src, e in tn.latest.items()
                    if src in copies
                }
            ok = True
            for src, tally in copies.items():
                ok = self._forwarder.push(
                    tally,
                    source=src,
                    skip_unchanged=not force,
                    telemetry=telem.get(src),
                    incarnation=incs.get(src, 0),
                ) and ok
            if not ok:
                with self._lock:
                    # parent unreachable: re-arm the failed sources so their
                    # state is re-forwarded once the parent comes back
                    self._dirty = True
                    tn.dirty_srcs.update(copies)
        else:
            ok = self._forwarder.push(self.composite(tenant=ftenant))
            if not ok:
                with self._lock:
                    self._dirty = True
        return ok

    # -- threads -------------------------------------------------------------
    def _accept_loop(self) -> None:
        ls = self._lsock
        while not self._stop_evt.is_set():
            try:
                conn, _peer = ls.accept()
            except OSError:
                break
            t = threading.Thread(
                target=self._client_loop, args=(conn,), name="thapi-master-conn", daemon=True
            )
            with self._lock:
                self._threads.append(t)
            t.start()

    def _send_error(self, conn: socket.socket, code: str, detail: str) -> None:
        """Best-effort rejection frame; the connection closes right after."""
        try:
            conn.sendall(
                pack_frame(
                    {
                        "type": "error",
                        "v": PROTOCOL_VERSION,
                        "error": code,
                        "detail": detail,
                    }
                )
            )
        except OSError:
            pass

    def _client_loop(self, conn: socket.socket) -> None:
        peer = "?"
        try:
            peer = "%s:%d" % conn.getpeername()[:2]
        except OSError:
            pass
        if self._tls is not None:
            # handshake under a timeout so a plaintext/hostile client cannot
            # pin this thread; a plaintext client's first bytes fail to parse
            # as a TLS record and the handshake errors out cleanly
            try:
                conn.settimeout(5.0)
                conn = self._tls.wrap_socket(conn, server_side=True)
                conn.settimeout(None)
            except (OSError, ssl.SSLError):
                self.tls_failures += 1
                logger.warning("TLS handshake failed from %s", peer)
                try:
                    conn.close()
                except OSError:
                    pass
                with self._lock:
                    cur = threading.current_thread()
                    if cur in self._threads:
                        self._threads.remove(cur)
                return
        with self._lock:
            self._conns.append(conn)
            self._conn_gen += 1
            gen = self._conn_gen  # scopes this connection's seq chains
        # with auth on, no frame does anything until a hello carrying a
        # valid token binds the connection to a tenant
        tenant: Optional[str] = None if self.options.auth_required else DEFAULT_TENANT
        handed_off = False  # subscribe: the hub owns the socket from then on
        try:
            while not self._stop_evt.is_set():
                try:
                    msg = recv_frame(conn)
                except (ProtocolError, OSError):
                    break
                if msg is None:
                    break
                self.frames += 1
                kind = msg.get("type")
                if kind == "hello":
                    # a fresh connection restarts the peer's seq counter (e.g.
                    # a new Tracer session in the same process): forget the
                    # stored seq so its snapshots aren't dropped as stale.
                    # The ack tells v2 senders they may switch to deltas.
                    got = self.options.tenant_for(msg.get("token"))
                    if got is None:
                        self.auth_failures += 1
                        logger.warning(
                            "auth failure from %s (source %r): bad or missing token",
                            peer,
                            msg.get("source"),
                        )
                        self._send_error(conn, "auth", "invalid or missing token")
                        break
                    tenant = got
                    src = str(msg.get("source", "?"))
                    hello_inc = int(msg.get("incarnation", 0) or 0)
                    cur_inc = self.incarnation_of(src, tenant)
                    if hello_inc < cur_inc:
                        # a zombie incarnation reconnecting: fence it at the
                        # door — letting its hello through would reset the
                        # live incarnation's seq chain (_reset_seq below)
                        self.fence_rejects += 1
                        logger.warning(
                            "fenced hello from %s: %r incarnation %d "
                            "superseded by %d",
                            peer,
                            src,
                            hello_inc,
                            cur_inc,
                        )
                        self._send_error(
                            conn,
                            "fence",
                            f"incarnation {hello_inc} superseded by {cur_inc}",
                        )
                        break
                    self._reset_seq(src, tenant)
                    try:
                        conn.sendall(
                            pack_frame(
                                {
                                    "type": "hello_ack",
                                    "v": PROTOCOL_VERSION,
                                    "tenant": tenant,
                                }
                            )
                        )
                    except OSError:
                        break
                elif tenant is None:
                    self.auth_failures += 1
                    logger.warning(
                        "rejected %r frame from %s before authentication", kind, peer
                    )
                    self._send_error(
                        conn, "auth", "authenticate first: hello with token"
                    )
                    break
                elif kind == "snapshot":
                    source = str(msg.get("source", "?"))
                    inc = int(msg.get("incarnation", 0) or 0)
                    telem = msg.get("telemetry")
                    ok = self.submit(
                        source,
                        msg["tally"],
                        msg.get("seq"),
                        gen,
                        tenant=tenant,
                        telemetry=telem if isinstance(telem, dict) else None,
                        incarnation=inc,
                    )
                    if not ok and inc < self.incarnation_of(source, tenant):
                        # fenced zombie: tell it why and cut the connection
                        self._send_error(
                            conn, "fence", f"incarnation {inc} of {source} superseded"
                        )
                        break
                elif kind == "delta":
                    source = str(msg.get("source", "?"))
                    inc = int(msg.get("incarnation", 0) or 0)
                    telem = msg.get("telemetry")
                    ok = self.submit_delta(
                        source,
                        msg["delta"],
                        int(msg.get("seq", -1)),
                        int(msg.get("base_seq", -2)),
                        gen,
                        tenant=tenant,
                        telemetry=telem if isinstance(telem, dict) else None,
                        incarnation=inc,
                    )
                    if not ok:
                        if inc < self.incarnation_of(source, tenant):
                            # fenced zombie: no resync — coaching a superseded
                            # sender back to full snapshots would just feed
                            # more fenced frames; cut it off instead
                            self._send_error(
                                conn,
                                "fence",
                                f"incarnation {inc} of {source} superseded",
                            )
                            break
                        # mis-based delta: ask the sender for a full snapshot
                        # (scoped to the one source whose chain diverged)
                        self.resyncs_sent += 1
                        try:
                            conn.sendall(
                                pack_frame(
                                    {
                                        "type": "resync",
                                        "v": PROTOCOL_VERSION,
                                        "source": source,
                                    }
                                )
                            )
                        except OSError:
                            break
                elif kind == "query":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._composite_msg(tenant=tenant)))
                    except OSError:
                        break
                elif kind == "query_ranks":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._ranks_msg(tenant=tenant)))
                    except OSError:
                        break
                elif kind == "query_groups":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._groups_msg(tenant=tenant)))
                    except OSError:
                        break
                elif kind == "subscribe":
                    # hand the connection to the broadcast hub: frames are
                    # encoded once per tenant per update and fanned out to
                    # every subscriber from shared buffers
                    period = float(msg.get("period_s", 1.0))
                    by_rank = bool(msg.get("by_rank", False))
                    with self._lock:
                        tn = self._tenant_locked(tenant)
                        if (
                            self.options.max_subscribers
                            and tn.subscribers >= self.options.max_subscribers
                        ):
                            self.quota_sub_rejects += 1
                            admitted = False
                        else:
                            tn.subscribers += 1
                            admitted = True
                    if not admitted:
                        logger.warning(
                            "tenant %r: rejected subscribe from %s (quota %d)",
                            tenant,
                            peer,
                            self.options.max_subscribers,
                        )
                        self._send_error(conn, "quota", "subscriber quota reached")
                        break
                    self._hub.add(conn, tenant, period, by_rank)
                    handed_off = True
                    break
                elif kind == "ping":
                    try:
                        conn.sendall(pack_frame({"type": "pong", "v": PROTOCOL_VERSION}))
                    except OSError:
                        break
                elif kind == "bye":
                    break
                # unknown types: ignored, no reply needed
        finally:
            if not handed_off:
                try:
                    conn.close()
                except OSError:
                    pass
            # long-lived masters see many short query connections: prune, or
            # _conns/_threads grow without bound
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                cur = threading.current_thread()
                if cur in self._threads:
                    self._threads.remove(cur)

    def _forward_loop(self) -> None:
        while not self._stop_evt.wait(self.forward_period_s):
            self.flush()

    def _tenant_meta_locked(self, tn: _Tenant) -> dict:
        """Reply meta, scoped to one tenant's sources (frame/delta counters
        stay master-global: they are load telemetry, not state)."""
        return {
            "sources": len(tn.latest),
            "snapshots": self.snapshots,
            "deltas": self.deltas,
            "updated": max((e.ts for e in tn.latest.values()), default=0.0),
        }

    def _composite_msg(
        self, by_rank: bool = False, tenant: str = DEFAULT_TENANT
    ) -> dict:
        # one snapshot under one lock: a frame's composite and per-rank map
        # must describe the same instant, or a subscriber cross-checking
        # invariant 7 (per-rank sums == composite) sees spurious mismatches
        # whenever a submit races the push.  Both sides come from the
        # incremental caches — no per-query re-merge of every source — and
        # the frozen snapshots are safe to serialize outside the lock.  On
        # the rare rebuild, the source copies and the per-rank snapshot are
        # taken under the same hold (same instant) and the merge runs
        # outside the lock so ingest never stalls behind it.
        comp = None
        with self._lock:
            tn = self._tenant_locked(tenant)
            if self.composite_cache and tn.comp is not None and not tn.comp_dirty:
                comp = Tally().merge(tn.comp)
            else:
                version = tn.version
                copies, ops = self._comp_copies_locked(tn)
            snap = self._ranks_snapshot_locked(tn) if by_rank else None
            incs = (
                {src: e.incarnation for src, e in tn.latest.items()}
                if by_rank
                else None
            )
            retired = (
                [src for src, e in tn.latest.items() if e.retired]
                if by_rank
                else None
            )
            meta = self._tenant_meta_locked(tn)
        if comp is None:
            comp = self._finish_rebuild(tn, copies, ops, version)
        msg = {"type": "composite", "v": PROTOCOL_VERSION, "tally": comp.to_obj()}
        msg.update(meta)
        if by_rank:
            msg["ranks"] = {src: t.to_obj() for src, t in snap.items()}
            msg["incarnations"] = incs
            if retired:
                msg["retired"] = retired
        return msg

    def _heartbeat_msg(self, tenant: str = DEFAULT_TENANT) -> dict:
        """Tally-less ``unchanged`` frame for idle subscription periods."""
        with self._lock:
            meta = self._tenant_meta_locked(self._tenant_locked(tenant))
        msg = {"type": "composite", "v": PROTOCOL_VERSION, "unchanged": True}
        msg.update(meta)
        return msg

    def _ranks_msg(self, tenant: str = DEFAULT_TENANT) -> dict:
        """``query_ranks`` reply: the per-source tally map + receipt times."""
        with self._lock:
            tn = self._tenant_locked(tenant)
            self._gc_sweep_locked()
            snap = self._ranks_snapshot_locked(tn)
            stamps = {src: e.ts for src, e in tn.latest.items()}
            telem = {
                src: dict(e.telemetry)
                for src, e in tn.latest.items()
                if e.telemetry is not None
            }
            incs = {src: e.incarnation for src, e in tn.latest.items()}
            retired = [src for src, e in tn.latest.items() if e.retired]
            meta = self._tenant_meta_locked(tn)
        # frozen snapshots: replaced wholesale on change, safe to serialize
        # after the lock is released
        msg = {
            "type": "ranks",
            "v": PROTOCOL_VERSION,
            "ranks": {src: t.to_obj() for src, t in snap.items()},
            "ts": stamps,
            "incarnations": incs,
        }
        if telem:
            msg["telemetry"] = telem
        if retired:
            msg["retired"] = retired
        msg.update(meta)
        return msg

    def _groups_msg(self, tenant: str = DEFAULT_TENANT) -> dict:
        """``query_groups`` reply: the rollup breakdown (empty when off)."""
        gro = self.groups(tenant=tenant)
        with self._lock:
            meta = self._tenant_meta_locked(self._tenant_locked(tenant))
        msg = {
            "type": "groups",
            "v": PROTOCOL_VERSION,
            "rollup": self.rollup_groups is not None,
            "groups": {g: t.to_obj() for g, t in gro.items()},
        }
        msg.update(meta)
        return msg


# ---------------------------------------------------------------------------
# Broadcast hub: encode-once subscription fanout
# ---------------------------------------------------------------------------


class _Subscriber:
    """One subscribed connection: a bounded frame queue drained by a
    dedicated sender thread.  The hub *offers* encoded frames; the sender
    pushes them down the socket at whatever pace the client sustains.  A
    full queue means the client is not keeping up — the subscriber is
    evicted rather than allowed to stall the hub or balloon memory."""

    __slots__ = (
        "conn",
        "tenant",
        "period_s",
        "by_rank",
        "maxq",
        "queue",
        "cv",
        "closed",
        "next_due",
        "last_version",
        "thread",
    )

    def __init__(
        self,
        conn: socket.socket,
        tenant: str,
        period_s: float,
        by_rank: bool,
        maxq: int,
    ):
        self.conn = conn
        self.tenant = tenant
        self.period_s = max(0.01, float(period_s))
        self.by_rank = bool(by_rank)
        self.maxq = maxq
        self.queue: collections.deque = collections.deque()
        self.cv = threading.Condition()
        self.closed = False
        self.next_due = 0.0  # due immediately: snapshot-on-join
        self.last_version: Optional[int] = None
        self.thread: Optional[threading.Thread] = None


class _BroadcastHub:
    """Shared subscription fanout for a :class:`MasterServer`.

    Replaces the per-client ``_subscription_loop`` (one render + serialize
    per subscriber per period) with a single hub thread: each composite
    update is encoded **once per (tenant, by_rank) variant** — the encoded
    bytes are shared by every subscriber's queue, so 1 and 512 subscribers
    cost the same serialization work (``encodes`` stays flat; the stream_bw
    fanout sweep measures exactly this).  Encoded frames are version-stamped
    and cached, so a late joiner of an idle tenant reuses the last encode
    (snapshot-on-join without a re-render).

    Per-subscriber pacing (``period_s``) and change-gating are preserved
    from the old loop: an idle period ships a tiny tally-less heartbeat.
    Slow consumers are evicted on queue overflow (``evictions``) — their
    socket is shut down, which also unblocks a sender mid-``sendall``."""

    def __init__(self, master: "MasterServer"):
        self.m = master
        self._subs: List[_Subscriber] = []
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        #: (tenant, by_rank) → (tenant version, encoded composite frame)
        self._cache: Dict[Tuple[str, bool], Tuple[int, bytes]] = {}
        self.encodes = 0  # composite serializations (once per tenant/update)
        self.heartbeats = 0  # idle-period heartbeat frames built
        self.frames_out = 0  # frames enqueued across all subscribers
        self.evictions = 0  # slow subscribers dropped on queue overflow

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._loop, name="thapi-hub", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Retire every subscriber and join the hub + sender threads.
        Relies on the master's ``_stop_evt`` being set already."""
        with self._lock:
            subs, self._subs = list(self._subs), []
        for sub in subs:
            self._retire(sub)
            try:
                sub.conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._wake.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=2.0)
        for sub in subs:
            if sub.thread is not None:
                sub.thread.join(timeout=2.0)
        self._cache.clear()

    def add(
        self, conn: socket.socket, tenant: str, period_s: float, by_rank: bool
    ) -> None:
        """Adopt a connection whose client sent ``subscribe`` (the caller
        already charged the tenant's subscriber quota)."""
        sub = _Subscriber(
            conn, tenant, period_s, by_rank, self.m.options.hub_queue_frames
        )
        sub.thread = threading.Thread(
            target=self._sender, args=(sub,), name="thapi-hub-send", daemon=True
        )
        with self._lock:
            self._subs.append(sub)
        sub.thread.start()
        self._wake.set()  # first frame (snapshot-on-join) goes out now

    # -- internals ----------------------------------------------------------
    def _retire(self, sub: _Subscriber, evicted: bool = False) -> bool:
        """Close out a subscriber exactly once (uncharge quota, optionally
        count the eviction and shut the socket down to unblock its sender)."""
        with sub.cv:
            if sub.closed:
                return False
            sub.closed = True
            sub.cv.notify_all()
        with self.m._lock:
            self.m._tenant_locked(sub.tenant).subscribers -= 1
        if evicted:
            self.evictions += 1
            logger.warning(
                "evicted slow subscriber (tenant %r): %d-frame queue full",
                sub.tenant,
                sub.maxq,
            )
            try:
                sub.conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        return True

    def _offer(self, sub: _Subscriber, frame: bytes) -> None:
        with sub.cv:
            if sub.closed:
                return
            if len(sub.queue) < sub.maxq:
                sub.queue.append(frame)
                self.frames_out += 1
                sub.cv.notify_all()
                return
        # queue full: the client is slower than its own requested period —
        # evict instead of stalling the hub behind one bad consumer
        self._retire(sub, evicted=True)

    def _sender(self, sub: _Subscriber) -> None:
        """Drain one subscriber's queue onto its socket (the only thread
        that writes to it after handoff)."""
        stop = self.m._stop_evt
        try:
            while True:
                with sub.cv:
                    while not sub.queue and not sub.closed and not stop.is_set():
                        sub.cv.wait(0.5)
                    if not sub.queue:
                        break  # closed or stopping, nothing left to drain
                    frame = sub.queue.popleft()
                try:
                    sub.conn.sendall(frame)
                except OSError:
                    break  # client went away (or eviction shut us down)
        finally:
            self._retire(sub)
            try:
                sub.conn.close()
            except OSError:
                pass

    def _loop(self) -> None:
        m = self.m
        stop = m._stop_evt
        while not stop.is_set():
            with self._lock:
                subs = [s for s in self._subs if not s.closed]
                self._subs = subs  # prune retired subscribers
            if not subs:
                self._wake.wait(0.2)
                self._wake.clear()
                continue
            now = time.monotonic()
            hb_cache: Dict[str, bytes] = {}  # tenant → heartbeat, this tick
            next_due = now + 1.0
            for sub in subs:
                if now + 1e-9 < sub.next_due:
                    next_due = min(next_due, sub.next_due)
                    continue
                sub.next_due = now + sub.period_s
                next_due = min(next_due, sub.next_due)
                with m._lock:
                    version = m._tenant_locked(sub.tenant).version
                if sub.last_version == version:
                    # no state change since this subscriber's last full
                    # frame: tiny heartbeat, shared across the tick
                    frame = hb_cache.get(sub.tenant)
                    if frame is None:
                        frame = pack_frame(m._heartbeat_msg(sub.tenant))
                        hb_cache[sub.tenant] = frame
                    self.heartbeats += 1
                else:
                    key = (sub.tenant, sub.by_rank)
                    ent = self._cache.get(key)
                    if ent is None or ent[0] != version:
                        # THE fanout invariant: this encode happens once per
                        # tenant/variant per update, not once per subscriber
                        ent = (
                            version,
                            pack_frame(
                                m._composite_msg(
                                    by_rank=sub.by_rank, tenant=sub.tenant
                                )
                            ),
                        )
                        self._cache[key] = ent
                        self.encodes += 1
                    sub.last_version = ent[0]
                    frame = ent[1]
                self._offer(sub, frame)
            delay = max(0.0, min(next_due - time.monotonic(), 1.0))
            if delay:
                self._wake.wait(delay)
                self._wake.clear()


# ---------------------------------------------------------------------------
# Query clients (iprof top, serve layer, tests)
# ---------------------------------------------------------------------------

_COMPOSITE_META_KEYS = ("sources", "snapshots", "deltas", "updated")


def _check_rejection(msg: Optional[dict]) -> None:
    """Raise :class:`ServerRejected` if the server answered an error frame."""
    if isinstance(msg, dict) and msg.get("type") == "error":
        raise ServerRejected(
            str(msg.get("error", "?")), str(msg.get("detail", ""))
        )


class StreamClient:
    """The one authenticated client for every master read path.

    One reusable connection, one place for TLS + token credentials, every
    query the protocol offers::

        with StreamClient("127.0.0.1:9000", token="s3cret", tls_ca="ca.pem") as c:
            tally, meta = c.composite()
            ranks, meta = c.ranks()
            for tally, meta in c.subscribe(period_s=1.0):
                ...

    ``connect()`` is lazy (first request connects) and performs the
    ``hello`` handshake: credentials are presented once per connection, and
    the master's ``hello_ack`` reveals the bound ``tenant`` and
    ``server_version``.  Requests transparently reconnect **once** when a
    pooled connection turns out dead (master restarted between polls) —
    fresh failures still raise, so an unreachable master is reported, not
    retried forever.  Auth/quota rejections raise :class:`ServerRejected`
    (a ``ProtocolError``), transport trouble raises ``OSError`` /
    ``ProtocolError`` exactly like the old one-shot helpers.

    Thread-safe for requests (one in flight at a time, guarded by a lock);
    ``subscribe`` detaches its connection from the pool, so a subscription
    and further queries can share one client.
    """

    def __init__(
        self,
        addr: Union[str, Tuple[str, int]],
        timeout_s: float = 3.0,
        token: Optional[str] = None,
        tls_ca: Optional[str] = None,
        tls_cert: Optional[str] = None,
        tls_key: Optional[str] = None,
        ssl_context: Optional[ssl.SSLContext] = None,
        server_hostname: Optional[str] = None,
        source: Optional[str] = None,
    ):
        self.addr = parse_addr(addr)
        self.timeout_s = timeout_s
        self.token = token
        if ssl_context is None and (tls_ca or tls_cert):
            ssl_context = client_ssl_context(
                cafile=tls_ca, certfile=tls_cert, keyfile=tls_key
            )
        self.ssl_context = ssl_context
        self.server_hostname = server_hostname or self.addr[0]
        self.source = source or f"client:{socket.gethostname()}:{os.getpid()}"
        #: tenant the master bound this client to (after the first connect)
        self.tenant: Optional[str] = None
        #: master's protocol version from ``hello_ack``
        self.server_version: Optional[int] = None
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()

    # -- lifecycle ----------------------------------------------------------
    def connect(self) -> "StreamClient":
        """Connect + authenticate now (requests do this lazily)."""
        with self._lock:
            self._connect_locked()
        return self

    def close(self) -> None:
        with self._lock:
            self._close_locked(say_bye=True)

    def __enter__(self) -> "StreamClient":
        return self.connect()  # surface auth/TLS errors at the `with`, not mid-loop

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def connected(self) -> bool:
        return self._sock is not None

    def _connect_locked(self) -> socket.socket:
        if self._sock is not None:
            return self._sock
        s = socket.create_connection(self.addr, timeout=self.timeout_s)
        try:
            s.settimeout(self.timeout_s)
            if self.ssl_context is not None:
                s = self.ssl_context.wrap_socket(
                    s, server_hostname=self.server_hostname
                )
            hello = {"type": "hello", "v": PROTOCOL_VERSION, "source": self.source}
            if self.token is not None:
                hello["token"] = self.token
            s.sendall(pack_frame(hello))
            ack = recv_frame(s)
            _check_rejection(ack)
            if ack is None:
                raise ProtocolError("connection closed during handshake")
            if ack.get("type") != "hello_ack":
                raise ProtocolError(f"expected hello_ack, got {ack!r}")
            self.server_version = int(ack.get("v", 1))
            self.tenant = ack.get("tenant")
        except BaseException:
            try:
                s.close()
            except OSError:
                pass
            raise
        self._sock = s
        return s

    def _close_locked(self, say_bye: bool = False) -> None:
        s, self._sock = self._sock, None
        if s is None:
            return
        if say_bye:
            try:
                s.sendall(pack_frame({"type": "bye", "source": self.source}))
            except OSError:
                pass
        try:
            s.close()
        except OSError:
            pass

    # -- request/response ---------------------------------------------------
    def _request(self, msg: dict, expect: str) -> dict:
        with self._lock:
            for attempt in (0, 1):
                pooled = self._sock is not None
                s = self._connect_locked()
                try:
                    s.sendall(pack_frame(msg))
                    reply = recv_frame(s)
                except (ProtocolError, OSError):
                    self._close_locked()
                    if not pooled or attempt:
                        raise
                    continue  # stale pooled conn: one transparent reconnect
                if reply is None:
                    self._close_locked()
                    if not pooled or attempt:
                        raise ProtocolError("connection closed by server")
                    continue
                _check_rejection(reply)
                if reply.get("type") != expect:
                    raise ProtocolError(f"expected {expect} reply, got {reply!r}")
                return reply
        raise AssertionError("unreachable")  # pragma: no cover

    def ping(self) -> bool:
        """Round-trip liveness check."""
        self._request({"type": "ping", "v": PROTOCOL_VERSION}, "pong")
        return True

    def composite(self) -> Tuple[Tally, dict]:
        """Fetch (composite tally, meta) for this client's tenant."""
        msg = self._request({"type": "query", "v": PROTOCOL_VERSION}, "composite")
        meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
        return Tally.from_obj(msg["tally"]), meta

    def ranks(self) -> Tuple[Dict[str, Tally], dict]:
        """Fetch the per-rank breakdown.

        Returns ``(ranks, meta)`` where ``ranks`` maps source id (the rank
        identity, ``host:pid:rankN``) → its latest cumulative tally, and
        ``meta`` carries the composite meta keys plus ``ts`` (source →
        receipt wall clock), ``telemetry`` (source → its latest
        device-telemetry dict, empty when no source shipped any),
        ``incarnations`` (source → incarnation number; 0 for sources that
        were never replaced) and ``retired`` (sources tombstoned by an
        eviction — render distinctly, their contribution still counts).
        Merging every value of ``ranks`` reproduces the :meth:`composite`
        tally exactly — per-rank sums equal the composite, API for API."""
        msg = self._request({"type": "query_ranks", "v": PROTOCOL_VERSION}, "ranks")
        meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
        meta["ts"] = msg.get("ts", {})
        telem = msg.get("telemetry")
        meta["telemetry"] = telem if isinstance(telem, dict) else {}
        incs = msg.get("incarnations")
        meta["incarnations"] = incs if isinstance(incs, dict) else {}
        retired = msg.get("retired")
        meta["retired"] = list(retired) if isinstance(retired, (list, tuple)) else []
        return {src: Tally.from_obj(o) for src, o in msg["ranks"].items()}, meta

    def groups(self) -> Tuple[Dict[str, Tally], dict]:
        """Fetch the rollup-group breakdown.

        Returns ``(groups, meta)`` where ``groups`` maps group id (e.g. a
        hostname, or ``groupK`` rank buckets) → the aggregated tally of its
        member sources, and ``meta`` carries the composite meta keys plus
        ``rollup`` (False when the master runs without ``rollup_groups`` —
        the map is then empty).  Merging every group reproduces the
        composite, so >1k-rank trees can be read at node granularity
        without shipping or merging per-rank tables."""
        msg = self._request(
            {"type": "query_groups", "v": PROTOCOL_VERSION}, "groups"
        )
        meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
        meta["rollup"] = bool(msg.get("rollup", False))
        return {g: Tally.from_obj(o) for g, o in msg["groups"].items()}, meta

    def subscribe(
        self, period_s: float = 1.0, by_rank: bool = False
    ) -> Iterator[Tuple[Tally, dict]]:
        """Subscribe: yields (composite, meta) as the master pushes.

        The generator *detaches* the client's pooled connection and owns it
        (the master's hub writes to it from then on); the client's next
        request opens a fresh connection, so one ``StreamClient`` can serve
        a subscription and queries side by side.  The generator ends on
        master shutdown (clean EOF) and raises ``OSError`` /
        ``ProtocolError`` on transport trouble.  Close the generator to
        disconnect.

        Idle periods arrive as tally-less heartbeats (the master only
        re-serializes the composite when state changed); the generator then
        re-yields the previous tally with ``meta["unchanged"] = True``, so
        consumers always see a renderable composite per period.

        With ``by_rank`` every full push also carries the per-source
        breakdown, surfaced as ``meta["ranks"]`` (source → Tally);
        heartbeats re-yield the cached breakdown like the cached composite.
        """
        with self._lock:
            s = self._connect_locked()
            self._sock = None  # detach: the subscription owns this socket
        try:
            s.settimeout(max(self.timeout_s, 2 * period_s))
            s.sendall(
                pack_frame(
                    {
                        "type": "subscribe",
                        "v": PROTOCOL_VERSION,
                        "period_s": period_s,
                        "by_rank": by_rank,
                    }
                )
            )
            last_tally: Optional[Tally] = None
            last_ranks: Optional[Dict[str, Tally]] = None
            last_incs: Dict[str, int] = {}
            last_retired: List[str] = []
            while True:
                msg = recv_frame(s)
                if msg is None:  # master stopped: end of stream
                    return
                _check_rejection(msg)  # e.g. subscriber quota reached
                if msg.get("type") != "composite":
                    raise ProtocolError(f"expected composite frame, got {msg!r}")
                meta = {k: msg[k] for k in _COMPOSITE_META_KEYS if k in msg}
                if "tally" in msg:
                    last_tally = Tally.from_obj(msg["tally"])
                    if "ranks" in msg:
                        last_ranks = {
                            src: Tally.from_obj(o) for src, o in msg["ranks"].items()
                        }
                        incs = msg.get("incarnations")
                        last_incs = incs if isinstance(incs, dict) else {}
                        ret = msg.get("retired")
                        last_retired = (
                            list(ret) if isinstance(ret, (list, tuple)) else []
                        )
                elif last_tally is None:
                    raise ProtocolError("unchanged heartbeat before any composite")
                else:
                    meta["unchanged"] = True
                if by_rank and last_ranks is not None:
                    meta["ranks"] = last_ranks
                    meta["incarnations"] = last_incs
                    meta["retired"] = last_retired
                yield last_tally, meta
        finally:
            try:
                s.close()
            except OSError:
                pass


# -- deprecated one-shot shims (the pre-StreamClient module-level API) ------


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old}() is deprecated; use {new}",
        DeprecationWarning,
        stacklevel=3,
    )


def query_composite(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0, **client_kw
) -> Tuple[Tally, dict]:
    """Deprecated shim: use :meth:`StreamClient.composite`."""
    _warn_deprecated("query_composite", "StreamClient(addr).composite()")
    with StreamClient(addr, timeout_s=timeout_s, **client_kw) as c:
        return c.composite()


def query_ranks(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0, **client_kw
) -> Tuple[Dict[str, Tally], dict]:
    """Deprecated shim: use :meth:`StreamClient.ranks`."""
    _warn_deprecated("query_ranks", "StreamClient(addr).ranks()")
    with StreamClient(addr, timeout_s=timeout_s, **client_kw) as c:
        return c.ranks()


def query_groups(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0, **client_kw
) -> Tuple[Dict[str, Tally], dict]:
    """Deprecated shim: use :meth:`StreamClient.groups`."""
    _warn_deprecated("query_groups", "StreamClient(addr).groups()")
    with StreamClient(addr, timeout_s=timeout_s, **client_kw) as c:
        return c.groups()


def subscribe_composites(
    addr: Union[str, Tuple[str, int]],
    period_s: float = 1.0,
    timeout_s: float = 10.0,
    by_rank: bool = False,
    **client_kw,
) -> Iterator[Tuple[Tally, dict]]:
    """Deprecated shim: use :meth:`StreamClient.subscribe`."""
    _warn_deprecated("subscribe_composites", "StreamClient(addr).subscribe()")
    c = StreamClient(addr, timeout_s=timeout_s, **client_kw)
    try:
        yield from c.subscribe(period_s=period_s, by_rank=by_rank)
    finally:
        c.close()


def live_snapshot() -> Optional[Tally]:
    """Global live profile of the *current process*, if a session is tracing.

    With ``serve_port`` set the tracer runs an in-process master, so the
    snapshot covers every source streaming to it (the global view); plain
    ``online=True`` yields this rank's own live tally; otherwise None.
    """
    from .tracer import active_tracer

    tr = active_tracer()
    if tr is None:
        return None
    server = getattr(tr, "server", None)
    if server is not None:
        return server.composite()
    if tr.online is not None:
        return tr.online.snapshot()
    return None

"""Live streaming multi-rank aggregation (THAPI §3.7 joined with §6).

The offline path (aggregate.py) is a *batch* tree reduction over ``.tally``
files; the online path (online.py) is a *single-process* live tally.  This
module joins them into a streaming service — the network-transported,
always-current version of ``aggregate_tree``:

    rank (OnlineAnalyzer) ──snapshot──▶ local master ──composite──▶ global master
                                             ▲                          ▲
                                        iprof top                  iprof top

  * Each traced rank periodically pushes a serialized tally snapshot (the
    same msgpack encoding ``aggregate.save_tally`` uses) over TCP to a
    master (:class:`SnapshotStreamer`, driven by the tracer's consumer
    thread).
  * A :class:`MasterServer` keeps the **latest** snapshot per source and
    merges them with the tally monoid on demand.  Snapshots are cumulative,
    so latest-wins merging is idempotent and converges to exactly the
    offline ``combine_aggregates`` result once every rank has pushed its
    final snapshot (tracer stop pushes one unconditionally).
  * Masters compose into a configurable-fanout tree: a master constructed
    with ``forward_to=`` periodically pushes its own composite upstream as a
    single snapshot, exactly the paper's "each local master sends its
    aggregate to the global master" — but live, while the ranks still run.
  * ``iprof serve`` runs a master; ``iprof top`` attaches to any master and
    renders the refreshing composite; :func:`query_composite` is the
    programmatic client.

Transport is deliberately tiny: length-prefixed msgpack frames (4-byte
big-endian length + body), one dict message per frame, ``type`` key selects
the handler.  Snapshots are kilobytes (§3.7), so a 64 MiB frame cap is
generous headroom, not a tuning knob.

Failure model: the traced application must never block or crash because a
master is slow, absent, or restarting.  The streamer connects lazily,
retries with backoff, and *drops* snapshots it cannot deliver (counted in
``dropped``) — the next successful push carries the full cumulative state,
so nothing is lost but latency.
"""

from __future__ import annotations

import os
import socket
import struct
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

import msgpack

from .aggregate import merge_tallies
from .plugins.tally import Tally

PROTOCOL_VERSION = 1
MAX_FRAME = 64 << 20  # frames are tally snapshots: KBs in practice (§3.7)
_HDR = struct.Struct("!I")


class ProtocolError(RuntimeError):
    """Malformed or truncated frame on a stream connection."""


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def pack_frame(msg: dict) -> bytes:
    """One message → one length-prefixed msgpack frame."""
    body = msgpack.packb(msg, use_bin_type=True)
    if len(body) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds cap {MAX_FRAME}")
    return _HDR.pack(len(body)) + body


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[dict]:
    """Read one frame; None on clean EOF, ProtocolError on a torn frame."""
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_FRAME:
        raise ProtocolError(f"peer announced {n}-byte frame (cap {MAX_FRAME})")
    body = _recv_exact(sock, n)
    if body is None:
        raise ProtocolError("connection closed mid-frame")
    return msgpack.unpackb(body, raw=False)


def parse_addr(addr: Union[str, Tuple[str, int]]) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``(host, port)`` → ``(host, port)``."""
    if isinstance(addr, tuple):
        return addr[0], int(addr[1])
    host, _, port = addr.rpartition(":")
    return (host or "127.0.0.1"), int(port)


def default_source(rank: int = 0) -> str:
    return f"{socket.gethostname()}:{os.getpid()}:rank{rank}"


# ---------------------------------------------------------------------------
# Rank side: snapshot push client
# ---------------------------------------------------------------------------


class SnapshotStreamer:
    """Pushes cumulative tally snapshots to a master; never blocks tracing.

    Push cadence belongs to the caller (the tracer's consumer thread, a
    master's forwarder loop); ``push(tally)`` always sends — the tracer's
    stop path relies on that for the final, authoritative snapshot.
    """

    def __init__(
        self,
        addr: Union[str, Tuple[str, int]],
        source: str,
        retry_s: float = 0.5,
        timeout_s: float = 2.0,
    ):
        self.addr = parse_addr(addr)
        self.source = source
        self.retry_s = retry_s
        self.timeout_s = timeout_s
        self.pushed = 0
        self.dropped = 0
        self._seq = 0
        self._sock: Optional[socket.socket] = None
        self._next_retry = 0.0
        self._lock = threading.Lock()

    def push(self, tally: Union[Tally, dict]) -> bool:
        msg = {
            "type": "snapshot",
            "v": PROTOCOL_VERSION,
            "source": self.source,
            "seq": self._seq,
            "ts": time.time(),
            "tally": tally.to_obj() if isinstance(tally, Tally) else tally,
        }
        with self._lock:
            sock = self._ensure_conn()
            if sock is None:
                self.dropped += 1
                return False
            try:
                sock.sendall(pack_frame(msg))
            except OSError:
                self._drop_conn()
                self.dropped += 1
                return False
            self._seq += 1
            self.pushed += 1
            return True

    def _ensure_conn(self) -> Optional[socket.socket]:
        if self._sock is not None:
            return self._sock
        if time.monotonic() < self._next_retry:
            return None
        try:
            s = socket.create_connection(self.addr, timeout=self.timeout_s)
            s.settimeout(self.timeout_s)
            s.sendall(
                pack_frame(
                    {"type": "hello", "v": PROTOCOL_VERSION, "source": self.source}
                )
            )
        except OSError:
            self._next_retry = time.monotonic() + self.retry_s
            return None
        self._sock = s
        return s

    def _drop_conn(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.sendall(pack_frame({"type": "bye", "source": self.source}))
                except OSError:
                    pass
                self._drop_conn()


# ---------------------------------------------------------------------------
# Master daemon (local or global, depending on forward_to)
# ---------------------------------------------------------------------------


class MasterServer:
    """Streaming master: latest-snapshot-per-source store + monoid merge.

    * leaf ranks (or child masters) connect and push ``snapshot`` frames;
    * any client may send ``query`` and gets the current composite back;
    * with ``forward_to=`` set this is a *local* master: a forwarder thread
      periodically pushes the composite upstream as one snapshot, making the
      whole arrangement the live fanout tree of §3.7.
    """

    def __init__(
        self,
        port: int = 0,
        host: str = "127.0.0.1",
        forward_to: Optional[Union[str, Tuple[str, int]]] = None,
        forward_period_s: float = 0.5,
        fanout: int = 32,
        source: Optional[str] = None,
    ):
        self.host = host
        self.port = port  # rebound to the real port at start()
        self.fanout = fanout
        self.forward_to = forward_to
        self.forward_period_s = forward_period_s
        self.source = source or f"master:{socket.gethostname()}:{os.getpid()}"
        #: source → (seq, cumulative tally, wall-clock receipt time)
        self._latest: Dict[str, Tuple[int, Tally, float]] = {}
        self._lock = threading.Lock()
        self._dirty = False
        self.frames = 0
        self.snapshots = 0
        self.queries = 0
        self._lsock: Optional[socket.socket] = None
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._forwarder: Optional[SnapshotStreamer] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "MasterServer":
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self.host, self.port))
        ls.listen(128)
        self._lsock = ls
        self.port = ls.getsockname()[1]
        self._stop_evt.clear()
        acceptor = threading.Thread(
            target=self._accept_loop, name="thapi-master-accept", daemon=True
        )
        acceptor.start()
        self._threads.append(acceptor)
        if self.forward_to is not None:
            self._forwarder = SnapshotStreamer(self.forward_to, source=self.source)
            fwd = threading.Thread(
                target=self._forward_loop, name="thapi-master-forward", daemon=True
            )
            fwd.start()
            self._threads.append(fwd)
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
            self._lsock = None
        if self._forwarder is not None:
            self.flush(force=True)  # last composite must reach the parent
            self._forwarder.close()
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = list(self._threads), []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        for t in threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "MasterServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def addr(self) -> str:
        return f"{self.host}:{self.port}"

    @property
    def forwarder(self) -> Optional[SnapshotStreamer]:
        """The upstream push client (local masters only), for its counters."""
        return self._forwarder

    # -- state ---------------------------------------------------------------
    def submit(
        self, source: str, tally: Union[Tally, dict], seq: Optional[int] = None
    ) -> None:
        """Ingest a cumulative snapshot (socket handlers and the in-process
        tracer both land here). Out-of-order frames (seq < stored) are stale
        duplicates of state we already supersede — dropped."""
        if not isinstance(tally, Tally):
            tally = Tally.from_obj(tally)
        with self._lock:
            prev = self._latest.get(source)
            if prev is not None and seq is not None and seq < prev[0]:
                return
            nseq = seq if seq is not None else (prev[0] + 1 if prev else 0)
            self._latest[source] = (nseq, tally, time.time())
            self.snapshots += 1
            self._dirty = True

    def _reset_seq(self, source: str) -> None:
        with self._lock:
            prev = self._latest.get(source)
            if prev is not None:
                # keep the last tally but accept any future seq from it
                self._latest[source] = (-1, prev[1], prev[2])

    def composite(self) -> Tally:
        """Tree-merge the latest snapshot of every source (fanout-ary, like
        the offline ``aggregate_tree``). Sources' stored tallies are never
        mutated — merging runs on defensive copies."""
        with self._lock:
            copies = [Tally().merge(t) for (_, t, _) in self._latest.values()]
        if not copies:
            return Tally()
        comp, _ = merge_tallies(copies, fanout=self.fanout)
        return comp

    def stats(self) -> dict:
        with self._lock:
            sources = len(self._latest)
            updated = max((ts for (_, _, ts) in self._latest.values()), default=0.0)
        return {
            "sources": sources,
            "frames": self.frames,
            "snapshots": self.snapshots,
            "queries": self.queries,
            "updated": updated,
            "forwarding": self.forward_to is not None,
        }

    def flush(self, force: bool = False) -> bool:
        """Push the composite upstream now (local masters only)."""
        if self._forwarder is None:
            return False
        with self._lock:
            if not self._latest or (not self._dirty and not force):
                return False
            self._dirty = False
        ok = self._forwarder.push(self.composite())
        if not ok:
            # parent unreachable: keep the trigger armed so the composite is
            # re-forwarded once the parent comes back, not lost forever
            with self._lock:
                self._dirty = True
        return ok

    # -- threads -------------------------------------------------------------
    def _accept_loop(self) -> None:
        ls = self._lsock
        while not self._stop_evt.is_set():
            try:
                conn, _peer = ls.accept()
            except OSError:
                break
            with self._lock:
                self._conns.append(conn)
            t = threading.Thread(
                target=self._client_loop, args=(conn,), name="thapi-master-conn", daemon=True
            )
            with self._lock:
                self._threads.append(t)
            t.start()

    def _client_loop(self, conn: socket.socket) -> None:
        try:
            while not self._stop_evt.is_set():
                try:
                    msg = recv_frame(conn)
                except (ProtocolError, OSError):
                    break
                if msg is None:
                    break
                self.frames += 1
                kind = msg.get("type")
                if kind == "snapshot":
                    self.submit(
                        str(msg.get("source", "?")), msg["tally"], msg.get("seq")
                    )
                elif kind == "hello":
                    # a fresh connection restarts the peer's seq counter (e.g.
                    # a new Tracer session in the same process): forget the
                    # stored seq so its snapshots aren't dropped as stale
                    self._reset_seq(str(msg.get("source", "?")))
                elif kind == "query":
                    self.queries += 1
                    try:
                        conn.sendall(pack_frame(self._composite_msg()))
                    except OSError:
                        break
                elif kind == "ping":
                    try:
                        conn.sendall(pack_frame({"type": "pong", "v": PROTOCOL_VERSION}))
                    except OSError:
                        break
                elif kind == "bye":
                    break
                # unknown types: ignored, no reply needed
        finally:
            try:
                conn.close()
            except OSError:
                pass
            # long-lived masters see many short query connections: prune, or
            # _conns/_threads grow without bound
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                cur = threading.current_thread()
                if cur in self._threads:
                    self._threads.remove(cur)

    def _forward_loop(self) -> None:
        while not self._stop_evt.wait(self.forward_period_s):
            self.flush()

    def _composite_msg(self) -> dict:
        comp = self.composite()
        st = self.stats()
        return {
            "type": "composite",
            "v": PROTOCOL_VERSION,
            "tally": comp.to_obj(),
            "sources": st["sources"],
            "snapshots": st["snapshots"],
            "updated": st["updated"],
        }


# ---------------------------------------------------------------------------
# Query client (iprof top, serve layer, tests)
# ---------------------------------------------------------------------------


def query_composite(
    addr: Union[str, Tuple[str, int]], timeout_s: float = 3.0
) -> Tuple[Tally, dict]:
    """One-shot request: connect to a master, fetch (composite, meta)."""
    host, port = parse_addr(addr)
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        s.settimeout(timeout_s)
        s.sendall(pack_frame({"type": "query", "v": PROTOCOL_VERSION}))
        msg = recv_frame(s)
    if not msg or msg.get("type") != "composite":
        raise ProtocolError(f"expected composite reply, got {msg!r}")
    meta = {k: msg[k] for k in ("sources", "snapshots", "updated") if k in msg}
    return Tally.from_obj(msg["tally"]), meta


def live_snapshot() -> Optional[Tally]:
    """Global live profile of the *current process*, if a session is tracing.

    With ``serve_port`` set the tracer runs an in-process master, so the
    snapshot covers every source streaming to it (the global view); plain
    ``online=True`` yields this rank's own live tally; otherwise None.
    """
    from .tracer import active_tracer

    tr = active_tracer()
    if tr is None:
        return None
    server = getattr(tr, "server", None)
    if server is not None:
        return server.composite()
    if tr.online is not None:
        return tr.online.snapshot()
    return None

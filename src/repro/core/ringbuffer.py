"""Per-thread ring buffers with discard mode (THAPI §3.1 / LTTng).

LTTng's key collection property: *lockless per-CPU ring buffers* — no
inter-core communication on the producer hot path — and *discard mode*: if
the consumer cannot keep up, new events are dropped (counted) rather than
blocking the traced application.

We reproduce the architecture with per-*thread* byte rings (the Python
analogue of per-CPU: under the GIL a thread owns its ring's write end).  The
design is single-producer/single-consumer:

  producer (traced thread)  — writes framed records at ``head``; only ever
                              advances ``head``; never blocks; drops when full.
  consumer (flusher daemon) — reads the committed region and advances
                              ``tail``; never touches ``head``.

``head``/``tail`` are monotonically increasing Python ints; a reader sees
either the old or the new binding (GIL-atomic), so the committed prefix is
always consistent.  Data is written *before* ``head`` is published, which is
the same publish protocol as LTTng's sub-buffer commit counters.

Two producer protocols share that publish ordering:

  ``write(record)``        — legacy: the caller builds the framed record as
                             one ``bytes`` object and the ring copies it in.
  ``reserve(n)/commit(n)`` — zero-allocation: the producer asks for ``n``
                             contiguous bytes, packs fields *directly into
                             ring storage* (``wbuf`` at the returned offset)
                             and then publishes.  On the common non-wrap path
                             no intermediate object is allocated; when the
                             record would straddle the physical end of the
                             ring, ``reserve`` stages the write through one
                             reusable per-ring scratch ``bytearray`` and
                             ``commit`` copies the two halves into place —
                             the ring *content* is identical either way.

Producers on the reserve path may additionally bound-check against ``_lim``
(`head`-space address below which a record is guaranteed to fit without
wrapping or overwriting unconsumed data) to skip ``reserve`` entirely:
``_lim`` is only ever advanced by ``reserve`` from a fresh ``tail`` read, so
a stale value is conservative — the generated tracepoints lean on this for
their single-compare fast path.

Record framing (little-endian):
    u32  total record length (including this header)
    u16  event id
    u64  timestamp (monotonic ns)
    ...  payload (per-event schema, packed by the generated tracepoints)
"""

from __future__ import annotations

import struct
import threading
from typing import List, Optional, Tuple

RECORD_HEADER = struct.Struct("<IHQ")
RECORD_HEADER_SIZE = RECORD_HEADER.size  # 14 bytes


class RingBuffer:
    """One SPSC byte ring. Capacity must be a power of two."""

    __slots__ = (
        "capacity",
        "_mask",
        "_buf",
        "_mv",
        "head",
        "tail",
        "dropped",
        "events",
        "wbuf",
        "_scratch",
        "_lim",
        "_pending",
        "pid",
        "tid",
        "tname",
    )

    def __init__(self, capacity: int, pid: int = 0, tid: int = 0, tname: str = ""):
        if capacity & (capacity - 1) or capacity <= 0:
            raise ValueError("ring capacity must be a power of two")
        self.capacity = capacity
        self._mask = capacity - 1
        self._buf = bytearray(capacity)
        self._mv = memoryview(self._buf)  # zero-copy drain slices come from here
        self.head = 0  # producer-owned
        self.tail = 0  # consumer-owned
        self.dropped = 0  # producer-owned (discard-mode counter)
        self.events = 0  # records written (write()/recorders; commit() is agnostic)
        #: buffer the producer packs into after ``reserve``: the ring storage
        #: itself on the non-wrap path, the scratch staging area otherwise
        self.wbuf = self._buf
        self._scratch = bytearray(0)
        #: producer-cached fast-path bound: head-space address up to which a
        #: record fits without wrap/overwrite. Stale values are conservative.
        self._lim = capacity
        self._pending = 0  # head snapshot of the outstanding drain_view
        self.pid = pid
        self.tid = tid
        self.tname = tname

    # -- producer hot path ---------------------------------------------------

    def write(self, record: bytes) -> bool:
        """Append one framed record; drop (never block) when full."""
        n = len(record)
        if n > self.capacity - (self.head - self.tail):
            self.dropped += 1
            return False
        h = self.head & self._mask
        end = h + n
        if end <= self.capacity:
            self._buf[h:end] = record
        else:  # wrap: zero-copy halves via memoryview (record[:k] would copy)
            k = self.capacity - h
            mv = memoryview(record)
            self._buf[h:] = mv[:k]
            self._buf[: n - k] = mv[k:]
        self.head += n  # publish (single int store under the GIL)
        self.events += 1
        return True

    def reserve(self, n: int) -> int:
        """Claim ``n`` bytes; return the ``wbuf`` offset to pack into, -1 = drop.

        Does not publish: the producer packs the record into ``self.wbuf`` at
        the returned offset, then calls :meth:`commit`.  ``head`` is untouched
        until then, so an exception between reserve and commit leaves the ring
        consistent (the reservation is simply forgotten).  Also refreshes
        ``_lim`` from a fresh ``tail`` read so subsequent records can skip
        straight to packing while ``head + n <= _lim`` holds.
        """
        h = self.head
        if n > self.capacity - (h - self.tail):
            self.dropped += 1
            return -1
        o = h & self._mask
        # fast-path bound for the generated recorders: stop at whichever comes
        # first, the consumer's tail + one capacity or the physical wrap point
        self._lim = min(self.tail + self.capacity, h - o + self.capacity)
        if o + n <= self.capacity:
            self.wbuf = self._buf
            return o
        # wrap: stage through the reusable scratch buffer (rare; one
        # allocation the first time it grows, then reused)
        if len(self._scratch) < n:
            self._scratch = bytearray(n)
        self.wbuf = self._scratch
        return 0

    def commit(self, n: int) -> None:
        """Publish the ``n`` bytes packed after :meth:`reserve`.

        Non-wrap: the record is already in ring storage; publishing is one
        ``head`` store.  Wrap: copy the scratch halves into place first (data
        lands before ``head`` moves — same ordering as :meth:`write`).
        ``events`` is *not* incremented here: reserve/commit callers account
        records themselves (a fused pair recorder commits two at once).
        """
        wb = self.wbuf
        if wb is not self._buf:
            h = self.head & self._mask
            k = self.capacity - h
            mv = memoryview(wb)
            self._buf[h:] = mv[:k]
            self._buf[: n - k] = mv[k:n]
            self.wbuf = self._buf
        self.head += n  # publish (single int store under the GIL)

    # -- consumer side ---------------------------------------------------------

    def drain(self) -> bytes:
        """Copy out the committed region and release it. Consumer-only."""
        t = self.tail
        h = self.head  # snapshot; producer may advance after this — fine
        n = h - t
        if n == 0:
            return b""
        lo = t & self._mask
        end = lo + n
        if end <= self.capacity:
            out = bytes(self._buf[lo:end])
        else:
            out = bytes(self._buf[lo:]) + bytes(self._buf[: end - self.capacity])
        self.tail = h  # release
        return out

    def drain_view(self) -> Tuple[memoryview, ...]:
        """Zero-copy drain: memoryview region(s) over the committed bytes.

        Returns ``()`` when empty, one region on the common path, two when the
        committed bytes straddle the physical end of the ring (records may be
        split across the pair — join before frame-parsing them).  The region
        is NOT released: the caller must finish consuming the views and then
        call :meth:`release`, or the producer could overwrite bytes still
        being read.  Consumer-only.
        """
        t = self.tail
        h = self.head  # snapshot; producer may advance after this — fine
        self._pending = h
        n = h - t
        if n == 0:
            return ()
        lo = t & self._mask
        end = lo + n
        mv = self._mv
        if end <= self.capacity:
            return (mv[lo:end],)
        return (mv[lo:], mv[: end - self.capacity])

    def release(self) -> None:
        """Release the region returned by the last :meth:`drain_view`."""
        if self._pending > self.tail:  # guard against drain()/drain_view() mixes
            self.tail = self._pending

    @property
    def used(self) -> int:
        return self.head - self.tail


class RingRegistry:
    """Tracks every thread's ring so the consumer daemon can drain them all.

    Ring creation is the only locked operation (once per thread); the event
    hot path never takes a lock — the LTTng property the paper leans on for
    its overhead numbers (Fig 7).
    """

    def __init__(self, capacity: int, pid: int):
        self._capacity = capacity
        self._pid = pid
        self._lock = threading.Lock()
        self._rings: List[RingBuffer] = []
        self._tls = threading.local()

    def get(self) -> RingBuffer:
        rb: Optional[RingBuffer] = getattr(self._tls, "ring", None)
        if rb is None:
            th = threading.current_thread()
            rb = RingBuffer(self._capacity, pid=self._pid, tid=th.ident or 0, tname=th.name)
            with self._lock:
                self._rings.append(rb)
            self._tls.ring = rb
        return rb

    def rings(self) -> List[RingBuffer]:
        with self._lock:
            return list(self._rings)

    @property
    def capacity(self) -> int:
        """Ring capacity (bytes) handed to newly-registered threads."""
        return self._capacity

    def set_capacity(self, nbytes: int) -> None:
        """Resize the capacity used for *future* rings (§6 adaptive knob).

        Existing rings keep their size — they are lock-free SPSC structures
        whose producer may be mid-write; only threads that first touch the
        registry after this call get the new capacity.
        """
        self._capacity = max(1 << 12, int(nbytes))

    @property
    def total_dropped(self) -> int:
        return sum(r.dropped for r in self.rings())

    @property
    def total_events(self) -> int:
        return sum(r.events for r in self.rings())

    def counters(self) -> dict:
        """One consistent snapshot of collection-side counters.

        ``events``/``dropped`` are cumulative producer counts; ``used`` is
        the bytes currently buffered (un-drained) across rings.  Cheap —
        one lock acquisition for the ring list, then plain reads — so mode
        conformance checks (e.g. "the off rung wrote nothing") and adaptive
        policies can poll it without perturbing producers.
        """
        rings = self.rings()
        return {
            "rings": len(rings),
            "events": sum(r.events for r in rings),
            "dropped": sum(r.dropped for r in rings),
            "used": sum(r.used() for r in rings),
        }

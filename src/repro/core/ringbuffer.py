"""Per-thread ring buffers with discard mode (THAPI §3.1 / LTTng).

LTTng's key collection property: *lockless per-CPU ring buffers* — no
inter-core communication on the producer hot path — and *discard mode*: if
the consumer cannot keep up, new events are dropped (counted) rather than
blocking the traced application.

We reproduce the architecture with per-*thread* byte rings (the Python
analogue of per-CPU: under the GIL a thread owns its ring's write end).  The
design is single-producer/single-consumer:

  producer (traced thread)  — writes framed records at ``head``; only ever
                              advances ``head``; never blocks; drops when full.
  consumer (flusher daemon) — copies the committed region and advances
                              ``tail``; never touches ``head``.

``head``/``tail`` are monotonically increasing Python ints; a reader sees
either the old or the new binding (GIL-atomic), so the committed prefix is
always consistent.  Data is written *before* ``head`` is published, which is
the same publish protocol as LTTng's sub-buffer commit counters.

Record framing (little-endian):
    u32  total record length (including this header)
    u16  event id
    u64  timestamp (monotonic ns)
    ...  payload (per-event schema, packed by the generated tracepoints)
"""

from __future__ import annotations

import struct
import threading
from typing import List, Optional

RECORD_HEADER = struct.Struct("<IHQ")
RECORD_HEADER_SIZE = RECORD_HEADER.size  # 14 bytes


class RingBuffer:
    """One SPSC byte ring. Capacity must be a power of two."""

    __slots__ = (
        "capacity",
        "_mask",
        "_buf",
        "head",
        "tail",
        "dropped",
        "events",
        "pid",
        "tid",
        "tname",
    )

    def __init__(self, capacity: int, pid: int = 0, tid: int = 0, tname: str = ""):
        if capacity & (capacity - 1) or capacity <= 0:
            raise ValueError("ring capacity must be a power of two")
        self.capacity = capacity
        self._mask = capacity - 1
        self._buf = bytearray(capacity)
        self.head = 0  # producer-owned
        self.tail = 0  # consumer-owned
        self.dropped = 0  # producer-owned (discard-mode counter)
        self.events = 0
        self.pid = pid
        self.tid = tid
        self.tname = tname

    # -- producer hot path ---------------------------------------------------

    def write(self, record: bytes) -> bool:
        """Append one framed record; drop (never block) when full."""
        n = len(record)
        if n > self.capacity - (self.head - self.tail):
            self.dropped += 1
            return False
        h = self.head & self._mask
        end = h + n
        if end <= self.capacity:
            self._buf[h:end] = record
        else:  # wrap: zero-copy halves via memoryview (record[:k] would copy)
            k = self.capacity - h
            mv = memoryview(record)
            self._buf[h:] = mv[:k]
            self._buf[: n - k] = mv[k:]
        self.head += n  # publish (single int store under the GIL)
        self.events += 1
        return True

    # -- consumer side ---------------------------------------------------------

    def drain(self) -> bytes:
        """Copy out the committed region and release it. Consumer-only."""
        t = self.tail
        h = self.head  # snapshot; producer may advance after this — fine
        n = h - t
        if n == 0:
            return b""
        lo = t & self._mask
        end = lo + n
        if end <= self.capacity:
            out = bytes(self._buf[lo:end])
        else:
            out = bytes(self._buf[lo:]) + bytes(self._buf[: end - self.capacity])
        self.tail = h  # release
        return out

    @property
    def used(self) -> int:
        return self.head - self.tail


class RingRegistry:
    """Tracks every thread's ring so the consumer daemon can drain them all.

    Ring creation is the only locked operation (once per thread); the event
    hot path never takes a lock — the LTTng property the paper leans on for
    its overhead numbers (Fig 7).
    """

    def __init__(self, capacity: int, pid: int):
        self._capacity = capacity
        self._pid = pid
        self._lock = threading.Lock()
        self._rings: List[RingBuffer] = []
        self._tls = threading.local()

    def get(self) -> RingBuffer:
        rb: Optional[RingBuffer] = getattr(self._tls, "ring", None)
        if rb is None:
            th = threading.current_thread()
            rb = RingBuffer(self._capacity, pid=self._pid, tid=th.ident or 0, tname=th.name)
            with self._lock:
                self._rings.append(rb)
            self._tls.ring = rb
        return rb

    def rings(self) -> List[RingBuffer]:
        with self._lock:
            return list(self._rings)

    @property
    def capacity(self) -> int:
        """Ring capacity (bytes) handed to newly-registered threads."""
        return self._capacity

    def set_capacity(self, nbytes: int) -> None:
        """Resize the capacity used for *future* rings (§6 adaptive knob).

        Existing rings keep their size — they are lock-free SPSC structures
        whose producer may be mid-write; only threads that first touch the
        registry after this call get the new capacity.
        """
        self._capacity = max(1 << 12, int(nbytes))

    @property
    def total_dropped(self) -> int:
        return sum(r.dropped for r in self.rings())

    @property
    def total_events(self) -> int:
        return sum(r.events for r in self.rings())

"""Tracing session control (THAPI §3.2, §5.2).

The tracer owns the collection side of the framework:

  * **modes** — ``minimal`` / ``default`` / ``full`` (§5.2): minimal traces
    device-side events only (kernel executions, device commands), default
    traces everything except polling / spin-lock APIs ("non-spawned APIs"),
    full traces everything including polled calls and argument dumps;
  * **selective events / ranks** — per-event enable flags and a rank filter
    ("trace specific groups of ranks in a large-scale setting", §3.2);
  * **consumer daemon** — drains every thread's ring buffer to CTF-lite
    streams on a period (LTTng's consumer/relay daemon), emitting
    discarded-event records when drop counters advance;
  * **aggregate-only mode** (§3.7) — for multi-node runs keep only the tally
    aggregate (kilobytes) instead of the full streams.

Usage (the iprof CLI wraps exactly this):

    cfg = TraceConfig(out_dir="/tmp/t", mode="default", sample=True)
    with Tracer(cfg) as tr:
        ...traced application...
    handle = tr.handle  # → analysis (pretty/tally/timeline)
"""

from __future__ import annotations

import dataclasses
import os
import socket
import sys
import threading
import time
from typing import Dict, Optional, Sequence, Set, Tuple

from . import telemetry as _telemetry
from .api_model import TraceModel, builtin_trace_model
from .clock import ClockInfo, now
from .ctf import StreamWriter, trace_size_bytes, write_metadata
from .ringbuffer import RingRegistry
from .tracepoints import FIDELITY_MODES, Tracepoints

MODES = ("minimal", "default", "full")


@dataclasses.dataclass
class TraceConfig:
    out_dir: str
    mode: str = "default"
    sample: bool = False  # device telemetry daemon (TS-* configurations)
    sample_period_s: float = 0.05  # paper default: 50 ms (§3.5)
    ring_bytes: int = 1 << 22  # 4 MiB per thread
    flush_period_s: float = 0.05
    rank: int = 0
    #: §3.2 — trace only these ranks (None = all). Non-selected ranks run untraced.
    ranks: Optional[Sequence[int]] = None
    #: §3.7 — keep only the aggregate tally, delete raw streams at stop().
    aggregate_only: bool = False
    #: escape hatch: tally the aggregate through the legacy Babeltrace-style
    #: graph instead of the single-pass fold engine (identical result,
    #: ~an order of magnitude slower on large traces; see core/fold.py)
    legacy_graph: bool = False
    #: escape hatch: False reverts recorders to the legacy bytes-build +
    #: RingBuffer.write path instead of the zero-allocation reserve/commit
    #: pack_into codegen (byte-identical streams, ~2-3x slower producers;
    #: see core/tracepoints.py)
    ring_reserve: bool = True
    #: zstd-compress CTF streams (space knob beyond Fig 8's mode ladder)
    compress: bool = False
    #: write a columnar ``.ctfcol`` sidecar per stream at drain time (packed
    #: interval columns + per-stream folded tally footer): repeat analysis
    #: and timeline queries then skip record parsing entirely (see
    #: core/ctf.py ColumnarWriter; staleness-checked, falls back safely)
    columnar: bool = False
    #: §6 future work, implemented: maintain a LIVE tally on the consumer
    #: thread (read via tracer.online.snapshot() mid-run)
    online: bool = False
    #: §3.7+§6 streaming: push live tally snapshots to a master at
    #: "host:port" (see core/stream.py). Implies ``online``.
    stream_to: Optional[str] = None
    #: snapshot push period; the final snapshot at stop() is always pushed
    stream_period_s: float = 0.25
    #: protocol-v2 delta streaming: ship only changed ApiStats entries in
    #: steady state (full-snapshot resync frames bound drift). Off = every
    #: push is a full snapshot (v1-compatible wire behavior).
    stream_delta: bool = True
    #: force a full-snapshot resync frame every N delta pushes
    stream_resync_every: int = 32
    #: run an in-process master on this port (0 = ephemeral) serving this
    #: rank's live tally — and, via ``stream_to`` on other ranks, theirs too;
    #: ``iprof top`` attaches here. Implies ``online``.
    serve_port: Optional[int] = None
    #: master-tree fanout used when this process is itself a master
    stream_fanout: int = 32
    #: extra per-event overrides applied after the mode preset, e.g.
    #: {"ust_jaxrt:alloc_entry": False}
    event_overrides: Optional[Dict[str, bool]] = None
    #: §6 adaptive consumer: policies (or a ready AdaptiveController) ticked
    #: from the consumer thread; they may turn session knobs mid-run from
    #: live windowed metrics (see core/adaptive.py). Implies ``online``.
    adaptive: Optional[Sequence] = None
    #: adaptation window: how often the controller diffs live snapshots
    adaptive_period_s: float = 0.5
    #: cluster-scope adaptive control: ClusterPolicy list (or a ready
    #: ClusterAdaptiveController) fed from the in-process master's per-rank
    #: map and ticked from the consumer thread; requires ``serve_port``
    #: (the master IS the per-rank data source). See core/adaptive.py.
    cluster_adaptive: Optional[Sequence] = None
    #: cluster adaptation window: how often per-rank maps are diffed
    cluster_period_s: float = 1.0
    #: forward per-rank breakdowns (not collapsed composites) when this
    #: process's in-process master forwards upstream — keeps rank identity
    #: visible at every level of the aggregation tree
    stream_ranks: bool = True
    #: bearer token presented to the ``stream_to`` master (and, for an
    #: in-process master, forwarded upstream) when the serving tier runs
    #: with token auth — see core/stream.py ServeOptions.auth_tokens
    stream_token: Optional[str] = None
    #: CA bundle path pinning the upstream master's TLS certificate; sets
    #: the client side of the hardened serving tier (None = plaintext)
    stream_tls_ca: Optional[str] = None
    #: initial-connect resilience for the ``stream_to`` push client: retry
    #: the first connect up to N times with capped-exponential backoff
    #: (base ``stream_connect_backoff_s``) so ranks that start before the
    #: master don't drop their early pushes.  0 = historical fail-fast.
    stream_connect_retries: int = 0
    stream_connect_backoff_s: float = 0.25
    #: attach per-rank device telemetry (host RSS, device memory pressure,
    #: memcpy/alloc bandwidth — core/telemetry.py) to every streamed
    #: snapshot, carried through the per-rank breakdown so cluster policies
    #: can tell "slow kernel" from "sick host".  Uses the sampling daemon's
    #: latest sample when ``sample`` is on, else a cheap inline read.
    stream_telemetry: bool = True
    #: closed-loop remediation: a ready core/remediation.RemediationEngine
    #: ticked from the consumer thread and attached to this session (its
    #: decisions land in the trace as ``ust_repro:remediation`` events).
    #: When ``cluster_adaptive`` runs too, the controller's flag/healthy
    #: channels are wired into the engine unless already set.
    remediation: Optional[object] = None
    #: full serving-tier configuration for the in-process master (TLS
    #: cert/key, auth tokens, per-tenant quotas, hub queue depth...).  None
    #: builds one from the legacy stream_* knobs above; when set, it wins
    #: over them (it IS the knob set) and stream_token/stream_tls_ca are
    #: still injected as upstream credentials if the options carry none.
    serve_options: Optional[object] = None
    #: override the streaming source identity (None = ``default_source(rank)``,
    #: i.e. "host:pid:rankN").  An elastic replacement process MUST present
    #: its predecessor's source id so the master's incarnation fencing can
    #: supersede the dead process instead of seeing a brand-new rank.
    stream_source: Optional[str] = None
    #: incarnation number carried in the streaming ``hello``/frames; masters
    #: fence frames from lower incarnations of the same source (zombie
    #: containment — see docs/streaming.md).  0 = the original launch.
    stream_incarnation: int = 0
    #: starting rung of the fidelity ladder (orthogonal to ``mode``, which
    #: selects *what* is traced): "full" | "sampled" | "tally-only" | "off".
    #: Switchable mid-run via Tracer.set_mode / repro.trace.set_mode.
    fidelity: str = "full"
    #: 1/N systematic-sampling interval for the "sampled" rung
    sampling_interval: int = 64
    #: seed for the per-thread sampling phase RNG (None = nondeterministic)
    sampling_seed: Optional[int] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.fidelity not in FIDELITY_MODES:
            raise ValueError(
                f"fidelity must be one of {FIDELITY_MODES}, got {self.fidelity!r}"
            )
        if self.sampling_interval < 1:
            raise ValueError("sampling_interval must be >= 1")
        if self.stream_connect_retries < 0:
            raise ValueError("stream_connect_retries must be >= 0")
        if self.stream_connect_backoff_s <= 0:
            raise ValueError("stream_connect_backoff_s must be > 0")
        if self.stream_incarnation < 0:
            raise ValueError("stream_incarnation must be >= 0")
        if self.cluster_adaptive is not None and self.serve_port is None:
            raise ValueError(
                "cluster_adaptive requires serve_port: the in-process master "
                "is the per-rank data source cluster policies read"
            )
        if (
            self.stream_to is not None
            or self.serve_port is not None
            or self.adaptive is not None
        ):
            self.online = True


def events_for_mode(model: TraceModel, mode: str, sample: bool) -> Set[int]:
    """Mode → enabled event-id set (§5.2 definitions).

    minimal : kernel execution + device command events (device spans).
    default : every event except polling ("non-spawned") APIs.
    full    : everything.
    Telemetry counters ride on ``sample`` independent of the mode (T- vs TS-).
    """
    out: Set[int] = set()
    for ev in model.events:
        if ev.phase == "meta":
            continue
        if ev.provider == "ust_thapi":
            if sample:
                out.add(ev.eid)
            continue
        if mode == "minimal":
            if ev.phase == "span":
                out.add(ev.eid)
        elif mode == "default":
            if not ev.polling:
                out.add(ev.eid)
        else:  # full
            out.add(ev.eid)
    return out


# Global tracepoints singleton over the builtin trace model. Interception
# code references these recorder callables directly (no per-call lookups).
_TRACEPOINTS: Optional[Tracepoints] = None
_TP_LOCK = threading.Lock()


def get_tracepoints() -> Tracepoints:
    global _TRACEPOINTS
    if _TRACEPOINTS is None:
        with _TP_LOCK:
            if _TRACEPOINTS is None:
                _TRACEPOINTS = Tracepoints(builtin_trace_model())
    return _TRACEPOINTS


_ACTIVE: Optional["Tracer"] = None


def active_tracer() -> Optional["Tracer"]:
    return _ACTIVE


@dataclasses.dataclass
class TraceHandle:
    """Result of a completed session, input to the analysis layer."""

    trace_dir: str
    mode: str
    events: int
    dropped: int
    size_bytes: int
    aggregate_path: Optional[str] = None
    #: snapshots delivered / undeliverable to the stream_to master
    streamed: int = 0
    stream_dropped: int = 0
    #: fidelity rung at stop time (see TraceConfig.fidelity)
    fidelity: str = "full"


class Tracer:
    def __init__(
        self,
        cfg: TraceConfig,
        model: Optional[TraceModel] = None,
        clock=None,
    ):
        self.cfg = cfg
        #: ``clock`` (injectable timestamp source, tests only) is honored when
        #: a private model is supplied — the global recorder singleton always
        #: runs on the trace clock
        self.tp = get_tracepoints() if model is None else Tracepoints(model, clock=clock)
        self.model = self.tp.model
        self.clock: Optional[ClockInfo] = None
        self.registry: Optional[RingRegistry] = None
        self.handle: Optional[TraceHandle] = None
        self._writers: Dict[Tuple[int, int], StreamWriter] = {}
        #: per-stream columnar sidecar writers (cfg.columnar) + shared engine
        self._colwriters: Dict[Tuple[int, int], object] = {}
        self._fold_engine = None
        self._consumer: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._sampler: Optional[_telemetry.TelemetryDaemon] = None
        self._started = False
        self.online = None  # OnlineAnalyzer when cfg.online
        self.streamer = None  # SnapshotStreamer when cfg.stream_to
        self.server = None  # MasterServer when cfg.serve_port
        self.adaptive = None  # AdaptiveController when cfg.adaptive
        self.cluster = None  # ClusterAdaptiveController when cfg.cluster_adaptive
        self.remediation = None  # RemediationEngine when cfg.remediation
        self._stream_source = ""
        self._stream_next = 0.0
        #: rank selected for tracing? (§3.2 selective rank tracing)
        self.selected = cfg.ranks is None or cfg.rank in set(cfg.ranks)
        #: fidelity-ladder state: current rung, rungs visited this session
        #: (in first-visit order — stamped into the trace metadata so the
        #: analysis side knows whether scaled estimates are exact), and the
        #: lock serializing drains against mid-run rung flips
        self._fidelity = cfg.fidelity
        self._modes_used = [cfg.fidelity]
        self._drain_lock = threading.Lock()
        self._seen_drops: Dict[Tuple[int, int], int] = {}
        #: final in-process folded tally (set at stop() when an online
        #: analyzer ran — always the case for tally-only sessions)
        self.final_tally = None

    # -- properties used by the interception layer ---------------------------
    @property
    def mode(self) -> str:
        return self.cfg.mode

    @property
    def fidelity(self) -> str:
        return self._fidelity

    @property
    def full(self) -> bool:
        return (
            self.cfg.mode == "full"
            and self._fidelity != "off"
            and self.selected
            and self._started
        )

    # -- fidelity ladder ------------------------------------------------------
    def set_mode(self, mode: str) -> str:
        """Move the session to another rung of the fidelity ladder mid-run;
        returns the previous rung.

        Handoff protocol (the conformance suite's mode-switch invariant):
        records already published are drained under the *outgoing* rung's
        policy before the recorders flip, the flip itself is one atomic
        ``__code__`` store per recorder (all variants share one signature and
        defaults tuple), and records are published whole (pack first, one
        atomic ``head`` store) — so no drain ever observes a torn or
        reordered record, in either rung's policy.
        """
        if mode not in FIDELITY_MODES:
            raise ValueError(f"unknown fidelity {mode!r} (want one of {FIDELITY_MODES})")
        if not self._started:
            raise RuntimeError("tracer not started")
        if not self.selected:  # untraced rank: track the rung, nothing to flip
            prev, self._fidelity = self._fidelity, mode
            return prev
        with self._drain_lock:
            prev = self._fidelity
            if mode == prev:
                return prev
            self._drain_unlocked()  # pending records leave under the old policy
            if mode == "tally-only" and self.online is None:
                from .online import OnlineAnalyzer

                self.online = OnlineAnalyzer(self.model, hostname=socket.gethostname())
            self.tp.set_fidelity(mode, interval=self.cfg.sampling_interval)
            self._fidelity = mode
            if mode not in self._modes_used:
                self._modes_used.append(mode)
        # one advisory per rung change, recorded into the trace itself (the
        # same channel adaptive policies use) — post-mortem analysis sees
        # when the session reconfigured; a flip to "off" records nothing by
        # construction (every enablement flag is already zero)
        rec = self.tp.record.get("ust_repro:advisory")
        if rec is not None:
            rec("fidelity", "set_mode", f"{prev}->{mode}")
        return prev

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Tracer":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("a tracing session is already active")
        if not self.selected:
            _ACTIVE = self  # active but disabled: recorders stay off
            self._started = True
            return self
        os.makedirs(self.cfg.out_dir, exist_ok=True)
        self.clock = ClockInfo.capture()
        self.registry = RingRegistry(self.cfg.ring_bytes, pid=os.getpid())
        enabled = events_for_mode(self.model, self.cfg.mode, self.cfg.sample)
        if self.cfg.event_overrides:
            name2ev = self.model.by_name()
            for name, on in self.cfg.event_overrides.items():
                eid = name2ev[name].eid
                (enabled.add if on else enabled.discard)(eid)
        self.tp.attach(self.registry, sorted(enabled), ring_reserve=self.cfg.ring_reserve)
        if self.cfg.fidelity != "full":
            self.tp.set_fidelity(
                self.cfg.fidelity,
                interval=self.cfg.sampling_interval,
                seed=self.cfg.sampling_seed,
            )
        elif self.cfg.sampling_seed is not None:
            # seed up front so a later mid-run flip into "sampled" is
            # deterministic too
            self.tp.set_fidelity(
                "full", interval=self.cfg.sampling_interval, seed=self.cfg.sampling_seed
            )
        # tally-only folds in-process via the online analyzer even when the
        # live-tally feature itself wasn't requested
        if self.cfg.online or self.cfg.fidelity == "tally-only":
            from .online import OnlineAnalyzer

            self.online = OnlineAnalyzer(self.model, hostname=socket.gethostname())
        if self.cfg.serve_port is not None or self.cfg.stream_to is not None:
            import dataclasses as _dc

            from .stream import (
                MasterServer,
                ServeOptions,
                SnapshotStreamer,
                client_ssl_context,
                default_source,
            )

            self._stream_source = self.cfg.stream_source or default_source(
                self.cfg.rank
            )
            if self.cfg.serve_port is not None:
                # In-process master: serves this rank's live tally (plus any
                # children streaming to it); forwards upstream when stream_to
                # is also set — this rank then acts as a local master.
                opts = self.cfg.serve_options
                if opts is None:
                    opts = ServeOptions(
                        fanout=self.cfg.stream_fanout,
                        forward_delta=self.cfg.stream_delta,
                        forward_resync_every=self.cfg.stream_resync_every,
                        forward_ranks=self.cfg.stream_ranks,
                    )
                # stream_token/stream_tls_ca are upstream credentials: inject
                # them unless the options already carry their own
                if self.cfg.stream_token is not None and opts.forward_token is None:
                    opts = _dc.replace(opts, forward_token=self.cfg.stream_token)
                if self.cfg.stream_tls_ca is not None and opts.forward_tls_ca is None:
                    opts = _dc.replace(opts, forward_tls_ca=self.cfg.stream_tls_ca)
                self.server = MasterServer(
                    port=self.cfg.serve_port,
                    forward_to=self.cfg.stream_to,
                    forward_period_s=self.cfg.stream_period_s,
                    options=opts,
                ).start()
            else:
                self.streamer = SnapshotStreamer(
                    self.cfg.stream_to,
                    source=self._stream_source,
                    delta=self.cfg.stream_delta,
                    resync_every=self.cfg.stream_resync_every,
                    token=self.cfg.stream_token,
                    ssl_context=(
                        client_ssl_context(cafile=self.cfg.stream_tls_ca)
                        if self.cfg.stream_tls_ca
                        else None
                    ),
                    connect_retries=self.cfg.stream_connect_retries,
                    connect_backoff_s=self.cfg.stream_connect_backoff_s,
                    incarnation=self.cfg.stream_incarnation,
                )
        if self.cfg.adaptive is not None:
            from .adaptive import build_controller

            self.adaptive = build_controller(
                self.cfg.adaptive, period_s=self.cfg.adaptive_period_s
            )
            self.adaptive.attach(self)
        if self.cfg.cluster_adaptive is not None:
            from .adaptive import build_cluster_controller

            self.cluster = build_cluster_controller(
                self.cfg.cluster_adaptive, period_s=self.cfg.cluster_period_s
            )
            self.cluster.bind(master=self.server)
            self.cluster.attach(self)  # advisories land in this rank's trace
        if self.cfg.remediation is not None:
            self.remediation = self.cfg.remediation
            self.remediation.attach(self)  # decisions land in this rank's trace
            if self.cluster is not None:
                # close the loop: cluster flags feed the escalation ladder,
                # healthy windows feed its hysteresis (unless the caller
                # already wired its own channels)
                if getattr(self.cluster, "on_flag", None) is None:
                    self.cluster.on_flag = self.remediation.ingest_flag
                if getattr(self.cluster, "on_healthy", None) is None:
                    self.cluster.on_healthy = self.remediation.observe_healthy
        self._stop_evt.clear()
        self._consumer = threading.Thread(
            target=self._consumer_loop, name="thapi-consumer", daemon=True
        )
        self._consumer.start()
        if self.cfg.sample:
            self._sampler = _telemetry.TelemetryDaemon(
                record=self.tp.record["ust_thapi:sample"],
                period_s=self.cfg.sample_period_s,
            )
            self._sampler.start()
        self._started = True
        _ACTIVE = self
        return self

    def stop(self) -> TraceHandle:
        global _ACTIVE
        if not self._started:
            raise RuntimeError("tracer not started")
        if not self.selected:
            _ACTIVE = None
            self._started = False
            self.handle = TraceHandle(
                self.cfg.out_dir, self.cfg.mode, 0, 0, 0, fidelity=self._fidelity
            )
            return self.handle
        try:
            if self._sampler is not None:
                self._sampler.stop()
            self.tp.detach()  # stop producing before the final drain
            self._stop_evt.set()
            assert self._consumer is not None
            self._consumer.join(timeout=10.0)
            self._drain_once()  # final drain catches post-loop residue
            self._stream_tick(final=True)  # authoritative last snapshot
            if self.streamer is not None:
                self.streamer.close()
            if self.server is not None:
                self.server.stop()  # flushes the composite upstream first
            for w in self._writers.values():
                w.close()
            for key, cw in self._colwriters.items():
                # staleness is keyed on the final on-disk stream size, so the
                # stream writer must be closed (flushed) first
                cw.close(os.path.getsize(self._writers[key].path))
            assert self.registry is not None and self.clock is not None
            #: pure-sampled sessions carry exact estimator semantics; mixed-
            #: fidelity sessions stamp every rung visited so the fold knows
            #: scaled counts would NOT be exact and reports raw ones instead
            write_metadata(
                self.cfg.out_dir,
                self.model,
                self.clock,
                env={
                    "hostname": socket.gethostname(),
                    "pid": os.getpid(),
                    "argv": sys.argv,
                    "rank": self.cfg.rank,
                    "sample": self.cfg.sample,
                    "fidelity": {
                        "final": self._fidelity,
                        "interval": self.cfg.sampling_interval,
                        "modes_used": list(self._modes_used),
                    },
                },
                mode=self.cfg.mode,
            )
            events = self.registry.total_events
            dropped = self.registry.total_dropped
            if self.online is not None:
                # flush unmatched entries exactly like the offline fold's
                # finish(), and scale when the estimator semantics are exact
                scale = (
                    self.cfg.sampling_interval
                    if self._modes_used == ["sampled"]
                    else 1
                )
                self.final_tally = self.online.finish(scale=scale)
            agg_path = None
            if self.cfg.aggregate_only:
                agg_path = self._write_aggregate_and_prune()
            elif (
                "tally-only" in self._modes_used
                and not self._writers
                and self.final_tally is not None
            ):
                # a session that never streamed still leaves its kilobyte
                # aggregate behind (§3.7 shape, producer-side fold)
                from .aggregate import save_tally

                agg_path = os.path.join(
                    self.cfg.out_dir, f"aggregate_rank{self.cfg.rank}.tally"
                )
                save_tally(self.final_tally, agg_path)
            # upstream delivery counters live on the leaf streamer, or on the
            # in-process master's forwarder when this rank is a local master
            pusher = self.streamer
            if pusher is None and self.server is not None:
                pusher = self.server.forwarder
            self.handle = TraceHandle(
                trace_dir=self.cfg.out_dir,
                mode=self.cfg.mode,
                events=events,
                dropped=dropped,
                size_bytes=trace_size_bytes(self.cfg.out_dir),
                aggregate_path=agg_path,
                streamed=pusher.pushed if pusher else 0,
                stream_dropped=pusher.dropped if pusher else 0,
                fidelity=self._fidelity,
            )
        finally:
            # a failed teardown must never leave the process un-traceable
            _ACTIVE = None
            self._started = False
        return self.handle

    def __enter__(self) -> "Tracer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- consumer daemon -------------------------------------------------------
    def _drain_once(self) -> None:
        with self._drain_lock:
            self._drain_unlocked()

    def _drain_unlocked(self) -> None:
        """Drain every ring zero-copy: stream + online analysis read the ring
        storage through ``drain_view`` memoryviews and the region is released
        only after both consumed it — no intermediate ``bytes`` on the common
        (single-region) path.  A ring that has produced nothing (an idle
        thread) gets no ``StreamWriter`` — and so no empty ``stream_*.ctf``
        file — until its first record or drop shows up; the ``now()`` stamp
        for discard records is only taken when the drop counter advanced.

        On the "tally-only" fidelity rung the stream path is bypassed
        entirely — records fold straight into the online analyzer (producer-
        side FoldEngine) and no ``.ctf`` file is created or appended; ring
        drops are accounted into the online tally instead of a stream
        discard record.  Caller holds ``_drain_lock`` (drains serialize
        against mid-run rung flips)."""
        assert self.registry is not None
        writers = self._writers
        online = self.online
        tally_only = self._fidelity == "tally-only"
        for ring in self.registry.rings():
            regions = ring.drain_view()
            dropped = ring.dropped
            key = (ring.pid, ring.tid)
            if tally_only:
                if regions:
                    chunk = regions[0] if len(regions) == 1 else b"".join(regions)
                    online.feed(chunk, ring.pid, ring.tid)
                    ring.release()
                seen = self._seen_drops.get(key)
                if seen is None:
                    w = writers.get(key)
                    seen = w.seen_dropped if w is not None else 0
                if dropped != seen:
                    online.note_discarded(dropped - seen)
                    self._seen_drops[key] = dropped
                continue
            w = writers.get(key)
            if w is None:
                if not regions and not dropped:
                    continue  # idle thread: defer stream-file creation
                path = os.path.join(self.cfg.out_dir, f"stream_{ring.pid}_{ring.tid}.ctf")
                w = writers[key] = StreamWriter(
                    path, ring.pid, ring.tid, compress=self.cfg.compress
                )
                # drops already accounted to the online tally during a
                # tally-only window must not re-emit as stream discards
                if key in self._seen_drops:
                    w.seen_dropped = self._seen_drops.pop(key)
            elif key in self._seen_drops:
                w.seen_dropped = max(w.seen_dropped, self._seen_drops.pop(key))
            cw = self._colwriters.get(key)
            if cw is None and self.cfg.columnar:
                cw = self._colwriters[key] = self._new_colwriter(w)
            if regions:
                for r in regions:
                    w.append(r)
                if online is not None or cw is not None:
                    # two regions = wrap: records may straddle the boundary,
                    # so the folds get them joined (rare; one copy)
                    chunk = regions[0] if len(regions) == 1 else b"".join(regions)
                    if online is not None:
                        online.feed(chunk, ring.pid, ring.tid)
                    if cw is not None:
                        cw.append(chunk)
                ring.release()
            if dropped != w.seen_dropped:
                delta = dropped - w.seen_dropped
                w.note_drops(dropped, now())
                if cw is not None and delta > 0:
                    # discard records go straight to the stream file; the
                    # sidecar's footer tally must account them too
                    cw.note_discard(delta)

    def _new_colwriter(self, w: StreamWriter):
        from .ctf import ColumnarWriter, sidecar_path

        if self._fold_engine is None:
            from .fold import FoldEngine

            self._fold_engine = FoldEngine(self.model)
        return ColumnarWriter(self._fold_engine, w.pid, w.tid, sidecar_path(w.path))

    def _consumer_loop(self) -> None:
        while not self._stop_evt.wait(self.cfg.flush_period_s):
            self._drain_once()
            self._stream_tick()
            if self.adaptive is not None:
                self.adaptive.tick()
            if self.cluster is not None:
                self.cluster.tick()
            if self.remediation is not None:
                try:
                    self.remediation.tick()
                except Exception:
                    pass  # remediation must never kill the consumer thread

    def _stream_tick(self, final: bool = False) -> None:
        """Push the live tally to the streaming service (§3.7+§6).

        One snapshot feeds both targets: the in-process master (when this
        rank serves) and the upstream master (when this rank is a leaf).
        The final push at stop() is unconditional — it carries the
        authoritative cumulative tally the composite converges on.
        """
        if self.online is None or (self.streamer is None and self.server is None):
            return
        t = time.monotonic()
        if not final and t < self._stream_next:
            return
        self._stream_next = t + self.cfg.stream_period_s
        snap = self.online.snapshot()
        if final and self._modes_used == ["sampled"] and self.cfg.sampling_interval > 1:
            # the authoritative last push carries the same 1/N estimate the
            # offline fold (and finish()) produce for a pure-sampled session,
            # so the live composite converges on the on-disk aggregate
            snap.scale(self.cfg.sampling_interval)
        telem = self._telemetry_snapshot() if self.cfg.stream_telemetry else None
        if self.server is not None:
            self.server.submit(
                self._stream_source,
                snap,
                telemetry=telem,
                incarnation=self.cfg.stream_incarnation,
            )
        if self.streamer is not None:
            self.streamer.push(snap, telemetry=telem)

    def _telemetry_snapshot(self) -> Optional[dict]:
        """This rank's device-telemetry dict for the outgoing frame.

        With the sampling daemon on, reuse its latest sample (one reader of
        the shared gauges).  Without it, take a cheap inline reading — the
        gauges' only reader is then this tick, so read-and-reset is safe.
        """
        if self._sampler is not None:
            last = self._sampler.last
            return dict(last) if last else None
        in_use, peak, limit = _telemetry.read_device_memory()
        memcpy_bw, alloc_bw = _telemetry.TransferGauge.read_and_reset()
        return {
            "mem_in_use": in_use,
            "mem_peak": peak,
            "mem_limit": limit,
            "host_rss": _telemetry.read_host_rss(),
            "step_rate": _telemetry.StepRateGauge.read_and_reset(),
            "memcpy_bw": memcpy_bw,
            "alloc_bw": alloc_bw,
        }

    # -- §3.7 aggregate-only ---------------------------------------------------
    def _write_aggregate_and_prune(self) -> str:
        # Imported here: analysis layer depends on tracer, not vice versa.
        from .aggregate import save_tally
        from .plugins.tally import tally_trace

        tally = tally_trace(self.cfg.out_dir, legacy_graph=self.cfg.legacy_graph)
        path = os.path.join(self.cfg.out_dir, f"aggregate_rank{self.cfg.rank}.tally")
        save_tally(tally, path)
        for name in os.listdir(self.cfg.out_dir):
            if name.endswith((".ctf", ".ctfcol")):
                os.unlink(os.path.join(self.cfg.out_dir, name))
        return path


def trace_session(out_dir: str, mode: str = "default", **kw) -> Tracer:
    """Convenience constructor mirroring ``iprof -m <mode> --sample``."""
    return Tracer(TraceConfig(out_dir=out_dir, mode=mode, **kw))

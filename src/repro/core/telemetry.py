"""Device-sampling daemon (THAPI §3.5).

THAPI's sampling framework is a daemon that polls Level-Zero Sysman counters
(energy, frequency, memory, fabric, utilization) at a user-defined period
(default 50 ms) and streams them into the LTTng trace.

Our heterogeneous devices are JAX devices.  On TPU, ``device.memory_stats()``
exposes HBM occupancy; on this CPU container the same call may return None,
in which case we fall back to host counters only — the daemon architecture
(thread + period + counter events into the trace) is identical.  Host RSS and
CPU% stand in for the power/frequency domains that have no CPU analogue
(DESIGN.md §2, §7).
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable, Optional

_PAGE = os.sysconf("SC_PAGE_SIZE") if hasattr(os, "sysconf") else 4096


def read_host_rss() -> int:
    """Resident set size in bytes, from /proc (no psutil dependency)."""
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * _PAGE
    except (OSError, ValueError, IndexError):
        return 0


def read_device_memory(device=None) -> tuple:
    """(in_use, peak, limit) bytes for the given (default: first) device."""
    try:
        import jax

        dev = device if device is not None else jax.local_devices()[0]
        stats = dev.memory_stats()
        if stats:
            return (
                int(stats.get("bytes_in_use", 0)),
                int(stats.get("peak_bytes_in_use", 0)),
                int(stats.get("bytes_limit", 0)),
            )
    except Exception:
        pass
    return (0, 0, 0)


class StepRateGauge:
    """Shared gauge the trainer bumps each step; the daemon samples it.

    Replaces the paper's GPU utilization domains with a framework-level
    utilization signal (steps/s) that makes sense for a training runtime.
    """

    _lock = threading.Lock()
    _count = 0
    _t0 = time.monotonic()

    @classmethod
    def bump(cls, n: int = 1) -> None:
        with cls._lock:
            cls._count += n

    @classmethod
    def read_and_reset(cls) -> float:
        with cls._lock:
            t = time.monotonic()
            dt = t - cls._t0
            rate = cls._count / dt if dt > 0 else 0.0
            cls._count = 0
            cls._t0 = t
            return rate


class TransferGauge:
    """Shared byte counters the interception layer bumps on every memcpy /
    alloc; the daemon (and the stream tick) reads them as bandwidths.

    Same class-gauge pattern as :class:`StepRateGauge`: the fused pair
    recorders in ``core/interception.py`` call :meth:`bump_memcpy` /
    :meth:`bump_alloc` on the hot path (one lock + add), and
    :meth:`read_and_reset` converts the window's bytes into bytes/s.  This
    is the "transfer bandwidth from the memcpy/alloc tracepoints" evidence
    channel the remediation policies use to tell a slow kernel from a sick
    host (ROADMAP "closed-loop remediation").
    """

    _lock = threading.Lock()
    _memcpy_bytes = 0
    _alloc_bytes = 0
    _t0 = time.monotonic()

    @classmethod
    def bump_memcpy(cls, nbytes: int) -> None:
        with cls._lock:
            cls._memcpy_bytes += nbytes

    @classmethod
    def bump_alloc(cls, nbytes: int) -> None:
        with cls._lock:
            cls._alloc_bytes += nbytes

    @classmethod
    def read_and_reset(cls) -> tuple:
        """(memcpy_bytes_per_s, alloc_bytes_per_s) over the window since the
        last read; resets the window."""
        with cls._lock:
            t = time.monotonic()
            dt = t - cls._t0
            mc = cls._memcpy_bytes / dt if dt > 0 else 0.0
            al = cls._alloc_bytes / dt if dt > 0 else 0.0
            cls._memcpy_bytes = 0
            cls._alloc_bytes = 0
            cls._t0 = t
            return (mc, al)


class TelemetryDaemon:
    """Sampling thread: one ``ust_thapi:sample`` counter event per period."""

    def __init__(self, record: Callable, period_s: float = 0.05, device_index: int = 0):
        self._record = record
        self.period_s = period_s
        self.device_index = device_index
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._last_cpu = (time.process_time(), time.monotonic())
        self.samples = 0
        self.sample_errors = 0
        self.last: dict = {}  # most recent sample, for the stream tick

    def _cpu_pct(self) -> float:
        pt, wt = time.process_time(), time.monotonic()
        lpt, lwt = self._last_cpu
        self._last_cpu = (pt, wt)
        dw = wt - lwt
        return 100.0 * (pt - lpt) / dw if dw > 0 else 0.0

    def sample_once(self) -> None:
        in_use, peak, limit = read_device_memory()
        host_rss = read_host_rss()
        cpu_pct = self._cpu_pct()
        step_rate = StepRateGauge.read_and_reset()
        memcpy_bw, alloc_bw = TransferGauge.read_and_reset()
        self.last = {
            "mem_in_use": in_use,
            "mem_peak": peak,
            "mem_limit": limit,
            "host_rss": host_rss,
            "cpu_pct": cpu_pct,
            "step_rate": step_rate,
            "memcpy_bw": memcpy_bw,
            "alloc_bw": alloc_bw,
        }
        self._record(
            self.device_index,
            in_use,
            peak,
            limit,
            host_rss,
            cpu_pct,
            step_rate,
        )
        self.samples += 1

    def _loop(self) -> None:
        while not self._stop.wait(self.period_s):
            # One bad read (transient /proc or device-stats failure) must not
            # kill the daemon thread: count it and keep sampling.
            try:
                self.sample_once()
            except Exception:
                self.sample_errors += 1

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, name="thapi-sampler", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

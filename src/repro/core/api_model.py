"""API models + meta-parameters (THAPI §3.3, Fig 1b, Fig 3).

THAPI parses API headers (CUDA/L0/HIP) or XML descriptions (OpenCL) into an
intermediary YAML *API model*, then enriches it with user-provided
*meta-parameters* (e.g. ``cuMemGetInfo: [[OutScalar, free], [OutScalar,
total]]``) that encode expert knowledge the headers cannot express: which
pointer args are inputs vs outputs, which APIs need device-profiling code,
which are polling/spin-lock APIs to exclude from the default mode.

Here the "headers" of our heterogeneous stack are Python call signatures and
declarative specs.  The same pipeline applies:

    declarative spec (this module)  ≙  header/XML parse → YAML API model
    Meta-parameters                 ≙  THAPI meta-parameters (Fig 3 bottom-left)
    build_trace_model()             ≙  API model → LTTng trace model (Fig 3 mid)
    tracepoints.generate_recorders  ≙  trace model → TRACEPOINT_EVENT codegen

Field classes map onto CTF integer/float/string classes with display hints
(pointers print base-16, exactly like the ``preferred_display_base: 16`` in
Fig 3).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Field classes (≙ CTF field classes). struct codes drive the codegen.
# ---------------------------------------------------------------------------

FIELD_CLASSES: Mapping[str, str] = {
    "u8": "B",
    "u16": "H",
    "u32": "I",
    "u64": "Q",
    "i32": "i",
    "i64": "q",
    "f32": "f",
    "f64": "d",
    "bool": "B",
    "ptr": "Q",  # preferred_display_base: 16
    # varlen classes (u32 length prefix), handled outside struct:
    "str": None,
    "bytes": None,
}

VARLEN = frozenset({"str", "bytes"})


@dataclasses.dataclass(frozen=True)
class Param:
    """One API parameter (≙ a ``params`` entry of the API model in Fig 3)."""

    name: str
    cls: str  # one of FIELD_CLASSES
    display_base: int = 10

    def __post_init__(self):
        if self.cls not in FIELD_CLASSES:
            raise ValueError(f"unknown field class {self.cls!r} for param {self.name!r}")

    def to_json(self) -> dict:
        return {"name": self.name, "class": self.cls, "display_base": self.display_base}


def P(name: str, cls: str) -> Param:
    """Shorthand constructor; pointers get base-16 display automatically."""
    return Param(name, cls, display_base=16 if cls == "ptr" else 10)


# ---------------------------------------------------------------------------
# Meta-parameters (THAPI Fig 3: expert knowledge the headers can't express).
# ---------------------------------------------------------------------------
#
#   OutScalar  — value produced by the call, recorded on the *exit* event
#                (cuMemGetInfo free/total in the paper's running example).
#   InScalar   — extra semantic input recorded on the *entry* event.
#   Profiled   — attach device-profiling code: the wrapper fences the device
#                and emits a span event with device start/end timestamps
#                (≙ "Cuda record entry/exit", "Level-Zero profiling" in Fig 2).
#   Polling    — spin-lock style API (zeEventHostSynchronize/cuQueryEvent
#                class): traced only in FULL mode (§5.2 "non-spawned APIs").
#   ArgDump    — serialize small argument buffers into the event payload
#                (full mode only; "values behind pointers", §1.1).

META_KINDS = ("OutScalar", "InScalar", "Profiled", "Polling", "ArgDump")


@dataclasses.dataclass(frozen=True)
class APISpec:
    """One traced API: entry/exit payload schema + meta-parameters."""

    name: str
    params: Tuple[Param, ...] = ()
    result: Optional[Param] = None
    meta: Tuple[Tuple[str, Param], ...] = ()  # (kind, param)
    span: bool = False  # device-span API: single event w/ start+end ts
    counter: bool = False  # telemetry counter: single sample event, no entry/exit

    def __post_init__(self):
        for kind, _ in self.meta:
            if kind not in META_KINDS:
                raise ValueError(f"unknown meta-parameter kind {kind!r} on {self.name}")

    # -- derived -----------------------------------------------------------
    @property
    def tags(self) -> frozenset:
        return frozenset(k for k, _ in self.meta)

    @property
    def is_polling(self) -> bool:
        return "Polling" in self.tags

    @property
    def is_profiled(self) -> bool:
        return "Profiled" in self.tags

    def entry_fields(self) -> Tuple[Param, ...]:
        extra = tuple(p for k, p in self.meta if k == "InScalar")
        return self.params + extra

    def exit_fields(self) -> Tuple[Param, ...]:
        out = tuple(p for k, p in self.meta if k == "OutScalar")
        res = (self.result,) if self.result is not None else ()
        return res + out

    def dump_fields(self) -> Tuple[Param, ...]:
        return tuple(p for k, p in self.meta if k == "ArgDump")


@dataclasses.dataclass(frozen=True)
class APIModel:
    """A programming-model description (≙ one YAML API model per backend)."""

    provider: str  # e.g. "ust_jaxrt" — ≙ lttng_ust_cuda domain prefix
    apis: Tuple[APISpec, ...]

    def by_name(self) -> Mapping[str, APISpec]:
        return {a.name: a for a in self.apis}


# ---------------------------------------------------------------------------
# Trace model (≙ the LTTng trace model of Fig 3, consumed by the codegen and
# by the Babeltrace-style analysis layer).
# ---------------------------------------------------------------------------

#: event id 0 is reserved for the CTF "discarded events" record the consumer
#: emits when it observes the ring-buffer drop counter advance (LTTng discard
#: mode, §3.1).
DISCARD_EVENT_ID = 0
DISCARD_EVENT_NAME = "ctf:events_discarded"


@dataclasses.dataclass(frozen=True)
class EventType:
    eid: int
    name: str  # "provider:api_entry" etc.
    provider: str
    api: str
    phase: str  # "entry" | "exit" | "span" | "sample" | "meta"
    fields: Tuple[Param, ...]
    polling: bool = False

    def to_json(self) -> dict:
        return {
            "eid": self.eid,
            "name": self.name,
            "provider": self.provider,
            "api": self.api,
            "phase": self.phase,
            "polling": self.polling,
            "fields": [f.to_json() for f in self.fields],
        }

    @staticmethod
    def from_json(d: dict) -> "EventType":
        return EventType(
            eid=int(d["eid"]),
            name=d["name"],
            provider=d["provider"],
            api=d["api"],
            phase=d["phase"],
            polling=bool(d.get("polling", False)),
            fields=tuple(
                Param(f["name"], f["class"], int(f.get("display_base", 10)))
                for f in d["fields"]
            ),
        )


@dataclasses.dataclass(frozen=True)
class TraceModel:
    """All event types of a session, id-indexed. Serialized into metadata.json
    so analysis tools are *generated from the trace model*, never hand-kept in
    sync (the paper's maintainability argument, §3.3 summary)."""

    events: Tuple[EventType, ...]

    def __post_init__(self):
        for i, e in enumerate(self.events):
            if e.eid != i:
                raise ValueError("event ids must be dense and ordered")

    def by_name(self) -> Mapping[str, EventType]:
        return {e.name: e for e in self.events}

    def to_json(self) -> list:
        return [e.to_json() for e in self.events]

    @staticmethod
    def from_json(items: Iterable[dict]) -> "TraceModel":
        return TraceModel(tuple(EventType.from_json(d) for d in items))


SPAN_EXTRA_FIELDS = (P("ts_begin", "u64"), P("ts_end", "u64"))


def build_trace_model(models: Sequence[APIModel]) -> TraceModel:
    """API models → trace model (Fig 3 middle column).

    Every API yields ``<provider>:<name>_entry`` / ``_exit`` events (or a
    single ``_span`` event for device-span APIs, which carry begin/end device
    timestamps like Level-Zero profiling results read "during wait").
    """
    events = [
        EventType(
            eid=DISCARD_EVENT_ID,
            name=DISCARD_EVENT_NAME,
            provider="ctf",
            api="events_discarded",
            phase="meta",
            fields=(P("count", "u64"),),
        )
    ]
    for model in models:
        for api in model.apis:
            if api.counter:
                events.append(
                    EventType(
                        eid=len(events),
                        name=f"{model.provider}:{api.name}",
                        provider=model.provider,
                        api=api.name,
                        phase="sample",
                        fields=api.entry_fields(),
                        polling=api.is_polling,
                    )
                )
                continue
            if api.span:
                events.append(
                    EventType(
                        eid=len(events),
                        name=f"{model.provider}:{api.name}_span",
                        provider=model.provider,
                        api=api.name,
                        phase="span",
                        fields=SPAN_EXTRA_FIELDS + api.entry_fields() + api.exit_fields(),
                        polling=api.is_polling,
                    )
                )
                continue
            events.append(
                EventType(
                    eid=len(events),
                    name=f"{model.provider}:{api.name}_entry",
                    provider=model.provider,
                    api=api.name,
                    phase="entry",
                    fields=api.entry_fields() + api.dump_fields(),
                    polling=api.is_polling,
                )
            )
            events.append(
                EventType(
                    eid=len(events),
                    name=f"{model.provider}:{api.name}_exit",
                    provider=model.provider,
                    api=api.name,
                    phase="exit",
                    fields=api.exit_fields(),
                    polling=api.is_polling,
                )
            )
    return TraceModel(tuple(events))


# ---------------------------------------------------------------------------
# The built-in API models of this framework's heterogeneous stack.
# Layering (top to bottom), mirroring HIP→Level-Zero in the paper's HIPLZ
# case study (§4.3): ust_repro (framework) → ust_jaxrt (JAX dispatch/memory)
# → ust_kernel / ust_collective (device) → ust_thapi (telemetry daemon).
# ---------------------------------------------------------------------------


def framework_model() -> APIModel:
    """ust_repro — framework-level API (≙ OMPT/Kokkos layer)."""
    return APIModel(
        provider="ust_repro",
        apis=(
            APISpec(
                "train_step",
                params=(P("step", "u64"), P("global_batch", "u32"), P("seq_len", "u32")),
                result=P("status", "u32"),
                meta=(
                    ("OutScalar", P("loss", "f32")),
                    ("OutScalar", P("grad_norm", "f32")),
                    ("Profiled", P("device", "u8")),
                ),
            ),
            APISpec(
                "eval_step",
                params=(P("step", "u64"), P("global_batch", "u32")),
                result=P("status", "u32"),
                meta=(("OutScalar", P("loss", "f32")),),
            ),
            APISpec(
                "data_next",
                params=(P("step", "u64"),),
                result=P("status", "u32"),
                meta=(("OutScalar", P("tokens", "u64")),),
            ),
            APISpec(
                "checkpoint_save",
                params=(P("step", "u64"), P("path", "str"), P("nbytes", "u64")),
                result=P("status", "u32"),
            ),
            APISpec(
                "checkpoint_restore",
                params=(P("path", "str"),),
                result=P("status", "u32"),
                meta=(("OutScalar", P("step", "u64")),),
            ),
            APISpec(
                "optimizer_update",
                params=(P("step", "u64"),),
                result=P("status", "u32"),
                meta=(("OutScalar", P("lr", "f32")),),
            ),
            APISpec(  # serving layer
                "prefill",
                params=(P("request_id", "u64"), P("batch", "u32"), P("seq_len", "u32")),
                result=P("status", "u32"),
                meta=(("Profiled", P("device", "u8")),),
            ),
            APISpec(
                "decode_step",
                params=(P("request_id", "u64"), P("batch", "u32"), P("cache_len", "u32")),
                result=P("status", "u32"),
                meta=(("OutScalar", P("tokens_out", "u32")), ("Profiled", P("device", "u8"))),
            ),
            APISpec(  # spin-lock style completion poll — FULL mode only (§5.2)
                "poll_ready",
                params=(P("handle", "ptr"),),
                result=P("ready", "bool"),
                meta=(("Polling", P("handle", "ptr")),),
            ),
            APISpec(  # §6 adaptive consumer: one advisory per knob change,
                # recorded into the trace so post-mortem analysis sees when
                # and why the session reconfigured itself mid-run
                "advisory",
                params=(P("policy", "str"), P("knob", "str"), P("detail", "str")),
                counter=True,
            ),
        ),
    )


def jaxrt_model() -> APIModel:
    """ust_jaxrt — JAX dispatch + memory layer (≙ lttng_ust_ze / lttng_ust_cuda).

    ``memcpy`` mirrors the paper's zeCommandListAppendMemoryCopy running
    example: src/dst pointers + size let the analysis deduce H2D vs D2H from
    the address classes (§1.1).
    """
    return APIModel(
        provider="ust_jaxrt",
        apis=(
            APISpec(
                "dispatch",
                params=(
                    P("fn", "str"),
                    P("nargs", "u32"),
                    P("arg_bytes", "u64"),
                    P("donated_bytes", "u64"),
                ),
                result=P("status", "u32"),
            ),
            APISpec(
                "compile",
                params=(P("fn", "str"), P("fingerprint", "u64")),
                result=P("status", "u32"),
                meta=(("OutScalar", P("cache_hit", "bool")),),
            ),
            APISpec(
                "memcpy",
                params=(
                    P("src", "ptr"),
                    P("dst", "ptr"),
                    P("nbytes", "u64"),
                    P("kind", "u8"),  # 0 h2d, 1 d2h, 2 d2d
                ),
                result=P("status", "u32"),
                meta=(("ArgDump", P("payload_head", "bytes")),),
            ),
            APISpec(
                "alloc",
                params=(P("nbytes", "u64"), P("device", "u8")),
                result=P("ptr", "ptr"),
            ),
            APISpec("free", params=(P("ptr", "ptr"),), result=P("status", "u32")),
            APISpec(
                "block_until_ready",
                params=(P("handle", "ptr"),),
                result=P("status", "u32"),
                meta=(("Polling", P("handle", "ptr")),),
            ),
        ),
    )


def kernel_model() -> APIModel:
    """ust_kernel — device execution spans (≙ GPU kernel timings, Fig 2
    Scenario 2 'GPU profiling code'). Span events carry device begin/end."""
    return APIModel(
        provider="ust_kernel",
        apis=(
            APISpec(
                "launch",
                params=(
                    P("name", "str"),
                    P("grid_x", "u32"),
                    P("grid_y", "u32"),
                    P("grid_z", "u32"),
                    P("flops", "u64"),
                    P("bytes_accessed", "u64"),
                ),
                span=True,
            ),
            APISpec(
                "transfer",
                params=(P("nbytes", "u64"), P("kind", "u8")),
                span=True,
            ),
        ),
    )


def collective_model() -> APIModel:
    """ust_collective — XLA/communication layer (≙ MPI model in THAPI)."""
    return APIModel(
        provider="ust_collective",
        apis=(
            APISpec(
                "all_reduce",
                params=(P("nbytes", "u64"), P("axis", "str"), P("n_devices", "u32")),
                span=True,
            ),
            APISpec(
                "all_gather",
                params=(P("nbytes", "u64"), P("axis", "str"), P("n_devices", "u32")),
                span=True,
            ),
            APISpec(
                "reduce_scatter",
                params=(P("nbytes", "u64"), P("axis", "str"), P("n_devices", "u32")),
                span=True,
            ),
            APISpec(
                "all_to_all",
                params=(P("nbytes", "u64"), P("axis", "str"), P("n_devices", "u32")),
                span=True,
            ),
            APISpec(
                "broadcast",
                params=(P("nbytes", "u64"), P("root", "u32"), P("n_devices", "u32")),
                span=True,
            ),
            APISpec(
                "barrier",
                params=(P("name", "str"), P("n_devices", "u32")),
                span=True,
            ),
        ),
    )


def telemetry_model() -> APIModel:
    """ust_thapi — device-sampling daemon counters (≙ Sysman telemetry, §3.5).

    PVC power/frequency domains have no CPU analogue; the counter *channel*
    design is identical (daemon, default 50 ms period, streamed to the trace).
    On TPU these bind to libtpu power/HBM counters.
    """
    return APIModel(
        provider="ust_thapi",
        apis=(
            APISpec(
                "sample",
                params=(
                    P("device", "u8"),
                    P("mem_in_use", "u64"),
                    P("mem_peak", "u64"),
                    P("mem_limit", "u64"),
                    P("host_rss", "u64"),
                    P("host_cpu_pct", "f32"),
                    P("step_rate", "f32"),
                ),
                counter=True,
            ),
        ),
    )


def user_model() -> APIModel:
    """ust_user — application-visible user API (≙ Extrae's user events).

    ``annotate`` is a one-shot marker with a JSON-encoded payload;
    ``phase`` is an entry/exit pair bracketing an application phase, so
    user phases tally and fold exactly like traced API calls.  Appended
    *after* the earlier models in :func:`builtin_models` so every
    pre-existing event id is unchanged (trace-format stability across the
    PR sequence); later additions (:func:`remediation_model`) follow the
    same append-only rule.
    """
    return APIModel(
        provider="ust_user",
        apis=(
            APISpec(
                "annotate",
                params=(P("name", "str"), P("payload", "str")),
                counter=True,
            ),
            APISpec(
                "phase",
                params=(P("name", "str"),),
                meta=(("OutScalar", P("name", "str")),),
            ),
        ),
    )


def remediation_model() -> APIModel:
    """ust_repro:remediation — closed-loop control decisions (one event per
    ladder action, ROADMAP "closed-loop remediation").

    A separate trailing :class:`APIModel` (same ``ust_repro`` provider string
    as :func:`framework_model`) rather than a new API inside it: models are
    eid-ordered by position, so appending a model keeps every pre-existing
    event id stable while the event still folds and tallies under the
    ``ust_repro:remediation`` name.
    """
    return APIModel(
        provider="ust_repro",
        apis=(
            APISpec(
                "remediation",
                params=(
                    P("action", "str"),  # escalate_fidelity / checkpoint_drain / evict / ...
                    P("target", "str"),  # rank source id, or "" for run-wide actions
                    P("detail", "str"),  # reason / rung / dry_run marker
                ),
                counter=True,
            ),
        ),
    )


def builtin_models() -> Tuple[APIModel, ...]:
    return (
        framework_model(),
        jaxrt_model(),
        kernel_model(),
        collective_model(),
        telemetry_model(),
        user_model(),
        remediation_model(),  # appended models keep earlier eids stable
    )


def builtin_trace_model() -> TraceModel:
    return build_trace_model(builtin_models())

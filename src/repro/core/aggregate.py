"""On-node processing & multi-rank aggregation (THAPI §3.7).

For large-scale runs THAPI saves only the *aggregate* of each rank's trace
(kilobytes), then "each local master sends its aggregate to the global
master, where the summaries are combined into a composite profile" — the
paper validated this to 512 nodes.

Tallies are mergeable monoids (plugins/tally.py), so the composite profile is
a tree reduction:

    rank tallies ──▶ local master (per node) ──▶ global master

``aggregate_tree`` implements the reduction generically (configurable fanout)
and reports tree statistics; ``combine_trace_dirs`` / ``combine_aggregates``
are the file-based transports used between processes (each rank writes
``aggregate_rank<k>.tally``; masters read + merge).  Serialization is msgpack
— compact, schema-free, fast.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Iterable, List, Sequence, TypeVar

import msgpack

from .plugins.tally import Tally, tally_trace

T = TypeVar("T")


# ---------------------------------------------------------------------------
# Tally (de)serialization
# ---------------------------------------------------------------------------


def save_tally(t: Tally, path: str) -> int:
    blob = msgpack.packb(t.to_obj(), use_bin_type=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return len(blob)


def load_tally(path: str) -> Tally:
    with open(path, "rb") as f:
        return Tally.from_obj(msgpack.unpackb(f.read(), raw=False))


# ---------------------------------------------------------------------------
# Tree reduction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TreeStats:
    leaves: int
    fanout: int
    depth: int
    messages: int


def aggregate_tree(
    items: Sequence[T],
    reducer: Callable[[T, T], T],
    fanout: int = 32,
) -> tuple:
    """Reduce ``items`` through a fanout-ary master tree.

    Level 0 = ranks; each group of ``fanout`` merges into its local master;
    repeat until one composite remains (the global master's profile).
    Returns (composite, TreeStats).
    """
    if not items:
        raise ValueError("nothing to aggregate")
    level: List[T] = list(items)
    depth = 0
    messages = 0
    while len(level) > 1:
        nxt: List[T] = []
        for i in range(0, len(level), fanout):
            group = level[i : i + fanout]
            acc = group[0]
            for other in group[1:]:
                acc = reducer(acc, other)
                messages += 1
            nxt.append(acc)
        level = nxt
        depth += 1
    return level[0], TreeStats(leaves=len(items), fanout=fanout, depth=depth, messages=messages)


def merge_tallies(tallies: Sequence[Tally], fanout: int = 32) -> tuple:
    return aggregate_tree(list(tallies), lambda a, b: a.merge(b), fanout)


# ---------------------------------------------------------------------------
# File transports
# ---------------------------------------------------------------------------


def combine_aggregates(paths: Iterable[str], fanout: int = 32) -> Tally:
    """Global master: merge per-rank ``.tally`` files into a composite."""
    tallies = [load_tally(p) for p in paths]
    composite, _ = merge_tallies(tallies, fanout)
    return composite


def combine_trace_dirs(
    trace_dirs: Iterable[str], fanout: int = 32, legacy_graph: bool = False
) -> Tally:
    """Merge full trace directories (re-tallying each) into a composite.

    Each directory is tallied through the single-pass fold engine by
    default; ``legacy_graph=True`` routes through the Babeltrace-style
    graph (identical result, for cross-checking)."""
    tallies = [tally_trace(d, legacy_graph=legacy_graph) for d in trace_dirs]
    composite, _ = merge_tallies(tallies, fanout)
    return composite


def find_aggregates(root: str) -> List[str]:
    out = []
    for dirpath, _, files in os.walk(root):
        for name in files:
            if name.endswith(".tally"):
                out.append(os.path.join(dirpath, name))
    return sorted(out)

"""Deterministic fault injection for chaos testing the tracing control loop.

The closed-loop remediation stack (``core/remediation.py``) claims it can
flag, drain, and evict a sick rank.  That claim is only testable if we can
*make* a rank sick on demand, reproducibly.  :class:`FaultInjector` is that
harness: a seeded, purely-deterministic schedule of faults a worker consults
at step boundaries (slowdowns, hangs, kills) and that the stream layer can
consult per frame (connection drops, corrupt/truncated frames).

Design rules:

* **Deterministic.**  Same ``FaultSpec`` + same seed → same schedule, on
  every platform.  Randomness comes only from a private ``random.Random``;
  nothing reads the wall clock.
* **Pull, not push.**  The injector never spawns threads or patches code;
  the instrumented site *asks* (``sleep_s(step)``, ``should_die(step)``,
  ``mangle_frame(payload)``) and acts on the answer.  Un-asked faults are
  inert, so wiring the injector into production code paths is safe.
* **CLI-parseable.**  ``FaultSpec.parse("slowdown:rank=1,after=10,factor=8")``
  gives the example driver and CI a one-string interface
  (``--inject-fault=...``).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["FaultKind", "FaultSpec", "FaultInjector", "parse_fault_specs"]


class FaultKind:
    """Enumeration of injectable fault classes (plain strings on the wire)."""

    SLOWDOWN = "slowdown"  # rank sleeps extra seconds per step
    HANG = "hang"          # rank stops making progress (driver must act)
    KILL = "kill"          # rank process exits hard mid-run
    DROP = "drop"          # stream connection dropped before a frame
    CORRUPT = "corrupt"    # frame payload bytes flipped
    TRUNCATE = "truncate"  # frame payload cut short

    ALL = (SLOWDOWN, HANG, KILL, DROP, CORRUPT, TRUNCATE)


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``kind``      one of :class:`FaultKind`.
    ``rank``      target rank (-1 = every rank).
    ``after``     first step (or frame ordinal, for stream faults) affected.
    ``factor``    slowdown multiplier (slowdown) — extra sleep is
                  ``base_step_s * (factor - 1)`` per step.
    ``p``         per-step/per-frame probability in [0, 1]; 1.0 = always
                  (once past ``after``).  Drawn from the injector's seeded
                  stream, so schedules stay reproducible.
    ``duration``  how many steps the fault stays active (0 = forever).
    """

    kind: str
    rank: int = -1
    after: int = 0
    factor: float = 4.0
    p: float = 1.0
    duration: int = 0

    def __post_init__(self) -> None:
        if self.kind not in FaultKind.ALL:
            raise ValueError(f"unknown fault kind {self.kind!r} (know {FaultKind.ALL})")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"fault probability must be in [0,1], got {self.p}")
        if self.factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {self.factor}")
        if self.after < 0 or self.duration < 0:
            raise ValueError("after/duration must be >= 0")

    def active_at(self, step: int) -> bool:
        if step < self.after:
            return False
        if self.duration and step >= self.after + self.duration:
            return False
        return True

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse ``kind[:k=v,k=v,...]`` — e.g. ``slowdown:rank=1,after=10,factor=8``."""
        text = text.strip()
        if not text:
            raise ValueError("empty fault spec")
        kind, _, rest = text.partition(":")
        kw: Dict[str, object] = {}
        if rest:
            for item in rest.split(","):
                if not item.strip():
                    continue
                key, eq, val = item.partition("=")
                key = key.strip()
                if not eq:
                    raise ValueError(f"bad fault option {item!r} (want k=v)")
                if key in ("rank", "after", "duration"):
                    kw[key] = int(val)
                elif key in ("factor", "p"):
                    kw[key] = float(val)
                else:
                    raise ValueError(f"unknown fault option {key!r}")
        return cls(kind=kind.strip(), **kw)  # type: ignore[arg-type]

    def render(self) -> str:
        return (
            f"{self.kind}:rank={self.rank},after={self.after},"
            f"factor={self.factor:g},p={self.p:g},duration={self.duration}"
        )


def parse_fault_specs(text: str) -> Tuple[FaultSpec, ...]:
    """Parse a ``;``-separated list of fault specs (CLI ``--inject-fault``)."""
    specs = tuple(FaultSpec.parse(part) for part in text.split(";") if part.strip())
    if not specs:
        raise ValueError(f"no fault specs in {text!r}")
    return specs


@dataclass
class FaultInjector:
    """Seeded schedule of faults one process consults.

    ``rank`` scopes the injector: specs targeting another rank are ignored,
    so every worker can be handed the same spec string and the same seed and
    still produce a globally consistent (and reproducible) schedule.
    """

    specs: Tuple[FaultSpec, ...] = ()
    rank: int = 0
    seed: int = 0
    log: List[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        # One private stream per (seed, rank): deterministic per-process,
        # uncorrelated across ranks.
        self._rng = random.Random((self.seed << 16) ^ (self.rank & 0xFFFF))

    # -- selection ---------------------------------------------------------

    def _mine(self, kind: str, step: int) -> Optional[FaultSpec]:
        for spec in self.specs:
            if spec.kind != kind:
                continue
            if spec.rank not in (-1, self.rank):
                continue
            if not spec.active_at(step):
                continue
            if spec.p >= 1.0 or self._rng.random() < spec.p:
                return spec
        return None

    def _note(self, what: str) -> None:
        self.log.append(what)

    # -- step-boundary faults (worker loop asks each step) -----------------

    def sleep_s(self, step: int, base_step_s: float = 0.01) -> float:
        """Extra seconds this rank should sleep at ``step`` (0.0 = healthy)."""
        spec = self._mine(FaultKind.SLOWDOWN, step)
        if spec is None:
            return 0.0
        extra = base_step_s * max(spec.factor - 1.0, 0.0)
        self._note(f"slowdown step={step} extra={extra:.4f}s")
        return extra

    def should_hang(self, step: int) -> bool:
        """True if this rank must stop progressing at ``step``."""
        spec = self._mine(FaultKind.HANG, step)
        if spec is not None:
            self._note(f"hang step={step}")
            return True
        return False

    def should_die(self, step: int) -> bool:
        """True if this rank must hard-exit at ``step`` (caller does os._exit)."""
        spec = self._mine(FaultKind.KILL, step)
        if spec is not None:
            self._note(f"kill step={step}")
            return True
        return False

    # -- stream-layer faults (per outgoing frame) --------------------------

    def should_drop_connection(self, frame_no: int) -> bool:
        """True if the streamer should sever its connection before this frame."""
        spec = self._mine(FaultKind.DROP, frame_no)
        if spec is not None:
            self._note(f"drop frame={frame_no}")
            return True
        return False

    def mangle_frame(self, payload: bytes, frame_no: int) -> bytes:
        """Return ``payload`` possibly corrupted/truncated per the schedule.

        Corruption flips one deterministic byte; truncation cuts the payload
        roughly in half.  Receivers must survive both (drop the connection,
        keep the last good state) — that is what the chaos tests assert.
        """
        spec = self._mine(FaultKind.CORRUPT, frame_no)
        if spec is not None and payload:
            i = self._rng.randrange(len(payload))
            self._note(f"corrupt frame={frame_no} byte={i}")
            return payload[:i] + bytes([payload[i] ^ 0xFF]) + payload[i + 1 :]
        spec = self._mine(FaultKind.TRUNCATE, frame_no)
        if spec is not None and len(payload) > 1:
            cut = max(1, len(payload) // 2)
            self._note(f"truncate frame={frame_no} keep={cut}")
            return payload[:cut]
        return payload

    # -- introspection -----------------------------------------------------

    def fired(self, kind: str) -> int:
        """How many times a fault of ``kind`` has fired (from the log)."""
        return sum(1 for line in self.log if line.startswith(kind))

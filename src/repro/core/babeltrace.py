"""Babeltrace2-style trace processing graph (THAPI §3.4, Fig 4).

Babeltrace2 structures analysis as a graph of *source* (CTF reader), *filter*
(muxer — "serializing messages by time"), and *sink* components.  THAPI
generates its plugins from the LTTng trace model via Metababel.  We reproduce
the graph:

    CTFSource(trace_dir) ──▶ muxer ──▶ IntervalFilter ──▶ sinks
                                   └─▶ metababel.Dispatcher callbacks

Events are materialized as lightweight :class:`Event` records; entry/exit
pairs are folded into :class:`Interval` spans by the interval filter ("Interval
plugins enable detailed timing analysis based on the start and end times of
events", §3.3).
"""

from __future__ import annotations

import heapq
import operator
from typing import Dict, Iterable, Iterator, List, Tuple

from .api_model import DISCARD_EVENT_ID, EventType
from .ctf import StreamReader, TraceMeta, stream_files
from .tracepoints import Tracepoints


class Event:
    """One decoded trace event."""

    __slots__ = ("ts", "etype", "fields", "pid", "tid")

    def __init__(self, ts: int, etype: EventType, fields: tuple, pid: int, tid: int):
        self.ts = ts
        self.etype = etype
        self.fields = fields
        self.pid = pid
        self.tid = tid

    @property
    def name(self) -> str:
        return self.etype.name

    def field(self, name: str):
        for p, v in zip(self.etype.fields, self.fields):
            if p.name == name:
                return v
        raise KeyError(name)

    def asdict(self) -> dict:
        return {p.name: v for p, v in zip(self.etype.fields, self.fields)}

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Event({self.name}@{self.ts} {self.asdict()})"


class Interval:
    """A folded entry/exit pair or a device span."""

    __slots__ = ("provider", "api", "ts", "dur", "pid", "tid", "entry", "exit", "device")

    def __init__(self, provider, api, ts, dur, pid, tid, entry, exit, device):
        self.provider = provider
        self.api = api
        self.ts = ts
        self.dur = dur
        self.pid = pid
        self.tid = tid
        self.entry = entry  # dict of entry fields
        self.exit = exit  # dict of exit fields (None if unmatched)
        self.device = device

    def __repr__(self):  # pragma: no cover
        return f"Interval({self.provider}:{self.api} ts={self.ts} dur={self.dur})"


# ---------------------------------------------------------------------------
# Source
# ---------------------------------------------------------------------------


class CTFSource:
    """Reads a CTF-lite trace directory into time-ordered Event streams."""

    def __init__(self, trace_dir: str):
        self.trace_dir = trace_dir
        self.meta = TraceMeta.load(trace_dir)
        self.model = self.meta.model
        # the unpackers are generated from the trace model — the read side
        # shares its schema source with the write side (§3.3)
        self._unpack = Tracepoints(self.model).unpack
        self._etypes = self.model.events
        self.discarded = 0

    def _stream_events(self, path: str) -> Iterator[Event]:
        reader = StreamReader(path)
        unpack = self._unpack
        etypes = self._etypes
        for eid, ts, payload in reader:
            if eid >= len(etypes):
                continue  # unknown event (newer writer) — skip, don't fail
            fields = unpack[eid](payload)
            if eid == DISCARD_EVENT_ID:
                self.discarded += fields[0]
            yield Event(ts, etypes[eid], fields, reader.pid, reader.tid)

    def streams(self) -> List[Iterator[Event]]:
        return [self._stream_events(p) for p in stream_files(self.trace_dir)]

    def __iter__(self) -> Iterator[Event]:
        return muxer(self.streams())


#: C-level attribute fetch — called once per event per heap sift, so the
#: lambda→attrgetter swap is measurable on 10⁶-event merges
_TS_KEY = operator.attrgetter("ts")


def muxer(streams: Iterable[Iterator[Event]]) -> Iterator[Event]:
    """Filter component: k-way merge by timestamp (§3.4 'Muxer plugin')."""
    return heapq.merge(*streams, key=_TS_KEY)


def mux_traces(trace_dirs: Iterable[str]) -> Iterator[Event]:
    """Merge multiple ranks' trace directories into one ordered stream."""
    all_streams: List[Iterator[Event]] = []
    for d in trace_dirs:
        all_streams.extend(CTFSource(d).streams())
    return muxer(all_streams)


# ---------------------------------------------------------------------------
# Interval filter
# ---------------------------------------------------------------------------


class IntervalFilter:
    """Folds entry/exit pairs (per pid/tid call stacks) and device spans.

    Unmatched entries (application crashed mid-call, or exits dropped under
    ring-buffer pressure) surface with ``exit=None`` and ``dur=0`` so the
    validation plugin (§4.2) can flag them rather than silently dropping.
    """

    def __init__(self, events: Iterable[Event]):
        self._events = events
        self.samples: List[Event] = []  # telemetry pass-through
        self.unmatched_exits = 0

    def __iter__(self) -> Iterator[Interval]:
        stacks: Dict[Tuple[int, int, str], List[Event]] = {}
        for ev in self._events:
            et = ev.etype
            if et.phase == "span":
                d = ev.asdict()
                ts0, ts1 = d.pop("ts_begin"), d.pop("ts_end")
                yield Interval(
                    et.provider, et.api, ts0, max(0, ts1 - ts0), ev.pid, ev.tid, d, {}, True
                )
            elif et.phase == "entry":
                stacks.setdefault((ev.pid, ev.tid, et.provider + ":" + et.api), []).append(ev)
            elif et.phase == "exit":
                key = (ev.pid, ev.tid, et.provider + ":" + et.api)
                stack = stacks.get(key)
                if not stack:
                    self.unmatched_exits += 1
                    continue
                entry = stack.pop()
                yield Interval(
                    et.provider,
                    et.api,
                    entry.ts,
                    max(0, ev.ts - entry.ts),
                    ev.pid,
                    ev.tid,
                    entry.asdict(),
                    ev.asdict(),
                    False,
                )
            elif et.phase == "sample":
                self.samples.append(ev)
            # phase == "meta" (discarded counters) handled by the source
        # flush unmatched entries
        for stack in stacks.values():
            for entry in stack:
                yield Interval(
                    entry.etype.provider,
                    entry.etype.api,
                    entry.ts,
                    0,
                    entry.pid,
                    entry.tid,
                    entry.asdict(),
                    None,
                    False,
                )


def intervals_of(trace_dir: str) -> Tuple[List[Interval], List[Event], "CTFSource"]:
    """Convenience: fully materialized intervals + telemetry samples."""
    src = CTFSource(trace_dir)
    filt = IntervalFilter(iter(src))
    ivs = list(filt)
    return ivs, filt.samples, src

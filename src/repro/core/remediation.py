"""Closed-loop remediation: act on cluster flags instead of paging a human.

PR 3 ends at an advisory: ``StragglerRankPolicy`` flags a lagging rank into
the trainer's ``StragglerWatchdog`` and a human (or nothing) takes it from
there.  This module finishes the loop (ROADMAP "closed-loop remediation"):
:class:`RemediationEngine` consumes those flags — straggler, imbalance,
sick-host — and walks a configurable **escalation ladder**:

    rung 0  ``escalate_fidelity``   turn up tracing on the suspect rank
                                    (``repro.trace.set_mode`` / PR 7 ladder)
                                    so the diagnosis sharpens before anything
                                    destructive happens;
    rung 1  ``checkpoint_drain``    checkpoint the trainer and quiesce the
                                    suspect (async ``Checkpointer.save`` +
                                    drain hooks in ``train/trainer.py``);
    rung 2  ``replace``             spawn a fresh incarnation of the drained
                                    rank, restore it from the drain
                                    checkpoint, and splice it back into the
                                    mesh (``launch/elastic.py``) — the rung
                                    exists only when a ``replace`` hook is
                                    configured; without one the ladder goes
                                    straight from drain to evict, exactly
                                    the pre-elastic behavior;
    rung 3  ``evict``               drop the sustained-bad rank from the
                                    active set and re-mesh onto survivors
                                    (``launch/mesh.py``) — the fallback when
                                    replacement is off, over budget, or
                                    failed ``replace_retries`` times.

Control-theory guardrails, all tunable:

* **cooldown** — a rung will not re-fire for the same target within
  ``cooldown_s`` of its last firing;
* **capped-exponential backoff** — a rung whose hook *failed* retries at
  ``cooldown_s * 2^attempts`` capped at ``backoff_cap_s``;
* **escalation patience** — ``escalate_after`` consecutive flagged
  evaluations (while a rung is already active) before the next rung fires;
* **hysteresis** — ``healthy_windows`` consecutive healthy observations
  de-escalate one rung at a time (never straight to zero), and a target is
  only forgotten once it walks all the way back down;
* **dry_run** — decisions are logged and traced but no hook is invoked:
  the advisory-only mode for gaining confidence in a new policy.

Invariants (asserted by the chaos tests):

* **drain-before-evict** — the ladder is strictly ordered; ``evict`` can
  only fire after ``checkpoint_drain`` *succeeded* for that target.
* **remediation is observable** — every decision (including dry-run and
  failed-hook decisions) is recorded as a ``ust_repro:remediation`` trace
  event, so the remediation itself shows up in the tally like any API.

The engine is transport-agnostic and clock-injectable: feed it flags from a
``ClusterAdaptiveController`` (``on_flag=engine.ingest_flag``), from a test,
or from a driver loop, and drive :meth:`tick` from any cadence you like.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "RemediationAction",
    "RemediationHooks",
    "RemediationEngine",
    "RUNG_ESCALATE",
    "RUNG_DRAIN",
    "RUNG_REPLACE",
    "RUNG_EVICT",
]

RUNG_ESCALATE = "escalate_fidelity"
RUNG_DRAIN = "checkpoint_drain"
RUNG_REPLACE = "replace"
RUNG_EVICT = "evict"
_DEESCALATE = "deescalate"
_RECOVER = "recover"

Hook = Callable[[str, str], bool]


@dataclass(frozen=True)
class RemediationAction:
    """One ladder decision, for the audit log (and the trace)."""

    ts: float
    action: str       # rung name, "deescalate", or "recover"
    target: str       # rank source id ("host:pid:rankN")
    detail: str       # reason / evidence summary
    rung: int         # ladder index the target is at after this action
    ok: bool          # hook outcome (True in dry_run / no-hook cases)
    dry_run: bool

    def __str__(self) -> str:
        mode = " [dry-run]" if self.dry_run else ("" if self.ok else " [FAILED]")
        return f"[{self.ts:.3f}] {self.action}({self.target}): {self.detail}{mode}"


@dataclass
class RemediationHooks:
    """The engine's effectors; each takes ``(target, reason) -> bool``.

    ``escalate``   rung 0 — raise trace fidelity on the target rank.
    ``drain``      rung 1 — checkpoint the trainer and quiesce the target.
    ``replace``    rung 2 — spawn/restore/splice a fresh incarnation of the
                   drained rank (``launch/elastic.py``).
    ``evict``      rung 3 — remove the target from the active set, re-mesh.
    ``restore``    called on full recovery (hysteresis walked the target
                   back to healthy) — e.g. undo the fidelity escalation.

    A missing hook makes its rung advisory-only (the decision is still
    logged and traced, and counts as succeeded so the ladder can progress);
    a hook returning ``False`` or raising marks the attempt failed and the
    rung retries with capped-exponential backoff.  ``replace`` is the one
    exception to advisory-only: when it is ``None`` the rung is *skipped*
    entirely (drain escalates straight to evict) — treating a no-op as a
    successful replacement would reset the ladder and the sick rank would
    never be dealt with.
    """

    escalate: Optional[Hook] = None
    drain: Optional[Hook] = None
    replace: Optional[Hook] = None
    evict: Optional[Hook] = None
    restore: Optional[Hook] = None

    def for_rung(self, name: str) -> Optional[Hook]:
        return {
            RUNG_ESCALATE: self.escalate,
            RUNG_DRAIN: self.drain,
            RUNG_REPLACE: self.replace,
            RUNG_EVICT: self.evict,
        }[name]


@dataclass
class _TargetState:
    """Per-target ladder position and timers."""

    rung: int = -1               # -1 = healthy, 0.. = highest rung fired
    flagged: bool = False        # flag seen since last tick
    last_kind: str = ""
    last_detail: str = ""
    flag_streak: int = 0         # consecutive flagged evaluations
    healthy_streak: int = 0      # consecutive healthy evaluations
    last_fire: float = -1e18     # when any rung last fired for this target
    attempts: int = 0            # failed attempts at ``retry_rung``
    retry_rung: int = 0          # rung to retry after a failed hook
    drained: bool = False        # checkpoint_drain succeeded
    evicted: bool = False

    def next_delay(self, cooldown_s: float, cap_s: float) -> float:
        """Seconds after ``last_fire`` before this target may act again."""
        return min(cooldown_s * (2.0 ** self.attempts), cap_s)


class RemediationEngine:
    """Walks flagged targets up the escalation ladder, healthy ones down.

    Thread-safe: flags typically arrive from the cluster controller's tick
    (consumer thread) while :meth:`tick` may run on a driver loop.
    """

    RUNGS: Tuple[str, ...] = (RUNG_ESCALATE, RUNG_DRAIN, RUNG_REPLACE, RUNG_EVICT)

    def __init__(
        self,
        hooks: Optional[RemediationHooks] = None,
        *,
        cooldown_s: float = 5.0,
        backoff_cap_s: float = 60.0,
        escalate_after: int = 2,
        healthy_windows: int = 3,
        dry_run: bool = False,
        max_evictions: int = 1,
        max_replacements: int = 1,
        replace_retries: int = 2,
        clock: Callable[[], float] = time.monotonic,
        on_action: Optional[Callable[[RemediationAction], None]] = None,
    ):
        if cooldown_s <= 0:
            raise ValueError("cooldown_s must be > 0")
        if backoff_cap_s < cooldown_s:
            raise ValueError("backoff_cap_s must be >= cooldown_s")
        if escalate_after < 1 or healthy_windows < 1:
            raise ValueError("escalate_after and healthy_windows must be >= 1")
        if max_replacements < 0 or replace_retries < 0:
            raise ValueError("max_replacements and replace_retries must be >= 0")
        self.hooks = hooks or RemediationHooks()
        self.cooldown_s = cooldown_s
        self.backoff_cap_s = backoff_cap_s
        self.escalate_after = escalate_after
        self.healthy_windows = healthy_windows
        self.dry_run = dry_run
        self.max_evictions = max_evictions
        self.max_replacements = max_replacements
        self.replace_retries = replace_retries
        self.replacements = 0  # successful (non-dry-run) replace rungs fired
        self.actions: List[RemediationAction] = []
        self.clock = clock
        self.on_action = on_action
        self.targets: Dict[str, _TargetState] = {}
        self._trace_record = None  # ust_repro:remediation recorder, when traced
        # re-entrant: a replace hook runs under the lock and its spawn/admit
        # sub-events come back in through note() on the same thread
        self._lock = threading.RLock()

    # -- wiring ------------------------------------------------------------

    def attach(self, tracer) -> "RemediationEngine":
        """Bind to a live tracing session: decisions land in its trace."""
        rec = getattr(tracer, "tp", None)
        self._trace_record = rec.record.get("ust_repro:remediation") if rec else None
        return self

    # -- evidence in -------------------------------------------------------

    def ingest_flag(self, source: str, kind: str = "straggler", detail: str = "") -> None:
        """Report ``source`` as unhealthy (controller ``on_flag`` callback)."""
        with self._lock:
            st = self.targets.setdefault(source, _TargetState())
            if st.evicted:
                return
            st.flagged = True
            st.last_kind = kind
            st.last_detail = detail

    def observe_healthy(self, source: str) -> None:
        """Report ``source`` healthy this window (drives hysteresis)."""
        with self._lock:
            st = self.targets.get(source)
            if st is None or st.evicted:
                return
            st.flagged = False

    # -- decisions out -----------------------------------------------------

    def _emit(self, action: str, target: str, detail: str, rung: int, ok: bool) -> RemediationAction:
        act = RemediationAction(self.clock(), action, target, detail, rung, ok, self.dry_run)
        self.actions.append(act)
        if self._trace_record is not None:
            try:
                tag = detail if not self.dry_run else f"dry_run {detail}"
                if not ok:
                    tag = f"FAILED {tag}"
                self._trace_record(action, target, tag)
            except Exception:
                pass  # observability must never break remediation
        if self.on_action is not None:
            self.on_action(act)
        return act

    def _invoke(self, rung_name: str, target: str, reason: str) -> bool:
        if self.dry_run:
            return True
        hook = self.hooks.for_rung(rung_name)
        if hook is None:
            return True  # advisory-only rung: decision stands, ladder moves on
        try:
            return bool(hook(target, reason))
        except Exception:
            return False

    def tick(self, now: Optional[float] = None) -> List[RemediationAction]:
        """Evaluate every target once; returns the actions fired this tick."""
        if now is None:
            now = self.clock()
        fired: List[RemediationAction] = []
        with self._lock:
            for target, st in self.targets.items():
                if st.evicted:
                    continue
                if st.flagged:
                    st.flag_streak += 1
                    st.healthy_streak = 0
                    act = self._consider_escalation(target, st, now)
                    if act is not None:
                        fired.append(act)
                    st.flagged = False  # consume; next window must re-flag
                else:
                    st.flag_streak = 0
                    st.healthy_streak += 1
                    act = self._consider_deescalation(target, st, now)
                    if act is not None:
                        fired.append(act)
        return fired

    def _replace_available(self) -> bool:
        """Whether the replace rung can fire at all right now."""
        if self.hooks.replace is None and not self.dry_run:
            return False  # no effector: skip the rung, don't fake success
        return self.replacements < self.max_replacements

    def _consider_escalation(self, target: str, st: _TargetState, now: float) -> Optional[RemediationAction]:
        if now - st.last_fire < st.next_delay(self.cooldown_s, self.backoff_cap_s):
            return None  # cooling down (or backing off after a failure)
        if st.attempts > 0:
            next_rung = st.retry_rung  # retry the failed rung before moving on
            if (
                self.RUNGS[next_rung] == RUNG_REPLACE
                and st.attempts > self.replace_retries
            ):
                next_rung += 1  # capped retries exhausted: fall through to evict
        elif st.rung < 0:
            next_rung = 0  # first evidence acts immediately: cheap rung only
        elif st.flag_streak >= self.escalate_after:
            next_rung = st.rung + 1
        else:
            return None  # flagged but not sustained: hold the current rung
        if (
            next_rung < len(self.RUNGS)
            and self.RUNGS[next_rung] == RUNG_REPLACE
            and not self._replace_available()
        ):
            next_rung += 1  # replacement off / over budget: straight to evict
        if next_rung >= len(self.RUNGS):
            return None  # already at the top; nothing above evict
        name = self.RUNGS[next_rung]
        if name == RUNG_REPLACE:
            # replace shares evict's precondition: only a drained target may
            # be torn down and re-spawned (its drain checkpoint is the
            # restore point the replacement comes back from).
            if not st.drained and not self.dry_run:
                return None
        if name == RUNG_EVICT:
            # drain-before-evict invariant, and an eviction budget so a
            # miscalibrated policy cannot shrink the cluster to nothing.
            if not st.drained and not self.dry_run:
                return None
            evicted = sum(1 for s in self.targets.values() if s.evicted)
            if evicted >= self.max_evictions:
                return None
        reason = f"{st.last_kind}: {st.last_detail}" if st.last_detail else st.last_kind
        ok = self._invoke(name, target, reason)
        st.last_fire = now
        if ok:
            st.rung = next_rung
            st.attempts = 0
            st.flag_streak = 0
            if name == RUNG_DRAIN:
                st.drained = True
            if name == RUNG_EVICT and not self.dry_run:
                st.evicted = True
            if name == RUNG_REPLACE and not self.dry_run:
                # The target is now a *new process*: its ladder history
                # belongs to the dead incarnation, so start it fresh (only
                # the replacement budget carries over).
                self.replacements += 1
                st.rung = -1
                st.drained = False
                st.flag_streak = 0
                st.healthy_streak = 0
        else:
            st.retry_rung = next_rung
            st.attempts += 1
        return self._emit(name, target, reason, st.rung, ok)

    def _consider_deescalation(self, target: str, st: _TargetState, now: float) -> Optional[RemediationAction]:
        if st.rung < 0 or st.healthy_streak < self.healthy_windows:
            return None
        st.healthy_streak = 0
        st.attempts = 0
        st.rung -= 1  # one rung at a time: hysteresis, not amnesia
        if st.rung < 0:
            st.drained = False
            ok = True
            if not self.dry_run and self.hooks.restore is not None:
                try:
                    ok = bool(self.hooks.restore(target, "recovered"))
                except Exception:
                    ok = False
            return self._emit(_RECOVER, target, f"healthy x{self.healthy_windows}", st.rung, ok)
        return self._emit(_DEESCALATE, target, f"healthy x{self.healthy_windows}", st.rung, True)

    def note(self, action: str, target: str, detail: str = "", ok: bool = True) -> RemediationAction:
        """Record an out-of-band remediation event in the audit log/trace.

        The elastic layer uses this for sub-decisions the ladder itself does
        not drive — replacement spawn attempts, mesh splices, fence rejects —
        so the full spawn/admit/fence story reads out of one audit trail.
        """
        with self._lock:
            st = self.targets.get(target)
            return self._emit(action, target, detail, st.rung if st else -1, ok)

    # -- introspection -----------------------------------------------------

    @property
    def evicted(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(t for t, s in self.targets.items() if s.evicted)

    def rung_of(self, source: str) -> int:
        """Current ladder rung for ``source`` (-1 = healthy/unknown)."""
        with self._lock:
            st = self.targets.get(source)
            return st.rung if st is not None else -1

    def render_log(self) -> str:
        """Human-readable decision log (one line per action)."""
        return "\n".join(str(a) for a in self.actions)

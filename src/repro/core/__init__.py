"""repro.core — THAPI: programming-model-centric tracing for the JAX stack.

The paper's primary contribution implemented as a system: API-model-driven
tracepoint codegen, per-thread ring buffers with discard mode, CTF-lite
streams, interception wrappers for the JAX stack, a telemetry daemon, and a
Babeltrace2-style analysis graph (pretty / tally / timeline / validate) with
multi-rank aggregation.

Public API:

    from repro.core import TraceConfig, Tracer, trace_session       # collection
    from repro.core import traced_jit, kernel_span, collective_span # interception
    from repro.core import MasterServer, ServeOptions, StreamClient    # streaming
    from repro.core import AdaptiveController, WidenSamplingPolicy  # §6 adaptive
    from repro.core import ClusterAdaptiveController, StragglerRankPolicy  # cluster scope
    from repro.core.plugins.tally import tally_trace, render        # analysis
"""

from .api_model import (  # noqa: F401
    APIModel,
    APISpec,
    P,
    Param,
    TraceModel,
    build_trace_model,
    builtin_models,
    builtin_trace_model,
)
from .interception import (  # noqa: F401
    TracedJit,
    collective_span,
    kernel_span,
    traced_device_get,
    traced_device_put,
    traced_jit,
    train_step_span,
)
from .adaptive import (  # noqa: F401
    AdaptiveAction,
    AdaptiveController,
    AdaptivePolicy,
    ClusterAdaptiveController,
    ClusterPolicy,
    RankImbalanceAdvisoryPolicy,
    RingPressurePolicy,
    SickHostPolicy,
    StragglerRankPolicy,
    StreamCadencePolicy,
    ThresholdAdvisoryPolicy,
    WidenSamplingPolicy,
)
from .faults import (  # noqa: F401
    FaultInjector,
    FaultKind,
    FaultSpec,
    parse_fault_specs,
)
from .remediation import (  # noqa: F401
    RUNG_DRAIN,
    RUNG_ESCALATE,
    RUNG_EVICT,
    RUNG_REPLACE,
    RemediationAction,
    RemediationEngine,
    RemediationHooks,
)
from .fold import (  # noqa: F401
    FoldEngine,
    FoldState,
    fold_trace,
)
from .stream import (  # noqa: F401
    MasterServer,
    ServeOptions,
    ServerRejected,
    SnapshotStreamer,
    StreamClient,
    live_snapshot,
    query_composite,
    query_groups,
    query_ranks,
    subscribe_composites,
)
from .tracer import (  # noqa: F401
    MODES,
    TraceConfig,
    TraceHandle,
    Tracer,
    active_tracer,
    get_tracepoints,
    trace_session,
)

"""Online trace analysis (THAPI §6 future work, implemented).

    "we are also working on online trace analysis, where tracing and analysis
     can be performed concurrently to enable adaptive optimizations during
     application runtime."

The consumer daemon can hand each drained chunk to an :class:`OnlineAnalyzer`
that decodes records incrementally and maintains a LIVE tally (same monoid as
the offline plugin), without waiting for session stop.  The trainer (or an
adaptive policy) can read ``snapshot()`` mid-run — e.g. to detect a dispatch/
poll imbalance and adjust microbatching, the paper's "adaptive optimization"
loop.

Implementation: the analyzer folds the same framed record stream the CTF
writer receives through the shared single-pass fold engine
(:mod:`repro.core.fold`) — the exact code path behind the offline
``tally_trace`` fast path, so live snapshots and offline tallies can never
diverge.  The write path stays zero-cost; analysis rides the consumer
thread.  Pairing stacks are keyed ``(pid, tid)`` first, so multi-process
chunk feeds (a master analyzing several ranks' drains) can never cross-match
an entry from one process with an exit from another.
"""

from __future__ import annotations

import threading

from .api_model import TraceModel
from .fold import FoldEngine
from .plugins.tally import Tally


class OnlineAnalyzer:
    """Incremental entry/exit folding + live tally over drained chunks.

    The live member of the analysis family: fed by the tracer's consumer
    thread (never by recorders), it folds the framed record stream into the
    same :class:`~repro.core.plugins.tally.Tally` monoid the offline plugin
    produces, so live snapshots, streamed snapshots, and batch aggregates
    all merge interchangeably.  ``snapshot()`` is what the streaming layer
    ships and the adaptive controller diffs.
    """

    def __init__(self, model: TraceModel, hostname: str = ""):
        self.model = model
        self._engine = FoldEngine(model)
        self._lock = threading.Lock()
        self._state = self._engine.new_state()
        if hostname:
            self._state.hostnames.add(hostname)

    @property
    def events_seen(self) -> int:
        """Records folded so far (all phases, including skipped samples)."""
        return self._state.events_seen

    @property
    def discarded(self) -> int:
        """Cumulative ctf:events_discarded count observed in the feed."""
        return self._state.discarded

    def feed(self, chunk, pid: int = 0, tid: int = 0) -> None:
        """Fold one drained ring-buffer chunk into the live tally.

        ``chunk`` is any bytes-like object; the tracer's zero-copy drain
        passes a ``memoryview`` over ring storage directly (the fold is
        synchronous, so the region may be released when this returns).
        Entry events open per-``(pid, tid)``, per-API LIFO stacks; exits pop
        and accumulate; device spans accumulate directly; discard records
        bump ``discarded``.  One shared fold pass, one memoryview per chunk.
        Safe to call concurrently with ``snapshot()``.
        """
        with self._lock:
            self._engine.fold_chunk(self._state, chunk, pid, tid)

    def note_discarded(self, n: int) -> None:
        """Account ring drops observed by the consumer directly (tally-only
        fidelity: there is no stream file to carry a discard record)."""
        if n > 0:
            with self._lock:
                self._state.discarded += n

    def finish(self, scale: int = 1) -> Tally:
        """Final tally at session stop: flush unmatched entries as
        zero-duration calls (exactly :meth:`FoldEngine.finish`, so a
        tally-only session's aggregate matches what the offline fold of the
        same records would produce) and, when ``scale > 1``, apply the
        1/N sampling estimator (calls and total durations scale by N; the
        tally is marked estimated).  Terminal: the state has been mutated by
        the flush, so ``feed`` must not be called afterwards."""
        with self._lock:
            t = self._engine.finish(self._state)
        if scale > 1:
            t.scale(scale)
        return t

    def snapshot(self) -> Tally:
        """Copy-on-read live tally (safe to render while tracing continues).

        Open (not yet exited) calls are not part of the snapshot — they join
        the tally when their exit record arrives, matching the cumulative
        semantics the streaming deltas rely on.  The discarded counter is
        stamped in, so streamed snapshots carry ring-pressure evidence."""
        with self._lock:
            return self._state.to_tally()

    def busy_fraction(self, provider: str, api: str, window_total_ns: int) -> float:
        """Adaptive-optimization helper: share of wall time inside an API.

        Cumulative since session start — the caller supplies the elapsed
        window (``window_total_ns``).  For *recent* busy fractions computed
        from successive snapshots, use the windowed metrics on
        :class:`repro.core.adaptive.AdaptiveContext` instead.
        """
        with self._lock:
            row = self._state.rows.get((provider, api))
            return (row[1] / window_total_ns) if row and window_total_ns else 0.0

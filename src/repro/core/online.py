"""Online trace analysis (THAPI §6 future work, implemented).

    "we are also working on online trace analysis, where tracing and analysis
     can be performed concurrently to enable adaptive optimizations during
     application runtime."

The consumer daemon can hand each drained chunk to an :class:`OnlineAnalyzer`
that decodes records incrementally and maintains a LIVE tally (same monoid as
the offline plugin), without waiting for session stop.  The trainer (or an
adaptive policy) can read ``snapshot()`` mid-run — e.g. to detect a dispatch/
poll imbalance and adjust microbatching, the paper's "adaptive optimization"
loop.

Implementation: the analyzer consumes the same framed record stream the CTF
writer receives, using the generated unpackers — write path stays zero-cost,
analysis rides the consumer thread.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from .api_model import DISCARD_EVENT_ID, TraceModel
from .plugins.tally import ApiStat, Tally
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE
from .tracepoints import Tracepoints


class OnlineAnalyzer:
    """Incremental entry/exit folding + live tally over drained chunks.

    The live member of the analysis family: fed by the tracer's consumer
    thread (never by recorders), it folds the framed record stream into the
    same :class:`~repro.core.plugins.tally.Tally` monoid the offline plugin
    produces, so live snapshots, streamed snapshots, and batch aggregates
    all merge interchangeably.  ``snapshot()`` is what the streaming layer
    ships and the adaptive controller diffs.
    """

    def __init__(
        self,
        model: TraceModel,
        tracepoints: Optional[Tracepoints] = None,
        hostname: str = "",
    ):
        self.model = model
        self._unpack = (tracepoints or Tracepoints(model)).unpack
        self._etypes = model.events
        self._lock = threading.Lock()
        self._tally = Tally()
        if hostname:
            self._tally.hostnames.add(hostname)
        #: open entry timestamps per (tid, provider:api) — LIFO like intervals
        self._open: Dict[Tuple[int, str], list] = {}
        self.events_seen = 0
        self.discarded = 0

    def feed(self, chunk: bytes, pid: int = 0, tid: int = 0) -> None:
        """Fold one drained ring-buffer chunk into the live tally.

        Entry events open per-(tid, api) LIFO stacks; exits pop and
        accumulate; device spans accumulate directly; discard records bump
        ``discarded``.  Safe to call concurrently with ``snapshot()``.
        """
        off, n = 0, len(chunk)
        etypes = self._etypes
        with self._lock:
            while off + RECORD_HEADER_SIZE <= n:
                total, eid, ts = RECORD_HEADER.unpack_from(chunk, off)
                if total < RECORD_HEADER_SIZE or off + total > n:
                    break
                self.events_seen += 1
                if eid < len(etypes):
                    et = etypes[eid]
                    if eid == DISCARD_EVENT_ID:
                        self.discarded += self._unpack[eid](
                            memoryview(chunk)[off + RECORD_HEADER_SIZE : off + total]
                        )[0]
                    elif et.phase == "entry":
                        self._open.setdefault((tid, et.provider + ":" + et.api), []).append(ts)
                    elif et.phase == "exit":
                        stack = self._open.get((tid, et.provider + ":" + et.api))
                        if stack:
                            t0 = stack.pop()
                            self._stat(et.provider, et.api, False).add(max(0, ts - t0))
                            self._tally.processes.add(pid)
                            self._tally.threads.add((pid, tid))
                    elif et.phase == "span":
                        payload = memoryview(chunk)[off + RECORD_HEADER_SIZE : off + total]
                        vals = self._unpack[eid](payload)
                        t0, t1 = vals[0], vals[1]
                        name = et.api
                        if et.api == "launch":
                            # kernel name is the first post-span payload field
                            name = vals[2] if len(vals) > 2 and isinstance(vals[2], str) else et.api
                        self._stat(et.provider, name, True).add(max(0, t1 - t0))
                        self._tally.processes.add(pid)
                        self._tally.threads.add((pid, tid))
                off += total

    def _stat(self, provider: str, api: str, device: bool) -> ApiStat:
        table = self._tally.device_apis if device else self._tally.apis
        st = table.get((provider, api))
        if st is None:
            st = table[(provider, api)] = ApiStat()
        return st

    def snapshot(self) -> Tally:
        """Copy-on-read live tally (safe to render while tracing continues)."""
        with self._lock:
            return Tally().merge(self._tally)

    def busy_fraction(self, provider: str, api: str, window_total_ns: int) -> float:
        """Adaptive-optimization helper: share of wall time inside an API.

        Cumulative since session start — the caller supplies the elapsed
        window (``window_total_ns``).  For *recent* busy fractions computed
        from successive snapshots, use the windowed metrics on
        :class:`repro.core.adaptive.AdaptiveContext` instead.
        """
        with self._lock:
            st = self._tally.apis.get((provider, api))
            return (st.total_ns / window_total_ns) if st and window_total_ns else 0.0

"""Automatic tracepoint generation (THAPI §3.3, Fig 1b, Fig 3).

THAPI generates the LTTng ``TRACEPOINT_EVENT`` C code and the interception
wrappers from the API model.  We do exactly that, in Python: for every event
type of the trace model we *generate source code* for

  * a **recorder** — the tracepoint: packs the payload per the event schema
    and writes one framed record into the calling thread's ring buffer;
  * an **unpacker** — the inverse, used by the Babeltrace-style analysis
    layer (and by Metababel's generated dispatchers), guaranteeing that the
    write and read sides can never drift apart because they come from the
    same schema.

The generated recorder hot path is branch-light:

    def ust_jaxrt__memcpy_entry(src, dst, nbytes, kind):
        if not _enabled[7]: return
        _rb = _rings.get()
        _p = _S.pack(src, dst, nbytes, kind)
        _rb.write(_H.pack(14 + len(_p), 7, _now()) + _p)

Per-event enablement (`_enabled`, a flat list of ints) is LTTng's selective
event activation (§3.2): the tracer flips entries per tracing mode; with no
active session every entry is 0 and tracepoints cost one list index + branch.
"""

from __future__ import annotations

import struct
from typing import Callable, Dict, List, Optional, Sequence

from .api_model import FIELD_CLASSES, VARLEN, EventType, TraceModel
from .clock import now
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE, RingRegistry

_LEN = struct.Struct("<I")


def _segments(fields) -> List:
    """Split the field tuple into runs of fixed-size fields and varlen fields.

    Returns a list of ("fixed", [Param...], struct.Struct) / ("var", Param).
    """
    segs: List = []
    run = []
    for f in fields:
        if f.cls in VARLEN:
            if run:
                segs.append(("fixed", list(run)))
                run = []
            segs.append(("var", f))
        else:
            run.append(f)
    if run:
        segs.append(("fixed", list(run)))
    out = []
    for seg in segs:
        if seg[0] == "fixed":
            fmt = "<" + "".join(FIELD_CLASSES[p.cls] for p in seg[1])
            out.append(("fixed", seg[1], struct.Struct(fmt)))
        else:
            out.append(seg)
    return out


# ---------------------------------------------------------------------------
# Recorder codegen
# ---------------------------------------------------------------------------


def codegen_recorder(ev: EventType) -> str:
    """Source for one tracepoint function (≙ one TRACEPOINT_EVENT of Fig 3)."""
    args = [p.name for p in ev.fields]
    fname = ev.name.replace(":", "__")
    lines = [f"def {fname}({', '.join(args)}):"]
    lines.append(f"    if not _enabled[{ev.eid}]: return")
    segs = _segments(ev.fields)
    parts = []
    for i, seg in enumerate(segs):
        if seg[0] == "fixed":
            _, params, _ = seg
            argl = ", ".join(p.name for p in params)
            lines.append(f"    _p{i} = _S{i}.pack({argl})")
        else:
            _, p = seg
            if p.cls == "str":
                lines.append(f"    _v{i} = {p.name}.encode() if type({p.name}) is str else bytes({p.name})")
            else:
                lines.append(f"    _v{i} = bytes({p.name})")
            lines.append(f"    _p{i} = _L.pack(len(_v{i})) + _v{i}")
        parts.append(f"_p{i}")
    payload = " + ".join(parts) if parts else "b''"
    lines.append(f"    _p = {payload}")
    lines.append(
        f"    _rings.get().write(_H.pack({RECORD_HEADER_SIZE} + len(_p), {ev.eid}, _now()) + _p)"
    )
    return "\n".join(lines)


def codegen_unpacker(ev: EventType) -> str:
    """Source for the payload unpacker (field-order tuple from a memoryview)."""
    fname = "unpack_" + ev.name.replace(":", "__")
    lines = [f"def {fname}(mv):", "    _o = 0", "    _out = []"]
    for i, seg in enumerate(_segments(ev.fields)):
        if seg[0] == "fixed":
            _, params, st = seg
            lines.append(f"    _out.extend(_S{i}.unpack_from(mv, _o)); _o += {st.size}")
        else:
            _, p = seg
            lines.append("    _n = _L.unpack_from(mv, _o)[0]; _o += 4")
            if p.cls == "str":
                lines.append("    _out.append(bytes(mv[_o:_o+_n]).decode(errors='replace')); _o += _n")
            else:
                lines.append("    _out.append(bytes(mv[_o:_o+_n])); _o += _n")
    lines.append("    return tuple(_out)")
    return "\n".join(lines)


class Tracepoints:
    """All generated recorders/unpackers for one trace model.

    ``record[name]`` — tracepoint callables keyed by event name.
    ``unpack[eid]``  — payload unpackers keyed by event id.
    ``enabled``      — per-event activation flags (shared with recorders).
    """

    def __init__(self, model: TraceModel):
        self.model = model
        self.enabled: List[int] = [0] * len(model.events)
        self._registry_holder = _RegistryHolder()
        self.record: Dict[str, Callable] = {}
        self.unpack: Dict[int, Callable] = {}
        for ev in model.events:
            ns = {
                "_enabled": self.enabled,
                "_rings": self._registry_holder,
                "_H": RECORD_HEADER,
                "_L": _LEN,
                "_now": now,
            }
            for i, seg in enumerate(_segments(ev.fields)):
                if seg[0] == "fixed":
                    ns[f"_S{i}"] = seg[2]
            src = codegen_recorder(ev)
            exec(compile(src, f"<tracepoint {ev.name}>", "exec"), ns)
            self.record[ev.name] = ns[ev.name.replace(":", "__")]

            uns = {"_L": _LEN}
            for i, seg in enumerate(_segments(ev.fields)):
                if seg[0] == "fixed":
                    uns[f"_S{i}"] = seg[2]
            usrc = codegen_unpacker(ev)
            exec(compile(usrc, f"<unpacker {ev.name}>", "exec"), uns)
            self.unpack[ev.eid] = uns["unpack_" + ev.name.replace(":", "__")]

    # -- session binding -----------------------------------------------------

    def attach(self, registry: RingRegistry, enabled_eids: Sequence[int]) -> None:
        self._registry_holder.registry = registry
        for eid in range(len(self.enabled)):
            self.enabled[eid] = 0
        for eid in enabled_eids:
            self.enabled[eid] = 1

    def detach(self) -> None:
        for eid in range(len(self.enabled)):
            self.enabled[eid] = 0
        self._registry_holder.registry = None

    def set_event(self, name: str, on: bool) -> None:
        ev = self.model.by_name()[name]
        self.enabled[ev.eid] = 1 if on else 0


class _RegistryHolder:
    """Indirection cell so generated code survives session swaps.

    ``get()`` raises only if a recorder fires while enabled[eid]==1 but no
    registry is attached — a tracer bug, not a user state.
    """

    __slots__ = ("registry",)

    def __init__(self):
        self.registry: Optional[RingRegistry] = None

    def get(self):
        return self.registry.get()

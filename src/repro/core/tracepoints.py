"""Automatic tracepoint generation (THAPI §3.3, Fig 1b, Fig 3).

THAPI generates the LTTng ``TRACEPOINT_EVENT`` C code and the interception
wrappers from the API model.  We do exactly that, in Python: for every event
type of the trace model we *generate source code* for

  * a **recorder** — the tracepoint: packs the payload per the event schema
    and writes one framed record into the calling thread's ring buffer;
  * an **unpacker** — the inverse, used by the Babeltrace-style analysis
    layer (and by Metababel's generated dispatchers), guaranteeing that the
    write and read sides can never drift apart because they come from the
    same schema;
  * a **pair recorder** for every entry/exit API — one call that frames both
    records in a single reserved region (the wrapper supplies the entry
    timestamp it captured before the traced work ran).

Two code variants are compiled per recorder and swapped via ``__code__`` at
session attach (so callables cached by the interception layer stay valid):

``ring_reserve=True`` (default) — the zero-allocation hot path.  The record
layout is compiled into fused ``struct`` formats (header + fixed fields +
varlen length prefixes collapse into ONE ``pack_into`` per contiguous run)
written directly into ring storage through the reserve/commit protocol.  The
per-thread ``(ring, storage, mask)`` binding is cached after first touch at
``_tls.c`` — one attribute load on the session registry's thread-local, no
registry/holder call chain — and the single-compare ``_lim`` bound skips
even the ``reserve()`` call on the common path.  Runtime helpers ride in
trailing positional defaults (LOAD_FAST, not LOAD_GLOBAL); session-scoped
ones (``_tls``) are refreshed through ``fn.__defaults__`` at attach.  The
generated fast path looks like:

    def ust_jaxrt__memcpy_entry(src, dst, nbytes, kind, payload_head,
                                _e=..., _bytes=..., _len=..., _tls=..., _bind=..., _now=..., _pk0=...):
        if not _e[7]: return
        _v0 = payload_head if payload_head.__class__ is _bytes else ...
        _k0 = _len(_v0)
        _n = 43 + _k0
        try:
            _ct = _tls.c
        except AttributeError:
            _ct = _bind()          # first touch: bind this thread's ring
        _rb = _ct[0]; _h = _rb.head
        if _h + _n <= _rb._lim:
            _pk0(_ct[1], _h & _ct[2], _n, 7, _now(), src, dst, nbytes, kind, _k0)
            ...
            _rb.head = _h + _n

``ring_reserve=False`` — the legacy bytes-write escape hatch: per-segment
``_S.pack`` objects concatenated and handed to ``RingBuffer.write``.  Both
variants produce byte-identical ring content for the same inputs and clock.

Per-event enablement (`_enabled`, a flat list of ints) is LTTng's selective
event activation (§3.2): the tracer flips entries per tracing mode; with no
active session every entry is 0 and tracepoints cost one list index + branch.
"""

from __future__ import annotations

import random
import struct
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .api_model import FIELD_CLASSES, VARLEN, EventType, TraceModel
from .clock import now
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE, RingRegistry

_LEN = struct.Struct("<I")

#: the fidelity ladder (instrumentation-mode axis, orthogonal to the §5.2
#: *content* modes minimal/default/full): how much each tracepoint costs.
#:   full       — every enabled event is recorded (the historical behavior)
#:   sampled    — 1/N systematic sampling of entry/exit *pairs* (uniform
#:                random initial phase → exactly unbiased scaled estimates)
#:   tally-only — record as usual, but the consumer folds in-process and no
#:                .ctf stream is ever written (tracer.py's drain policy)
#:   off        — every enablement flag is zeroed in place: recorders cost
#:                one list index + branch, rings see zero writes
FIDELITY_MODES = ("full", "sampled", "tally-only", "off")

#: cap on the per-pair sampling-decision stacks: entries recorded without a
#: matching exit (or across a mid-run fidelity flip) must not grow the
#: per-thread state without bound.  Deeper nesting than this of one API on
#: one thread is degenerate; beyond it exits fall back to "record".
_SAMPLE_STACK_MAX = 1024


def _segments(fields) -> List:
    """Split the field tuple into runs of fixed-size fields and varlen fields.

    Returns a list of ("fixed", [Param...], struct.Struct) / ("var", Param).
    """
    segs: List = []
    run = []
    for f in fields:
        if f.cls in VARLEN:
            if run:
                segs.append(("fixed", list(run)))
                run = []
            segs.append(("var", f))
        else:
            run.append(f)
    if run:
        segs.append(("fixed", list(run)))
    out = []
    for seg in segs:
        if seg[0] == "fixed":
            fmt = "<" + "".join(FIELD_CLASSES[p.cls] for p in seg[1])
            out.append(("fixed", seg[1], struct.Struct(fmt)))
        else:
            out.append(seg)
    return out


# ---------------------------------------------------------------------------
# Reserve-mode codegen: record layout → fused pack_into program
# ---------------------------------------------------------------------------


class _RecordPlan:
    """One record of a recorder (a pair recorder has two)."""

    def __init__(self, ev: EventType, ts_expr: str, arg_prefix: str = ""):
        self.ev = ev
        self.ts_expr = ts_expr  # python expr for the header timestamp
        self.arg_prefix = arg_prefix  # disambiguates pair exit args
        self.segs = _segments(ev.fields)
        self.const = RECORD_HEADER_SIZE + sum(
            seg[2].size if seg[0] == "fixed" else 4 for seg in self.segs
        )
        self.kterms: List[str] = []  # filled by the walker

    def arg(self, p) -> str:
        return self.arg_prefix + p.name

    @property
    def size_expr(self) -> str:
        return " + ".join([str(self.const)] + self.kterms)


def _compile_records(records: List[_RecordPlan]):
    """Lay out one or more framed records into a fused pack_into program.

    Returns (prologue, ops, fmts, total_const, total_kterms, mega_vals).
    ``ops`` interleaves ("pack", fmt_idx, vals, off) and ("data", v, k, off)
    preserving byte order; ``off`` is (const, [k-terms]) relative to the
    reserved offset.  ``mega_vals`` is the all-varlens-empty value list for
    the single fused struct covering every byte (None when any group is
    impossible to fuse — i.e. never; it is None only when there is no varlen
    at all, in which case ``ops`` is already a single pack).
    """
    prologue: List[str] = []
    ops: List[tuple] = []
    fmts: List[str] = []
    mega_fmt = ""
    mega_vals: List[str] = []
    cur_fmt = ""
    cur_vals: List[str] = []
    cur_off: Optional[Tuple[int, List[str]]] = None
    const = 0
    terms: List[str] = []
    vidx = 0

    def flush():
        nonlocal cur_fmt, cur_vals, cur_off
        if cur_fmt:
            fmts.append("<" + cur_fmt)
            ops.append(("pack", len(fmts) - 1, cur_vals, cur_off))
        cur_fmt, cur_vals, cur_off = "", [], None

    def add(fmt: str, vals: List[str], mvals: List[str]):
        nonlocal cur_fmt, cur_off, mega_fmt
        if not cur_fmt:
            cur_off = (const, list(terms))
        cur_fmt += fmt
        cur_vals.extend(vals)
        mega_fmt += fmt
        mega_vals.extend(mvals)

    for rec in records:
        # precompute this record's own varlen terms (header needs its size)
        own = [f"_k{vidx + j}" for j, seg in enumerate(
            s for s in rec.segs if s[0] == "var")]
        rec.kterms = own
        add(
            "IHQ",
            [rec.size_expr, str(rec.ev.eid), rec.ts_expr],
            [str(rec.const), str(rec.ev.eid), rec.ts_expr],
        )
        const += RECORD_HEADER_SIZE
        for seg in rec.segs:
            if seg[0] == "fixed":
                _, params, st = seg
                names = [rec.arg(p) for p in params]
                add(st.format[1:], names, names)
                const += st.size
            else:
                _, p = seg
                v, k = f"_v{vidx}", f"_k{vidx}"
                name = rec.arg(p)
                if p.cls == "str":
                    prologue.append(
                        f"    {v} = {name}.encode() if {name}.__class__ is _str else _bytes({name})"
                    )
                else:
                    prologue.append(
                        f"    {v} = {name} if {name}.__class__ is _bytes else _bytes({name})"
                    )
                prologue.append(f"    {k} = _len({v})")
                add("I", [k], ["0"])
                const += 4
                flush()
                ops.append(("data", v, k, (const, list(terms))))
                terms.append(k)
                vidx += 1
    flush()
    if terms:
        fmts.append("<" + mega_fmt)
    else:
        mega_vals = None  # no varlen: the general program is one static pack
    return prologue, ops, fmts, const, terms, mega_vals


def _off_expr(off: Tuple[int, List[str]]) -> str:
    const, terms = off
    parts = ["_o"] + ([str(const)] if const else []) + terms
    return " + ".join(parts)


def _emit_pack_block(ops, indent: str) -> List[str]:
    lines = []
    for op in ops:
        if op[0] == "pack":
            _, idx, vals, off = op
            lines.append(f"{indent}_pk{idx}(_b, {_off_expr(off)}, {', '.join(vals)})")
        else:
            _, v, k, off = op
            lines.append(f"{indent}_s = {_off_expr(off)}")
            lines.append(f"{indent}_b[_s:_s + {k}] = {v}")
    return lines


def _reserve_body(
    records: List[_RecordPlan], nrecords: int, extra_drop: int
) -> Tuple[List[str], List[str], List[str]]:
    """Shared reserve-mode body (after the enablement check).

    Returns (lines, default_params, struct_formats).  ``extra_drop`` adds to
    ``dropped`` on a failed reserve beyond the 1 reserve() itself counts (a
    dropped pair discards two events).

    The per-thread binding ``(ring, storage, mask)`` lives at ``_tls.c``,
    where ``_tls`` is the *session registry's* ``threading.local`` (rebound
    into the recorder defaults at attach).  Thread-local storage dies with
    its thread, so a recycled thread ident can never alias a dead thread's
    ring, and every recorder shares the one binding per thread.
    """
    prologue, ops, fmts, const, terms, mega_vals = _compile_records(records)
    lines = list(prologue)
    lines.append(f"    _n = {' + '.join([str(const)] + terms)}")
    lines.append("    try:")
    lines.append("        _ct = _tls.c")
    lines.append("    except AttributeError:")
    lines.append("        _ct = _bind()")
    lines.append("    _rb = _ct[0]")
    lines.append("    _h = _rb.head")
    lines.append("    if _h + _n <= _rb._lim:")
    lines.append("        _b = _ct[1]")
    lines.append("        _o = _h & _ct[2]")
    if mega_vals is not None:
        mega_idx = len(fmts) - 1
        any_k = " or ".join(terms)
        lines.append(f"        if {any_k}:")
        lines.extend(_emit_pack_block(ops, " " * 12))
        lines.append("        else:")
        lines.append(
            f"            _pk{mega_idx}(_b, _o, {', '.join(mega_vals)})"
        )
    else:
        lines.extend(_emit_pack_block(ops, " " * 8))
    lines.append("        _rb.head = _h + _n")
    lines.append(f"        _rb.events += {nrecords}")
    lines.append("        return")
    lines.append("    _o = _rb.reserve(_n)")
    lines.append("    if _o < 0:")
    if extra_drop:
        lines.append(f"        _rb.dropped += {extra_drop}")
    lines.append("        return")
    lines.append("    _b = _rb.wbuf")
    lines.extend(_emit_pack_block(ops, "    "))
    lines.append("    _rb.commit(_n)")
    lines.append(f"    _rb.events += {nrecords}")

    # helpers ride in trailing positional defaults: LOAD_FAST, not LOAD_GLOBAL.
    # The flag lists are mutated in place by attach()/set_event (never
    # rebound), so binding the list object at def time is safe; `_tls` is
    # session state and gets refreshed via fn.__defaults__ at attach/detach.
    defaults = ["_e=_enabled"] if len(records) == 1 else ["_e2=_enabled2"]
    if terms:
        defaults.extend(["_bytes=_bytes", "_len=_len"])
        if any(
            seg[0] == "var" and seg[1].cls == "str"
            for rec in records
            for seg in rec.segs
        ):
            defaults.append("_str=_str")
    defaults.extend(["_tls=_tls", "_bind=_bind", "_now=_now"])
    defaults.extend(f"_pk{i}=_PK{i}" for i in range(len(fmts)))
    # sampling helpers ride in EVERY variant's defaults (used only by the
    # sampled codes): all four codes of one recorder share one parameter
    # list, so a fidelity flip is a single atomic __code__ store — no
    # __defaults__ rewrite racing concurrent callers
    defaults.extend(["_sn=_SN", "_qi=_QI"])
    return lines, defaults, fmts


def _sample_gate_lines(role: str, pair_idx: int) -> List[str]:
    """Systematic-sampling gate prepended to a recorder body (sampled tier).

    ``_q`` is the per-thread sampling state at ``_tls.q``: one list per
    entry/exit pair, ``_q[pair_idx][0]`` that pair's call counter
    (initialized to a uniform random phase in ``[0, N)`` by ``_qi``) and the
    remaining elements its decision stack, so an exit follows its own
    entry's decision under nesting.  Counters are *per pair*, not shared:
    each API keeps 1 of every N of *its own* calls, so periodic workloads
    (the common case — the same event sequence every step) cannot alias one
    API onto "always selected" and another onto "never selected"; every
    API's sampled count converges to calls/N.  The gate runs *before* the
    enablement check: the counter indexes call attempts, so entry singles,
    exit singles, and fused pair recorders stay mutually consistent
    regardless of per-event enablement overrides.
    """
    lines = [
        "    try:",
        "        _q = _tls.q",
        "    except AttributeError:",
        "        _q = _qi(_tls)",
        f"    _qp = _q[{pair_idx}]",
    ]
    if role == "pair":
        lines += [
            "    _c = _qp[0]",
            "    _qp[0] = _c + 1",
            "    if _c % _sn[0]: return",
        ]
    elif role == "entry":
        lines += [
            "    _c = _qp[0]",
            "    _qp[0] = _c + 1",
            "    _sel = 0 if _c % _sn[0] else 1",
            f"    if len(_qp) < {_SAMPLE_STACK_MAX}: _qp.append(_sel)",
            "    if not _sel: return",
        ]
    else:  # exit: follow the matching entry's decision; empty stack (entry
        # recorded before a flip into sampled mode) falls back to "record"
        lines += [
            "    if len(_qp) > 1 and not _qp.pop(): return",
        ]
    return lines


# ---------------------------------------------------------------------------
# Legacy codegen (the bytes-write escape hatch, ring_reserve=False)
# ---------------------------------------------------------------------------


def _legacy_payload_lines(
    ev: EventType, sname: str, pname: str, prefix: str = "", indent: str = "    "
) -> Tuple[List[str], str]:
    """Current-behavior payload build: per-segment packs + concatenation."""
    lines = []
    parts = []
    for i, seg in enumerate(_segments(ev.fields)):
        if seg[0] == "fixed":
            _, params, _ = seg
            argl = ", ".join(prefix + p.name for p in params)
            lines.append(f"{indent}{pname}{i} = {sname}{i}.pack({argl})")
        else:
            _, p = seg
            name = prefix + p.name
            if p.cls == "str":
                lines.append(
                    f"{indent}_lv{pname}{i} = {name}.encode() if type({name}) is str else bytes({name})"
                )
            else:
                lines.append(f"{indent}_lv{pname}{i} = bytes({name})")
            lines.append(f"{indent}{pname}{i} = _L.pack(len(_lv{pname}{i})) + _lv{pname}{i}")
        parts.append(f"{pname}{i}")
    payload = " + ".join(parts) if parts else "b''"
    return lines, payload


# ---------------------------------------------------------------------------
# Recorder codegen (both variants)
# ---------------------------------------------------------------------------


def codegen_recorder(
    ev: EventType,
    reserve: bool = True,
    sampled_pair: Optional[Tuple[int, str]] = None,
) -> str:
    """Source for one tracepoint function (≙ one TRACEPOINT_EVENT of Fig 3).

    ``sampled_pair=(pair_idx, role)`` emits the statistical-sampling variant:
    the systematic 1/N gate of :func:`_sample_gate_lines` runs first, then
    the normal enablement check and record body.
    """
    args = [p.name for p in ev.fields]
    fname = ev.name.replace(":", "__")
    gate = (
        _sample_gate_lines(sampled_pair[1], sampled_pair[0]) if sampled_pair else []
    )
    if reserve:
        body, defaults, _ = _reserve_body(
            [_RecordPlan(ev, "_now()")], nrecords=1, extra_drop=0
        )
        sig = ", ".join(args + defaults)
        lines = [f"def {fname}({sig}):"]
        lines.extend(gate)
        lines.append(f"    if not _e[{ev.eid}]: return")
        lines.extend(body)
        return "\n".join(lines)
    # legacy: identical behavior to the historical bytes-write recorder, with
    # the reserve variant's signature so __code__ swapping stays legal
    _, defaults, _ = _reserve_body([_RecordPlan(ev, "_now()")], 1, 0)
    sig = ", ".join(args + defaults)
    lines = [f"def {fname}({sig}):"]
    lines.extend(gate)
    lines.append(f"    if not _enabled[{ev.eid}]: return")
    pay_lines, payload = _legacy_payload_lines(ev, "_S", "_p")
    lines.extend(pay_lines)
    lines.append(f"    _p = {payload}")
    lines.append(
        f"    _rings.get().write(_H.pack({RECORD_HEADER_SIZE} + len(_p), {ev.eid}, _now()) + _p)"
    )
    return "\n".join(lines)


def codegen_pair_recorder(
    entry_ev: EventType,
    exit_ev: EventType,
    pair_idx: int,
    reserve: bool = True,
    sampled: bool = False,
) -> str:
    """Source for a fused entry/exit recorder: two framed records, one call.

    Signature: ``(<entry args>, _ts_entry, <exit args prefixed x_>)`` — the
    wrapper captures the entry timestamp before the traced work and records
    both events after it, halving the per-call overhead of the hottest
    interception pattern (the paper's memcpy running example; polling fences).
    The pair is atomic under discard: both records or neither (``dropped``
    advances by 2).  Enablement is one precomputed flag (``_enabled2``,
    maintained at attach/set_event); when overrides split the pair, a
    still-enabled entry is written with the caller's ``_ts_entry`` (not a
    fresh clock read — its timestamp must not shift because the *other*
    event of the pair was disabled) and a still-enabled exit goes through
    its single recorder.
    """
    e_args = [p.name for p in entry_ev.fields]
    x_args = ["x_" + p.name for p in exit_ev.fields]
    fname = entry_ev.name.replace(":", "__").replace("_entry", "_pair")
    gate = _sample_gate_lines("pair", pair_idx) if sampled else []

    def fallback(flag_expr):
        fa_lines, fa_payload = _legacy_payload_lines(
            entry_ev, "_SA", "_fa", indent=" " * 12
        )
        return [
            f"    if not {flag_expr}:",
            f"        if _enabled[{entry_ev.eid}]:",
            *fa_lines,
            f"            _fa = {fa_payload}",
            f"            _rings.get().write(_H.pack({RECORD_HEADER_SIZE} + len(_fa), "
            f"{entry_ev.eid}, _ts_entry) + _fa)",
            f"        if _enabled[{exit_ev.eid}]: _rec_exit({', '.join(x_args)})",
            "        return",
        ]

    records = [
        _RecordPlan(entry_ev, "_ts_entry"),
        _RecordPlan(exit_ev, "_now()", arg_prefix="x_"),
    ]
    if reserve:
        body, defaults, _ = _reserve_body(records, nrecords=2, extra_drop=1)
        sig = ", ".join(e_args + ["_ts_entry"] + x_args + defaults)
        lines = [f"def {fname}({sig}):"]
        lines.extend(gate)
        lines.extend(fallback(f"_e2[{pair_idx}]"))
        lines.extend(body)
        return "\n".join(lines)
    _, defaults, _ = _reserve_body(records, 2, 1)
    sig = ", ".join(e_args + ["_ts_entry"] + x_args + defaults)
    lines = [f"def {fname}({sig}):"]
    lines.extend(gate)
    lines.extend(fallback(f"_enabled2[{pair_idx}]"))
    pay_a, payload_a = _legacy_payload_lines(entry_ev, "_SA", "_pa")
    lines.extend(pay_a)
    lines.append(f"    _pa = {payload_a}")
    lines.append(
        f"    _r1 = _H.pack({RECORD_HEADER_SIZE} + len(_pa), {entry_ev.eid}, _ts_entry) + _pa"
    )
    pay_b, payload_b = _legacy_payload_lines(exit_ev, "_SB", "_pb", prefix="x_")
    lines.extend(pay_b)
    lines.append(f"    _pb = {payload_b}")
    lines.append(
        f"    _r2 = _H.pack({RECORD_HEADER_SIZE} + len(_pb), {exit_ev.eid}, _now()) + _pb"
    )
    lines.append("    _rb = _rings.get()")
    lines.append("    if len(_r1) + len(_r2) > _rb.capacity - (_rb.head - _rb.tail):")
    lines.append("        _rb.dropped += 2")
    lines.append("        return")
    lines.append("    _rb.write(_r1)")
    lines.append("    _rb.write(_r2)")
    return "\n".join(lines)


def codegen_unpacker(ev: EventType) -> str:
    """Source for the payload unpacker (field-order tuple from a memoryview)."""
    fname = "unpack_" + ev.name.replace(":", "__")
    lines = [f"def {fname}(mv):", "    _o = 0", "    _out = []"]
    for i, seg in enumerate(_segments(ev.fields)):
        if seg[0] == "fixed":
            _, params, st = seg
            lines.append(f"    _out.extend(_S{i}.unpack_from(mv, _o)); _o += {st.size}")
        else:
            _, p = seg
            lines.append("    _n = _L.unpack_from(mv, _o)[0]; _o += 4")
            if p.cls == "str":
                lines.append("    _out.append(bytes(mv[_o:_o+_n]).decode(errors='replace')); _o += _n")
            else:
                lines.append("    _out.append(bytes(mv[_o:_o+_n])); _o += _n")
    lines.append("    return tuple(_out)")
    return "\n".join(lines)


class Tracepoints:
    """All generated recorders/unpackers for one trace model.

    ``record[name]``       — tracepoint callables keyed by event name.
    ``record_pair[api]``   — fused entry/exit recorders keyed "provider:api".
    ``unpack[eid]``        — payload unpackers keyed by event id.
    ``enabled``            — per-event activation flags (shared with recorders).
    ``clock``              — timestamp source (injectable for byte-identity
                             tests; defaults to the trace clock).
    """

    def __init__(self, model: TraceModel, clock: Optional[Callable[[], int]] = None):
        self.model = model
        self.enabled: List[int] = [0] * len(model.events)
        #: the session's *wanted* enablement, as handed to attach()/set_event:
        #: the source of truth that "off" zeroes `enabled` against and that
        #: leaving "off" restores from
        self._session_enabled: List[int] = [0] * len(model.events)
        #: derived per-pair flags: enabled[entry] & enabled[exit], so the
        #: fused recorders pay one list index instead of two
        self.enabled_pair: List[int] = []
        self._pair_eids: List[Tuple[int, int]] = []
        self.clock = clock or now
        self.ring_reserve = True
        #: current rung of the fidelity ladder (see FIDELITY_MODES)
        self.fidelity = "full"
        self._sampled = False
        #: 1/N sampling interval, in a one-element list so the live value is
        #: readable through the recorders' `_sn` default without a rebind
        self._sample_interval: List[int] = [64]
        self._sample_rng = random.Random()
        #: forced initial counter phase (tests/ensemble enumeration); None
        #: draws uniformly from [0, N) per thread — the unbiasedness source
        self._sample_phase: Optional[int] = None
        self._qinit = self._make_qinit()
        self._registry_holder = _RegistryHolder()
        self._binder = self._make_binder(self._registry_holder)
        self.record: Dict[str, Callable] = {}
        self.record_pair: Dict[str, Callable] = {}
        self.unpack: Dict[int, Callable] = {}
        self._namespaces: List[dict] = []
        #: recorder → ((sampled, reserve) → code, ns, default names); attach()
        #: picks a code and refreshes __defaults__ from ns, set_fidelity()
        #: swaps codes alone (one atomic store per recorder)
        self._variants: Dict[Callable, Tuple] = {}

        # entry/exit pairing must precede single-recorder codegen: the
        # sampled variants of entry/exit singles address their pair's
        # decision stack by pair index
        by_key: Dict[Tuple[str, str], Dict[str, EventType]] = {}
        for ev in model.events:
            if ev.phase in ("entry", "exit"):
                by_key.setdefault((ev.provider, ev.api), {})[ev.phase] = ev
        pair_role: Dict[int, Tuple[int, str]] = {}  # eid → (pair_idx, role)
        for (provider, api), phases in by_key.items():
            if "entry" not in phases or "exit" not in phases:
                continue
            pair_idx = len(self._pair_eids)
            self._pair_eids.append((phases["entry"].eid, phases["exit"].eid))
            self.enabled_pair.append(0)
            pair_role[phases["entry"].eid] = (pair_idx, "entry")
            pair_role[phases["exit"].eid] = (pair_idx, "exit")

        for ev in model.events:
            ns = self._base_ns()
            for i, seg in enumerate(_segments(ev.fields)):
                if seg[0] == "fixed":
                    ns[f"_S{i}"] = seg[2]
            names = self._install_structs(ns, [_RecordPlan(ev, "_now()")], 1, 0)
            sp = pair_role.get(ev.eid)
            sources = [
                ((False, True), codegen_recorder(ev, reserve=True)),
                ((False, False), codegen_recorder(ev, reserve=False)),
            ]
            if sp is not None:  # only entry/exit pairs get a sampled tier
                sources += [
                    ((True, True), codegen_recorder(ev, reserve=True, sampled_pair=sp)),
                    ((True, False), codegen_recorder(ev, reserve=False, sampled_pair=sp)),
                ]
            fn = self._compile_variants(
                ns, ev.name.replace(":", "__"), sources, ev.name, names
            )
            self.record[ev.name] = fn

            uns = {"_L": _LEN}
            for i, seg in enumerate(_segments(ev.fields)):
                if seg[0] == "fixed":
                    uns[f"_S{i}"] = seg[2]
            usrc = codegen_unpacker(ev)
            exec(compile(usrc, f"<unpacker {ev.name}>", "exec"), uns)
            self.unpack[ev.eid] = uns["unpack_" + ev.name.replace(":", "__")]

        # fused entry/exit pair recorders (same pair order as the precompute)
        for (provider, api), phases in by_key.items():
            if "entry" not in phases or "exit" not in phases:
                continue
            entry_ev, exit_ev = phases["entry"], phases["exit"]
            pair_idx = pair_role[entry_ev.eid][0]
            ns = self._base_ns()
            for i, seg in enumerate(_segments(entry_ev.fields)):
                if seg[0] == "fixed":
                    ns[f"_SA{i}"] = seg[2]
            for i, seg in enumerate(_segments(exit_ev.fields)):
                if seg[0] == "fixed":
                    ns[f"_SB{i}"] = seg[2]
            ns["_rec_entry"] = self.record[entry_ev.name]
            ns["_rec_exit"] = self.record[exit_ev.name]
            records = [
                _RecordPlan(entry_ev, "_ts_entry"),
                _RecordPlan(exit_ev, "_now()", arg_prefix="x_"),
            ]
            names = self._install_structs(ns, records, 2, 1)
            sources = [
                ((False, True), codegen_pair_recorder(entry_ev, exit_ev, pair_idx, reserve=True)),
                ((False, False), codegen_pair_recorder(entry_ev, exit_ev, pair_idx, reserve=False)),
                ((True, True), codegen_pair_recorder(entry_ev, exit_ev, pair_idx, reserve=True, sampled=True)),
                ((True, False), codegen_pair_recorder(entry_ev, exit_ev, pair_idx, reserve=False, sampled=True)),
            ]
            fn = self._compile_variants(
                ns,
                entry_ev.name.replace(":", "__").replace("_entry", "_pair"),
                sources,
                f"{provider}:{api}",
                names,
            )
            self.record_pair[f"{provider}:{api}"] = fn

    # -- codegen plumbing ----------------------------------------------------

    def _base_ns(self) -> dict:
        ns = {
            "_enabled": self.enabled,
            "_enabled2": self.enabled_pair,
            "_rings": self._registry_holder,
            "_H": RECORD_HEADER,
            "_L": _LEN,
            "_now": self.clock,
            "_bytes": bytes,
            "_len": len,
            "_str": str,
            # per-thread ring-binding cache lives at _tls.c; a placeholder
            # local until a session attaches its registry's thread-local.
            # Per-THREAD storage (not ident-keyed): a recycled thread ident
            # can never alias a dead thread's binding.
            "_tls": threading.local(),
            "_bind": self._binder,
            "_SN": self._sample_interval,
            "_QI": self._qinit,
        }
        self._namespaces.append(ns)
        return ns

    def _make_qinit(self) -> Callable:
        """Cold-path sampling-state init: build this thread's ``_tls.q`` —
        one ``[counter, *decision_stack]`` list per entry/exit pair, each
        counter starting at a (random or forced) phase in ``[0, N)``.
        Random phases are drawn independently per pair; a forced phase
        (tests enumerating the ensemble) applies to every pair."""

        def qinit(tls):
            n = self._sample_interval[0]
            ph = self._sample_phase
            q: list = []
            for _ in range(len(self._pair_eids)):
                if ph is not None:
                    p = ph
                else:
                    p = self._sample_rng.randrange(n) if n > 1 else 0
                q.append([p])
            tls.q = q
            return q

        return qinit

    @staticmethod
    def _make_binder(holder) -> Callable:
        """Cold-path ring binding: resolve this thread's ring once, cache the
        ``(ring, storage, mask)`` tuple on the session registry's
        thread-local — all recorders share it via their ``_tls`` default."""

        def bind():
            registry = holder.registry
            rb = registry.get()
            ct = (rb, rb._buf, rb._mask)
            registry._tls.c = ct
            return ct

        return bind

    @staticmethod
    def _install_structs(ns: dict, records: List[_RecordPlan], nrec: int, extra: int) -> List[str]:
        """Bind the fused pack_into methods the reserve variant's defaults
        use; return the defaults' namespace names (for __defaults__ refresh)."""
        _, defaults, fmts = _reserve_body(records, nrec, extra)
        for i, fmt in enumerate(fmts):
            ns[f"_PK{i}"] = struct.Struct(fmt).pack_into
        return [d.split("=", 1)[1] for d in defaults]

    def _compile_variants(self, ns, pyname, sources, label, default_names):
        """Compile every (sampled, reserve) source into one namespace; the
        first source's function object is the installed callable, the rest
        contribute only their code objects.  Recorders with no sampled tier
        (spans, counters, samples) alias the full codes — a fidelity flip
        still swaps them, to the code they already run."""
        codes: Dict[Tuple[bool, bool], object] = {}
        fn = None
        for key, src in sources:
            tag = f"{'sampled ' if key[0] else ''}{'reserve' if key[1] else 'legacy'}"
            exec(compile(src, f"<tracepoint {tag} {label}>", "exec"), ns)
            f = ns.pop(pyname)
            if fn is None:
                fn = f
            codes[key] = f.__code__
        for r in (True, False):
            codes.setdefault((True, r), codes[(False, r)])
        ns[pyname] = fn
        self._variants[fn] = (codes, ns, default_names)
        return fn

    # -- session binding -----------------------------------------------------

    def _rebind_session(self, tls) -> None:
        """Point every recorder's ``_tls`` default at the session's
        thread-local.  A fresh local has no ``c`` attribute anywhere, so all
        threads fall to the bind path on first touch — cache invalidation
        across sessions comes for free (the sampling state ``_tls.q`` rides
        the same object and is invalidated the same way)."""
        key = (self._sampled, self.ring_reserve)
        for fn, (codes, ns, names) in self._variants.items():
            ns["_tls"] = tls
            code = codes[key]
            if fn.__code__ is not code:
                fn.__code__ = code
            fn.__defaults__ = tuple(ns[n] for n in names)

    def _swap_codes(self) -> None:
        """Flip every recorder to the current (sampled, reserve) code.

        The mode-switch handoff invariant: all variants of one recorder share
        one parameter list and one defaults tuple, so this is a single atomic
        ``__code__`` store per recorder under the GIL — a concurrent caller
        runs either the old or the new code in full, and both publish whole
        framed records (pack first, then one atomic ``head`` store), so no
        torn or reordered records can exist across the flip.
        """
        key = (self._sampled, self.ring_reserve)
        for fn, (codes, _ns, _names) in self._variants.items():
            code = codes[key]
            if fn.__code__ is not code:
                fn.__code__ = code

    def attach(
        self,
        registry: RingRegistry,
        enabled_eids: Sequence[int],
        ring_reserve: bool = True,
    ) -> None:
        self._registry_holder.registry = registry
        self.ring_reserve = bool(ring_reserve)
        self.fidelity = "full"  # every session starts at the top rung
        self._sampled = False
        self._rebind_session(registry._tls)
        for eid in range(len(self.enabled)):
            self.enabled[eid] = 0
            self._session_enabled[eid] = 0
        for eid in enabled_eids:
            self.enabled[eid] = 1
            self._session_enabled[eid] = 1
        self._recompute_pairs()

    def detach(self) -> None:
        for eid in range(len(self.enabled)):
            self.enabled[eid] = 0
            self._session_enabled[eid] = 0
        self.fidelity = "full"
        self._sampled = False
        self._recompute_pairs()
        self._rebind_session(threading.local())  # drop all ring bindings
        self._registry_holder.registry = None

    def set_event(self, name: str, on: bool) -> None:
        ev = self.model.by_name()[name]
        self._session_enabled[ev.eid] = 1 if on else 0
        if self.fidelity != "off":  # "off" keeps the live flags zeroed
            self.enabled[ev.eid] = 1 if on else 0
        self._recompute_pairs()

    def set_fidelity(
        self,
        mode: str,
        interval: Optional[int] = None,
        phase: Optional[int] = None,
        seed: Optional[int] = None,
    ) -> str:
        """Move to a rung of the fidelity ladder; returns the previous rung.

        ``interval`` updates the 1/N sampling interval (in place: already
        bound threads see it on their next draw).  ``phase`` forces the
        per-thread initial counter phase (tests enumerate the ensemble with
        it); ``seed`` reseeds the phase RNG.  Safe mid-run: see _swap_codes.
        """
        if mode not in FIDELITY_MODES:
            raise ValueError(f"unknown fidelity {mode!r} (want one of {FIDELITY_MODES})")
        if interval is not None:
            if int(interval) < 1:
                raise ValueError("sampling interval must be >= 1")
            self._sample_interval[0] = int(interval)
        if seed is not None:
            self._sample_rng = random.Random(seed)
        self._sample_phase = phase
        prev = self.fidelity
        self.fidelity = mode
        want_sampled = mode == "sampled"
        if want_sampled != self._sampled:
            self._sampled = want_sampled
            self._swap_codes()
        if mode == "off":
            for eid in range(len(self.enabled)):
                self.enabled[eid] = 0
        else:
            for eid in range(len(self.enabled)):
                self.enabled[eid] = self._session_enabled[eid]
        self._recompute_pairs()
        return prev

    def _recompute_pairs(self) -> None:
        enabled = self.enabled
        for i, (e, x) in enumerate(self._pair_eids):
            self.enabled_pair[i] = enabled[e] & enabled[x]


class _RegistryHolder:
    """Indirection cell so generated code survives session swaps.

    ``get()`` raises only if a recorder fires while enabled[eid]==1 but no
    registry is attached — a tracer bug, not a user state.
    """

    __slots__ = ("registry",)

    def __init__(self):
        self.registry: Optional[RingRegistry] = None

    def get(self):
        return self.registry.get()
